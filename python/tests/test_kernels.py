"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including ragged, non-tile-multiple sizes) and
block-size parameters — the CORE correctness signal for the kernels that
end up inside the exported HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

TOL = dict(rtol=1e-4, atol=1e-4)


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ----------------------------------------------------------------- matmul


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_small(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(K.matmul(a, b), ref.matmul_ref(a, b), **TOL)


@pytest.mark.parametrize(
    "m,k,n", [(128, 128, 128), (64, 3072, 512), (200, 300, 260), (8, 512, 16)]
)
def test_matmul_matches_ref_tileable(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    a, b = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(K.matmul(a, b), ref.matmul_ref(a, b), **TOL)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (32, 128, 256), (128, 256, 128)])
def test_matmul_block_shapes(bm, bn, bk):
    rng = np.random.default_rng(7)
    a, b = _arr(rng, 96, 384), _arr(rng, 384, 256)
    got = K.matmul_pallas(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), **TOL)


def test_matmul_grad_matches_ref_grad():
    rng = np.random.default_rng(11)
    a, b = _arr(rng, 17, 40), _arr(rng, 40, 23)

    def f_pal(a, b):
        return jnp.sum(jnp.tanh(K.matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.tanh(ref.matmul_ref(a, b)))

    ga_p, gb_p = jax.grad(f_pal, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, **TOL)
    np.testing.assert_allclose(gb_p, gb_r, **TOL)


def test_matmul_jit_and_vmap_compose():
    rng = np.random.default_rng(3)
    a, b = _arr(rng, 12, 20), _arr(rng, 20, 8)
    np.testing.assert_allclose(jax.jit(K.matmul)(a, b), ref.matmul_ref(a, b), **TOL)


# ------------------------------------------------------------- sgd_update


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 200_000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    wd=st.floats(0.0, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_update_matches_ref(n, lr, mu, wd, seed):
    rng = np.random.default_rng(seed)
    w, g, m = _arr(rng, n), _arr(rng, n), _arr(rng, n)
    hyper = jnp.array([lr, mu, wd, 1.0 / 256], jnp.float32)
    w1, m1 = K.sgd_update(w, g, m, hyper)
    w2, m2 = ref.sgd_update_ref(w, g, m, hyper)
    np.testing.assert_allclose(w1, w2, **TOL)
    np.testing.assert_allclose(m1, m2, **TOL)


@pytest.mark.parametrize("block", [64, 1024, 65_536])
def test_sgd_update_block_sizes(block):
    rng = np.random.default_rng(5)
    n = 10_000
    w, g, m = _arr(rng, n), _arr(rng, n), _arr(rng, n)
    hyper = jnp.array([0.1, 0.9, 1e-4, 1.0], jnp.float32)
    w1, m1 = K.sgd_update(w, g, m, hyper, block=block)
    w2, m2 = ref.sgd_update_ref(w, g, m, hyper)
    np.testing.assert_allclose(w1, w2, **TOL)
    np.testing.assert_allclose(m1, m2, **TOL)


def test_sgd_zero_momentum_is_plain_sgd():
    rng = np.random.default_rng(9)
    n = 1000
    w, g = _arr(rng, n), _arr(rng, n)
    m = jnp.zeros(n, jnp.float32)
    hyper = jnp.array([0.5, 0.0, 0.0, 1.0], jnp.float32)
    w1, _ = K.sgd_update(w, g, m, hyper)
    np.testing.assert_allclose(w1, w - 0.5 * g, **TOL)


# ---------------------------------------------------------- elastic_update


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 200_000),
    alpha=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_elastic_matches_ref(n, alpha, seed):
    rng = np.random.default_rng(seed)
    w, c = _arr(rng, n), _arr(rng, n)
    a = jnp.array([alpha], jnp.float32)
    np.testing.assert_allclose(K.elastic1(c, w, a), ref.elastic1_ref(c, w, a), **TOL)
    np.testing.assert_allclose(K.elastic2(w, c, a), ref.elastic2_ref(w, c, a), **TOL)
    wf, cf = K.elastic_fused(w, c, a)
    wr, cr = ref.elastic_fused_ref(w, c, a)
    np.testing.assert_allclose(wf, wr, **TOL)
    np.testing.assert_allclose(cf, cr, **TOL)


def test_elastic_fused_equals_split():
    """Fused kernel must equal applying eq.2 and eq.3 from the SAME w, c."""
    rng = np.random.default_rng(17)
    n = 4096
    w, c = _arr(rng, n), _arr(rng, n)
    a = jnp.array([0.25], jnp.float32)
    wf, cf = K.elastic_fused(w, c, a)
    np.testing.assert_allclose(wf, K.elastic2(w, c, a), **TOL)
    np.testing.assert_allclose(cf, K.elastic1(c, w, a), **TOL)


def test_elastic_alpha_zero_is_identity():
    rng = np.random.default_rng(2)
    w, c = _arr(rng, 512), _arr(rng, 512)
    a = jnp.zeros(1, jnp.float32)
    np.testing.assert_allclose(K.elastic2(w, c, a), w, **TOL)
    np.testing.assert_allclose(K.elastic1(c, w, a), c, **TOL)


def test_elastic_alpha_one_swaps_roles():
    rng = np.random.default_rng(4)
    w, c = _arr(rng, 512), _arr(rng, 512)
    a = jnp.ones(1, jnp.float32)
    np.testing.assert_allclose(K.elastic2(w, c, a), c, **TOL)  # w -> center
    np.testing.assert_allclose(K.elastic1(c, w, a), w, **TOL)  # center -> w


# ---------------------------------------------------------- tensor_reduce


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 8),
    n=st.integers(1, 100_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_tensor_reduce_matches_ref(k, n, seed):
    rng = np.random.default_rng(seed)
    s = _arr(rng, k, n)
    np.testing.assert_allclose(
        K.tensor_reduce(s), ref.tensor_reduce_ref(s), rtol=1e-4, atol=1e-3
    )


@pytest.mark.parametrize("block", [128, 8192, 32_768])
def test_tensor_reduce_block_sizes(block):
    rng = np.random.default_rng(6)
    s = _arr(rng, 4, 50_000)
    np.testing.assert_allclose(
        K.tensor_reduce(s, block=block), ref.tensor_reduce_ref(s), rtol=1e-4, atol=1e-3
    )


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 100_000), seed=st.integers(0, 2**31 - 1))
def test_reduce_pair_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, n), _arr(rng, n)
    np.testing.assert_allclose(K.reduce_pair(x, y), x + y, **TOL)


def test_reduce_pair_is_commutative_associative_on_ints():
    """With integer-valued f32 data the reduction is exact: order-free."""
    rng = np.random.default_rng(8)
    vals = [jnp.asarray(rng.integers(-100, 100, 1000).astype(np.float32)) for _ in range(4)]
    acc1 = K.reduce_pair(K.reduce_pair(vals[0], vals[1]), K.reduce_pair(vals[2], vals[3]))
    acc2 = K.reduce_pair(vals[3], K.reduce_pair(vals[2], K.reduce_pair(vals[1], vals[0])))
    np.testing.assert_array_equal(np.asarray(acc1), np.asarray(acc2))
