"""AOT pipeline: lowered HLO text + metadata round-trip sanity."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = M.VARIANTS["mlp_tiny"]
    meta = aot.lower_variant(cfg, out, tensor_ks=(2,))
    return out, cfg, meta


def test_artifact_files_exist_and_are_hlo_text(lowered):
    out, cfg, meta = lowered
    for kind, fname in meta["artifacts"].items():
        path = os.path.join(out, fname)
        assert os.path.exists(path), fname
        if fname.endswith(".hlo.txt"):
            text = open(path).read()
            assert text.startswith("HloModule"), f"{fname} is not HLO text"
            assert "ENTRY" in text


def test_init_bin_matches_param_count(lowered):
    out, cfg, meta = lowered
    init = np.fromfile(os.path.join(out, meta["artifacts"]["init"]), "<f4")
    assert init.shape[0] == meta["params"]
    ref = M.init_params(cfg, seed=0)
    np.testing.assert_array_equal(init, ref)


def test_meta_segments_cover_params(lowered):
    _, cfg, meta = lowered
    off = 0
    for s in meta["segments"]:
        assert s["offset"] == off
        assert s["size"] == int(np.prod(s["shape"]))
        off += s["size"]
    assert off == meta["params"]


def test_meta_shapes_match_config(lowered):
    _, cfg, meta = lowered
    assert meta["x"]["shape"] == [cfg.batch, cfg.input_dim]
    assert meta["y"]["shape"] == [cfg.batch]
    assert meta["x"]["dtype"] == "float32"
    assert meta["y"]["dtype"] == "int32"


def test_grad_hlo_has_tuple_root_with_loss_and_grads(lowered):
    out, cfg, meta = lowered
    text = open(os.path.join(out, meta["artifacts"]["grad"])).read()
    n = meta["params"]
    # root tuple carries (f32[] loss, f32[n] grads)
    assert f"f32[{n}]" in text


def test_full_meta_json_written(tmp_path):
    """main() writes a meta.json covering all requested variants."""
    import sys
    from unittest import mock

    out = str(tmp_path / "arts")
    argv = ["aot", "--out-dir", out, "--variants", "mlp_tiny"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    meta = json.load(open(os.path.join(out, "meta.json")))
    assert "mlp_tiny" in meta["variants"]
    assert meta["variants"]["mlp_tiny"]["params"] == 4324
