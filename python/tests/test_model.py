"""L2 model correctness: flattening, shapes, loss/grad vs pure-jnp model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TOL = dict(rtol=2e-4, atol=2e-4)


def _mlp_ref_loss(cfg, flat, x, y):
    """Pure-jnp reimplementation of the residual MLP (no Pallas)."""
    segs = M.build_segments(cfg.param_shapes())
    p = M.unflatten(flat, segs)
    h = jax.nn.relu(x @ p["in.w"] + p["in.b"])
    for i in range(cfg.blocks):
        z = jax.nn.relu(h @ p[f"block{i}.w1"] + p[f"block{i}.b1"])
        z = z @ p[f"block{i}.w2"] + p[f"block{i}.b2"]
        h = jax.nn.relu(h + z)
    logits = h @ p["head.w"] + p["head.b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


@pytest.fixture(scope="module")
def tiny():
    cfg = M.VARIANTS["mlp_tiny"]
    grad_step, eval_step, segs, x_spec, y_spec = M.make_model(cfg)
    return cfg, grad_step, eval_step, segs, x_spec, y_spec


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(cfg.batch, cfg.input_dim)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, cfg.classes, cfg.batch).astype(np.int32))
    return x, y


def test_segments_are_contiguous_and_cover_vector(tiny):
    cfg, _, _, segs, _, _ = tiny
    off = 0
    for s in segs:
        assert s.offset == off
        assert s.size == int(np.prod(s.shape))
        off += s.size
    assert off == M.total_size(segs)


def test_init_params_deterministic_and_finite():
    cfg = M.VARIANTS["mlp_tiny"]
    a = M.init_params(cfg, seed=0)
    b = M.init_params(cfg, seed=0)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.isfinite(a))
    c = M.init_params(cfg, seed=1)
    assert not np.array_equal(a, c)


def test_init_biases_zero_scales_one():
    cfg = M.VARIANTS["transformer_tiny"]
    flat = M.init_params(cfg, seed=0)
    segs = M.build_segments(cfg.param_shapes())
    for s in segs:
        v = flat[s.offset : s.offset + s.size]
        if s.name.endswith(".bias") or s.name.endswith("_b"):
            assert np.all(v == 0), s.name
        if s.name.endswith(".scale"):
            assert np.all(v == 1), s.name


def test_mlp_loss_and_grad_match_pure_jnp(tiny):
    cfg, grad_step, _, segs, _, _ = tiny
    flat = jnp.asarray(M.init_params(cfg, seed=0))
    x, y = _batch(cfg)
    loss, grads = grad_step(flat, x, y)
    loss_ref, grads_ref = jax.value_and_grad(
        lambda f: _mlp_ref_loss(cfg, f, x, y)
    )(flat)
    np.testing.assert_allclose(loss, loss_ref, **TOL)
    np.testing.assert_allclose(grads, grads_ref, rtol=1e-3, atol=1e-3)


def test_eval_step_counts_correct(tiny):
    cfg, _, eval_step, _, _, _ = tiny
    flat = jnp.asarray(M.init_params(cfg, seed=0))
    x, y = _batch(cfg)
    loss, correct = eval_step(flat, x, y)
    assert 0 <= int(correct) <= cfg.batch
    assert np.isfinite(float(loss))


def test_mlp_one_sgd_step_reduces_loss(tiny):
    cfg, grad_step, _, _, _, _ = tiny
    flat = jnp.asarray(M.init_params(cfg, seed=0))
    x, y = _batch(cfg)
    loss0, g = grad_step(flat, x, y)
    loss1, _ = grad_step(flat - 0.05 * g, x, y)
    assert float(loss1) < float(loss0)


def test_transformer_loss_finite_and_trains():
    cfg = M.VARIANTS["transformer_tiny"]
    grad_step, _, segs, _, _ = M.make_model(cfg)
    rng = np.random.default_rng(0)
    flat = jnp.asarray(M.init_params(cfg, seed=0))
    x = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32))
    y = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32))
    loss0, g = grad_step(flat, x, y)
    assert np.isfinite(float(loss0))
    # near-uniform logits at init => loss ~ log(vocab)
    assert abs(float(loss0) - np.log(cfg.vocab)) < 1.0
    loss1, _ = grad_step(flat - 0.5 * g, x, y)
    assert float(loss1) < float(loss0)


def test_transformer_causality():
    """Changing a future token must not change past logits."""
    cfg = M.VARIANTS["transformer_tiny"]
    segs = M.build_segments(cfg.param_shapes())
    flat = jnp.asarray(M.init_params(cfg, seed=0))
    p = M.unflatten(flat, segs)
    rng = np.random.default_rng(1)
    x = rng.integers(0, cfg.vocab, (1, cfg.seq)).astype(np.int32)
    x2 = x.copy()
    x2[0, -1] = (x2[0, -1] + 1) % cfg.vocab
    l1 = M.transformer_logits(cfg, p, jnp.asarray(x))
    l2 = M.transformer_logits(cfg, p, jnp.asarray(x2))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_all_variants_build():
    for name, cfg in M.VARIANTS.items():
        segs = M.build_segments(cfg.param_shapes())
        assert M.total_size(segs) > 0, name
