"""L1 Pallas kernels for the MXNET-MPI reproduction (build-time only)."""

from .elastic_update import elastic1, elastic2, elastic_fused
from .matmul import matmul, matmul_pallas
from .sgd_update import sgd_update
from .tensor_reduce import reduce_pair, tensor_reduce

__all__ = [
    "elastic1",
    "elastic2",
    "elastic_fused",
    "matmul",
    "matmul_pallas",
    "sgd_update",
    "reduce_pair",
    "tensor_reduce",
]
