"""Pure-jnp oracles for every Pallas kernel (correctness reference)."""

import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.matmul(a, b)


def sgd_update_ref(w, g, m, hyper):
    lr, mu, wd, rescale = hyper[0], hyper[1], hyper[2], hyper[3]
    g_eff = rescale * g + wd * w
    m_new = mu * m + g_eff
    return w - lr * m_new, m_new


def elastic1_ref(center, w, alpha):
    return center + alpha[0] * (w - center)


def elastic2_ref(w, center, alpha):
    return w - alpha[0] * (w - center)


def elastic_fused_ref(w, center, alpha):
    diff = w - center
    return w - alpha[0] * diff, center + alpha[0] * diff


def tensor_reduce_ref(stacked):
    return jnp.sum(stacked, axis=0)


def reduce_pair_ref(x, y):
    return x + y
