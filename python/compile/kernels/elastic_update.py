"""L1 Pallas kernels: elastic-averaging SGD updates (paper eqs. 2-3).

Elastic averaging (Zhang et al. 2015, paper §2.2) keeps *center variables*
w~ on the PS and applies, every INTERVAL iterations:

    server (Elastic1):  w~ <- w~ + alpha * (w - w~)      (eq. 2)
    client (Elastic2):  w  <- w  - alpha * (w - w~)      (eq. 3)

Both sides read the *pre-update* (w - w~) difference, so the fused kernel
computes the difference once and emits both outputs; the split kernels
mirror the paper's deployment (Elastic1 shipped to the PS via
set_optimizer, Elastic2 run by the MPI client, Fig. 8 lines 2/12).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Single grid step whenever the vector fits; first vector argument
# aliases the first output (in-place update). See sgd_update.py.
BLOCK = 1 << 20


def _elastic1_kernel(a_ref, c_ref, w_ref, c_out):
    alpha = a_ref[0]
    c_out[...] = c_ref[...] + alpha * (w_ref[...] - c_ref[...])


def _elastic2_kernel(a_ref, w_ref, c_ref, w_out):
    alpha = a_ref[0]
    w_out[...] = w_ref[...] - alpha * (w_ref[...] - c_ref[...])


def _elastic_fused_kernel(a_ref, w_ref, c_ref, w_out, c_out):
    alpha = a_ref[0]
    diff = w_ref[...] - c_ref[...]
    c_out[...] = c_ref[...] + alpha * diff
    w_out[...] = w_ref[...] - alpha * diff


def _blocked_1d(kernel, n_out, args, *, block=BLOCK, aliases=None):
    """Run an elementwise 1-D kernel over equally-shaped flat vectors.

    args[0] is the f32[1] scalar block (broadcast); the rest are f32[n].
    """
    n = args[1].shape[0]
    blk = min(block, n)
    pad = (-n) % blk
    vecs = [jnp.pad(v, (0, pad)) if pad else v for v in args[1:]]
    np_ = n + pad
    grid = (np_ // blk,)
    vec_spec = pl.BlockSpec((blk,), lambda i: (i,))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))] + [vec_spec] * len(vecs),
        out_specs=[vec_spec] * n_out if n_out > 1 else vec_spec,
        out_shape=[jax.ShapeDtypeStruct((np_,), jnp.float32)] * n_out
        if n_out > 1
        else jax.ShapeDtypeStruct((np_,), jnp.float32),
        input_output_aliases=aliases or {},
        interpret=True,
    )(args[0], *vecs)
    if n_out == 1:
        return outs[:n]
    return tuple(o[:n] for o in outs)


def elastic1(center, w, alpha):
    """Server-side center update (eq. 2). alpha: f32[1]."""
    return _blocked_1d(_elastic1_kernel, 1, (alpha, center, w), aliases={1: 0})


def elastic2(w, center, alpha):
    """Client-side parameter update (eq. 3). alpha: f32[1]."""
    return _blocked_1d(_elastic2_kernel, 1, (alpha, w, center), aliases={1: 0})


def elastic_fused(w, center, alpha):
    """Both updates from the shared pre-update difference -> (w', center')."""
    return _blocked_1d(_elastic_fused_kernel, 2, (alpha, w, center), aliases={1: 0, 2: 1})
