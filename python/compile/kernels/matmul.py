"""L1 Pallas matmul kernel — the model's dense-layer hot spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
matmuls (cuBLAS under MXNET) map on TPU to an MXU-targeted tiled matmul.
Tiles are chosen MXU/VMEM friendly: (bm, bk) x (bk, bn) blocks, with the
output block revisited across the K grid dimension as the accumulator —
the classic Pallas schedule where BlockSpec index maps express the
HBM<->VMEM movement that the paper's thread blocks expressed in CUDA.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO; structure (BlockSpec
schedule, VMEM footprint) is what we optimize, not CPU wall-clock.

Autodiff: ``pallas_call`` has no VJP, so ``matmul`` carries a custom VJP
whose backward pass reuses the same kernel (dA = dY @ B^T, dB = A^T @ dY).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic array edge; a
# (128,128)x(128,128) step holds 3 f32 tiles = 192 KiB in VMEM, leaving
# ample room for double buffering within the ~16 MiB budget.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o += A[i,k] @ B[k,j], o zeroed at k == 0."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, mult_r, mult_c):
    r, c = x.shape
    pr = (-r) % mult_r
    pc = (-c) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _tile(dim, pref, align):
    """Largest multiple of ``align`` <= min(pref, dim), or dim if tiny."""
    if dim <= align:
        return dim
    t = min(pref, dim)
    return max(align, t - t % align)


def matmul_pallas(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """C = A @ B via the Pallas kernel, padding ragged edges to tile size."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm_ = _tile(m, bm, 8)
    bn_ = _tile(n, bn, 128)
    bk_ = _tile(k, bk, 128)
    a_p = _pad_to(a, bm_, bk_)
    b_p = _pad_to(b, bk_, bn_)
    mp, kp = a_p.shape
    _, np_ = b_p.shape
    n_k = kp // bk_
    grid = (mp // bm_, np_ // bn_, n_k)
    res = pl.pallas_call(
        partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return res[:m, :n]


@jax.custom_vjp
def matmul(a, b):
    """Differentiable Pallas matmul (f32)."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = matmul_pallas(g, b.T)
    db = matmul_pallas(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
