"""L1 Pallas kernel: fused SGD update with momentum + weight decay.

The paper ships the optimizer to the PS via ``KVStore.set_optimizer`` (§3.2)
and rescales gradients by 1/mini_batch_size (§5). This kernel fuses the
whole parameter update into one pass over the flat parameter vector:

    g'  = rescale * g + wd * w
    m'  = mu * m + g'
    w'  = w - lr * m'        (mu = 0 degrades to plain SGD)

Scalars (lr, mu, wd, rescale) arrive as a single f32[4] operand so the Rust
coordinator can drive learning-rate schedules without recompiling.

The vectors are blocked 1-D; each grid step streams one VMEM-resident block
of w/g/m — the TPU analog of the paper's "112 thread blocks keeping multiple
read/write requests in flight" (IBMGpu kernels, §7.3).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Perf (EXPERIMENTS.md §Perf): one grid step per call whenever the vector
# fits (interpret-mode grid steps cost ~2 ms each on CPU-PJRT); on a real
# TPU the 1M-f32 block (4 MiB x 3 streams = 12 MiB VMEM) still fits, and
# larger models fall back to the grid. Outputs alias their inputs (w->w',
# m->m') so XLA can update in place.
BLOCK = 1 << 20


def _sgd_kernel(h_ref, w_ref, g_ref, m_ref, w_out, m_out):
    lr, mu, wd, rescale = h_ref[0], h_ref[1], h_ref[2], h_ref[3]
    g = rescale * g_ref[...] + wd * w_ref[...]
    m_new = mu * m_ref[...] + g
    m_out[...] = m_new
    w_out[...] = w_ref[...] - lr * m_new


def sgd_update(w, g, m, hyper, *, block=BLOCK):
    """Fused momentum-SGD step on flat f32 vectors.

    Args:
      w, g, m: f32[n] parameters, gradients, momentum buffer.
      hyper:   f32[4] = (lr, mu, wd, rescale).
    Returns:
      (w_new, m_new), both f32[n].
    """
    (n,) = w.shape
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        w = jnp.pad(w, (0, pad))
        g = jnp.pad(g, (0, pad))
        m = jnp.pad(m, (0, pad))
    np_ = n + pad
    grid = (np_ // blk,)
    w_new, m_new = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),  # hyper broadcast to all steps
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        input_output_aliases={1: 0, 3: 1},  # w -> w', m -> m'
        interpret=True,
    )(hyper, w, g, m)
    return w_new[:n], m_new[:n]
