"""L1 Pallas kernel: tensor reduction — sum a *group of vectors*.

This is the compute core of the paper's tensor collectives (§6.1/§7.3):
the per-node "tensor" is the group of per-GPU vectors treated as one
object, and the IBMGpu kernel reduces them into host memory at 30 GB/s by
keeping many read/write requests in flight (112 thread blocks x 1024
threads). The TPU adaptation streams (k, BLOCK) tiles through VMEM and
reduces over the k (vector-group) axis per tile — grid parallelism over
the flat length replaces CUDA thread blocks (DESIGN.md
§Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Single grid step for paper-scale vectors (see sgd_update.py).
BLOCK = 1 << 20


def _reduce_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], axis=0)


def tensor_reduce(stacked, *, block=BLOCK):
    """Sum k stacked vectors: f32[k, n] -> f32[n]."""
    k, n = stacked.shape
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    np_ = n + pad
    grid = (np_ // blk,)
    out = pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(stacked)
    return out[:n]


def _axpy_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def reduce_pair(x, y, *, block=BLOCK):
    """Elementwise x + y on flat f32 vectors — one ring-step reduction."""
    (n,) = x.shape
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    np_ = n + pad
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    out = pl.pallas_call(
        _axpy_kernel,
        grid=(np_ // blk,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(x, y)
    return out[:n]
