"""AOT pipeline: lower L2/L1 to HLO **text** + metadata for the Rust runtime.

Python runs exactly once, at build time (``make artifacts``); the Rust
coordinator is self-contained afterwards. Interchange is HLO *text*, not a
serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects, while the text parser
reassigns ids (see /opt/xla-example/README.md).

Per model variant we emit:

    {name}_grad.hlo.txt     (params, x, y) -> (loss, grads)
    {name}_eval.hlo.txt     (params, x, y) -> (loss, n_correct)
    {name}_init.bin         f32 LE initial flat parameters
    sgd_{n}.hlo.txt         (hyper[4], w, g, m) -> (w', m')
    elastic1_{n}.hlo.txt    (alpha[1], center, w) -> center'
    elastic2_{n}.hlo.txt    (alpha[1], w, center) -> w'
    elastic_fused_{n}.hlo.txt (alpha[1], w, center) -> (w', center')
    tensor_reduce_{k}x{n}.hlo.txt  f32[k, n] -> f32[n]

plus ``meta.json`` describing shapes, per-layer segments (KVStore keys) and
artifact filenames.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import kernels as K


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, fname: str, text: str) -> str:
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    return fname


def lower_variant(cfg, out_dir: str, tensor_ks=(2, 4)) -> dict:
    grad_step, eval_step, segs, x_spec, y_spec = M.make_model(cfg)
    n = M.total_size(segs)
    p_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    name = cfg.name

    arts = {}
    arts["grad"] = _write(
        out_dir, f"{name}_grad.hlo.txt",
        to_hlo_text(jax.jit(grad_step).lower(p_spec, x_spec, y_spec)),
    )
    arts["eval"] = _write(
        out_dir, f"{name}_eval.hlo.txt",
        to_hlo_text(jax.jit(eval_step).lower(p_spec, x_spec, y_spec)),
    )

    init = M.init_params(cfg, seed=0)
    init_name = f"{name}_init.bin"
    init.astype("<f4").tofile(os.path.join(out_dir, init_name))
    arts["init"] = init_name

    # Optimizer / collective-math artifacts sized to this parameter count.
    v = jax.ShapeDtypeStruct((n,), jnp.float32)
    h4 = jax.ShapeDtypeStruct((4,), jnp.float32)
    a1 = jax.ShapeDtypeStruct((1,), jnp.float32)

    arts["sgd"] = _write(
        out_dir, f"sgd_{n}.hlo.txt",
        to_hlo_text(jax.jit(lambda h, w, g, m: K.sgd_update(w, g, m, h)).lower(h4, v, v, v)),
    )
    arts["elastic1"] = _write(
        out_dir, f"elastic1_{n}.hlo.txt",
        to_hlo_text(jax.jit(lambda a, c, w: (K.elastic1(c, w, a),)).lower(a1, v, v)),
    )
    arts["elastic2"] = _write(
        out_dir, f"elastic2_{n}.hlo.txt",
        to_hlo_text(jax.jit(lambda a, w, c: (K.elastic2(w, c, a),)).lower(a1, v, v)),
    )
    arts["elastic_fused"] = _write(
        out_dir, f"elastic_fused_{n}.hlo.txt",
        to_hlo_text(jax.jit(lambda a, w, c: K.elastic_fused(w, c, a)).lower(a1, v, v)),
    )
    for k in tensor_ks:
        kv = jax.ShapeDtypeStruct((k, n), jnp.float32)
        arts[f"tensor_reduce{k}"] = _write(
            out_dir, f"tensor_reduce_{k}x{n}.hlo.txt",
            to_hlo_text(jax.jit(lambda s: (K.tensor_reduce(s),)).lower(kv)),
        )

    def spec_json(s):
        return {"shape": list(s.shape), "dtype": str(s.dtype)}

    return {
        "params": n,
        "kind": type(cfg).__name__,
        "config": {k_: v_ for k_, v_ in cfg.__dict__.items()},
        "x": spec_json(x_spec),
        "y": spec_json(y_spec),
        "segments": [
            {"name": s.name, "offset": s.offset, "size": s.size, "shape": list(s.shape)}
            for s in segs
        ],
        "artifacts": arts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="mlp_tiny,mlp,transformer_tiny,transformer",
        help="comma-separated subset of " + ",".join(M.VARIANTS),
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {"variants": {}}
    for vname in args.variants.split(","):
        vname = vname.strip()
        cfg = M.VARIANTS[vname]
        print(f"[aot] lowering {vname} ...", flush=True)
        meta["variants"][vname] = lower_variant(cfg, args.out_dir)

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    sizes = {v: meta["variants"][v]["params"] for v in meta["variants"]}
    print(f"[aot] wrote {meta_path}; param counts: {sizes}")


if __name__ == "__main__":
    main()
