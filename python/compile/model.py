"""L2 JAX models for the MXNET-MPI reproduction (build-time only).

The paper trains ResNet-50 on ImageNet-1K. Substitutions (DESIGN.md §2):

* ``ResidualMLP`` — a residual-block image classifier over synthetic
  Gaussian-mixture "images"; plays ResNet's role in every convergence
  experiment (Figs 11-14, 16).
* ``TransformerLM`` — a small decoder-only LM for the end-to-end driver
  (system-prompt requirement: train a transformer and log the loss curve).

Both models:
* route every dense layer through the L1 Pallas ``matmul`` kernel so the
  paper's compute hot spot lowers into the exported HLO;
* operate on a single **flat f32 parameter vector**. The per-layer
  (per-"key") segment table is exported in ``meta.json`` so the Rust
  KVStore can treat each layer as a separate key, exactly like MXNET's
  per-ndarray keys (§3.2), while the AOT artifacts keep one signature:

      grad_step(params, x, y)  -> (loss, grads)
      eval_step(params, x, y)  -> (loss, n_correct)
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul


# --------------------------------------------------------------------------
# Parameter flattening
# --------------------------------------------------------------------------


@dataclass
class Segment:
    """One KVStore key: a named slice of the flat parameter vector."""

    name: str
    offset: int
    size: int
    shape: Tuple[int, ...]


def build_segments(shapes: List[Tuple[str, Tuple[int, ...]]]) -> List[Segment]:
    segs, off = [], 0
    for name, shape in shapes:
        size = int(np.prod(shape))
        segs.append(Segment(name, off, size, tuple(shape)))
        off += size
    return segs


def total_size(segs: List[Segment]) -> int:
    return segs[-1].offset + segs[-1].size if segs else 0


def unflatten(flat: jnp.ndarray, segs: List[Segment]) -> Dict[str, jnp.ndarray]:
    return {
        s.name: flat[s.offset : s.offset + s.size].reshape(s.shape) for s in segs
    }


# --------------------------------------------------------------------------
# Residual MLP classifier (the "ResNet" stand-in)
# --------------------------------------------------------------------------


@dataclass
class MlpConfig:
    name: str = "mlp"
    input_dim: int = 768  # 16x16x3 synthetic image
    hidden: int = 256
    blocks: int = 2
    classes: int = 16
    batch: int = 64

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        shapes = [
            ("in.w", (self.input_dim, self.hidden)),
            ("in.b", (self.hidden,)),
        ]
        for i in range(self.blocks):
            shapes += [
                (f"block{i}.w1", (self.hidden, self.hidden)),
                (f"block{i}.b1", (self.hidden,)),
                (f"block{i}.w2", (self.hidden, self.hidden)),
                (f"block{i}.b2", (self.hidden,)),
            ]
        shapes += [
            ("head.w", (self.hidden, self.classes)),
            ("head.b", (self.classes,)),
        ]
        return shapes


def mlp_logits(cfg: MlpConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray):
    h = jax.nn.relu(matmul(x, p["in.w"]) + p["in.b"])
    for i in range(cfg.blocks):
        z = jax.nn.relu(matmul(h, p[f"block{i}.w1"]) + p[f"block{i}.b1"])
        z = matmul(z, p[f"block{i}.w2"]) + p[f"block{i}.b2"]
        h = jax.nn.relu(h + z)
    return matmul(h, p["head.w"]) + p["head.b"]


# --------------------------------------------------------------------------
# Transformer LM (end-to-end driver model)
# --------------------------------------------------------------------------


@dataclass
class TransformerConfig:
    name: str = "transformer"
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq: int = 64
    batch: int = 8
    d_ff: int = field(default=0)  # 0 -> 4*d_model

    def __post_init__(self):
        if self.d_ff == 0:
            self.d_ff = 4 * self.d_model

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        d, f = self.d_model, self.d_ff
        shapes = [
            ("embed", (self.vocab, d)),
            ("pos", (self.seq, d)),
        ]
        for i in range(self.n_layers):
            shapes += [
                (f"layer{i}.ln1.scale", (d,)),
                (f"layer{i}.ln1.bias", (d,)),
                (f"layer{i}.qkv", (d, 3 * d)),
                (f"layer{i}.attn_out", (d, d)),
                (f"layer{i}.ln2.scale", (d,)),
                (f"layer{i}.ln2.bias", (d,)),
                (f"layer{i}.ff1", (d, f)),
                (f"layer{i}.ff1_b", (f,)),
                (f"layer{i}.ff2", (f, d)),
                (f"layer{i}.ff2_b", (d,)),
            ]
        shapes += [("lnf.scale", (d,)), ("lnf.bias", (d,))]
        return shapes


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _dense(x, w):
    """Apply a weight matrix to the trailing dim via the Pallas matmul."""
    lead = x.shape[:-1]
    y = matmul(x.reshape(-1, x.shape[-1]), w)
    return y.reshape(*lead, w.shape[-1])


def transformer_logits(cfg: TransformerConfig, p: Dict[str, jnp.ndarray], tokens):
    b, s = tokens.shape
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    x = p["embed"][tokens] + p["pos"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), bool))
    for i in range(cfg.n_layers):
        ln = _layernorm(x, p[f"layer{i}.ln1.scale"], p[f"layer{i}.ln1.bias"])
        qkv = _dense(ln, p[f"layer{i}.qkv"]).reshape(b, s, 3, h, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
        x = x + _dense(o, p[f"layer{i}.attn_out"])
        ln = _layernorm(x, p[f"layer{i}.ln2.scale"], p[f"layer{i}.ln2.bias"])
        ff = jax.nn.gelu(_dense(ln, p[f"layer{i}.ff1"]) + p[f"layer{i}.ff1_b"])
        x = x + _dense(ff, p[f"layer{i}.ff2"]) + p[f"layer{i}.ff2_b"]
    x = _layernorm(x, p["lnf.scale"], p["lnf.bias"])
    # Tied output head: logits = x @ embed^T.
    return _dense(x, p["embed"].T)


# --------------------------------------------------------------------------
# Losses / step functions
# --------------------------------------------------------------------------


def _xent(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_model(cfg):
    """Return (loss_fn(flat, x, y), acc_fn(flat, x, y), segments, x/y specs)."""
    segs = build_segments(cfg.param_shapes())

    if isinstance(cfg, MlpConfig):
        x_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.input_dim), jnp.float32)
        y_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)

        def loss_fn(flat, x, y):
            return _xent(mlp_logits(cfg, unflatten(flat, segs), x), y)

        def correct_fn(flat, x, y):
            logits = mlp_logits(cfg, unflatten(flat, segs), x)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.int32))

    elif isinstance(cfg, TransformerConfig):
        x_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
        y_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

        def loss_fn(flat, x, y):
            return _xent(transformer_logits(cfg, unflatten(flat, segs), x), y)

        def correct_fn(flat, x, y):
            logits = transformer_logits(cfg, unflatten(flat, segs), x)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.int32))

    else:  # pragma: no cover
        raise TypeError(f"unknown config {cfg!r}")

    def grad_step(flat, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
        return loss, grads

    def eval_step(flat, x, y):
        return loss_fn(flat, x, y), correct_fn(flat, x, y)

    return grad_step, eval_step, segs, x_spec, y_spec


def init_params(cfg, seed: int = 0) -> np.ndarray:
    """He-style init over the flat vector (numpy, deterministic)."""
    rng = np.random.default_rng(seed)
    segs = build_segments(cfg.param_shapes())
    flat = np.zeros(total_size(segs), np.float32)
    for s in segs:
        base = s.name.rsplit(".", 1)[-1]
        if base in ("b", "b1", "b2", "bias", "ff1_b", "ff2_b"):
            val = np.zeros(s.shape, np.float32)
        elif base == "scale":
            val = np.ones(s.shape, np.float32)
        elif s.name in ("embed", "pos"):
            val = rng.normal(0, 0.02, s.shape).astype(np.float32)
        else:
            fan_in = s.shape[0]
            val = rng.normal(0, np.sqrt(2.0 / fan_in), s.shape).astype(np.float32)
        flat[s.offset : s.offset + s.size] = val.ravel()
    return flat


# Named model variants exposed to aot.py / tests.
VARIANTS = {
    "mlp_tiny": MlpConfig(name="mlp_tiny", input_dim=64, hidden=32, blocks=1, classes=4, batch=8),
    "mlp": MlpConfig(name="mlp"),
    "transformer_tiny": TransformerConfig(
        name="transformer_tiny", vocab=64, d_model=32, n_heads=2, n_layers=1, seq=16, batch=4
    ),
    "transformer": TransformerConfig(name="transformer"),
}
