//! Fig. 11: ImageNet validation accuracy vs (virtual) time — dist-SGD vs
//! mpi-SGD vs dist-ASGD vs mpi-ASGD on the testbed1 configuration
//! (12 workers, 2 servers; MPI modes group them into 2 clients of 6).
//!
//!     cargo run --release --example fig11_sgd_asgd [epochs]

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let runs = mxnet_mpi::figures::fig11(&root.join("artifacts"), &root.join("results"), epochs)?;
    mxnet_mpi::figures::print_acc_vs_time("Fig 11: dist-vs-MPI SGD optimizations", &runs);
    // Paper shape: mpi-SGD trains significantly faster than dist-SGD and
    // mpi-ASGD faster than dist-ASGD (acc-vs-time dominance).
    let at = |label: &str| runs.iter().find(|r| r.label == label).unwrap();
    for (mpi, dist) in [("mpi-SGD", "dist-SGD"), ("mpi-ASGD", "dist-ASGD")] {
        let (m, d) = (at(mpi), at(dist));
        println!(
            "{mpi}: final acc {:.3} @ {:.0}s | {dist}: final acc {:.3} @ {:.0}s",
            m.final_acc(), m.records.last().unwrap().vtime,
            d.final_acc(), d.records.last().unwrap().vtime
        );
    }
    println!("CSV -> results/fig11_sgd_asgd.csv");
    Ok(())
}
