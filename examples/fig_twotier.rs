//! Two-tier device figure (ISSUE 8): modeled epoch time and per-tier
//! wire bytes, flat vs two-tier reduction, as the per-node device count
//! sweeps k ∈ {1, 2, 4, 8} over the strategy × codec matrix at
//! transformer_tiny scale. The flat arms pay k-way NIC contention; the
//! two-tier schedule reduces the k device buffers on the NVLink-class
//! fabric first, so only 1/k of the flat inter-node bytes cross the NIC.
//!
//!     cargo run --release --example fig_twotier

use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let rows = mxnet_mpi::figures::fig_twotier(Some(&root.join("results")))?;

    let mut t = Table::new(&[
        "strategy",
        "codec",
        "devices",
        "flat epoch_s",
        "two-tier epoch_s",
        "speedup",
        "intra B/node",
        "inter B/node (flat -> two-tier)",
    ]);
    for r in &rows {
        t.row(vec![
            r.strategy.clone(),
            r.codec.clone(),
            r.devices.to_string(),
            format!("{:.4}", r.flat_epoch_s),
            format!("{:.4}", r.two_tier_epoch_s),
            format!("{:.2}x", r.flat_epoch_s / r.two_tier_epoch_s),
            r.two_tier_intra_bytes.to_string(),
            format!("{} -> {}", r.flat_inter_bytes, r.two_tier_inter_bytes),
        ]);
    }
    println!("{}", t.render());
    println!("CSV -> results/fig_twotier.csv");
    Ok(())
}
