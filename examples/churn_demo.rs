//! Churn demo: training that survives — and exploits — worker churn.
//!
//! Part 1 runs the *threaded* stack in pure MPI (`#servers == 0`) with a
//! scripted fault plan: one of the 4 workers is killed mid-run and a
//! replacement joins later. The static launcher would deadlock the moment
//! the dead rank missed its allreduce; the elastic core instead rebuilds
//! the client world at the next membership epoch, survivors renormalize,
//! and the joiner bootstraps by peer broadcast.
//!
//! Part 2 runs the same kill on the *sim* plane for sync-MPI vs the
//! ESGD hybrid, reproducing the paper's §2 argument: the hybrid's loss
//! keeps improving through the churn event while pure sync MPI stalls
//! globally.
//!
//!     cargo run --release --example churn_demo

use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn print_run(run: &mxnet_mpi::metrics::RunResult, time_axis: &str) {
    let mut t = Table::new(&["epoch", time_axis, "train_loss", "val_acc"]);
    for r in &run.records {
        t.row(vec![
            r.epoch.to_string(),
            format!("{:.2}", r.vtime),
            format!("{:.4}", r.train_loss),
            format!("{:.3}", r.val_acc),
        ]);
    }
    println!("{}", t.render());
}

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // --- Part 1: threaded plane, pure MPI, kill + join -------------------
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 1;
    cfg.servers = 0; // pure MPI: the mode a dead rank used to deadlock
    cfg.epochs = 6;
    cfg.samples_per_epoch = 4 * 8 * 8; // 8 batches per worker per epoch
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.lr = 0.1;
    cfg.fault = "kill:3@12,join@30".into();

    println!(
        "churn demo (threaded): {} | {} workers, pure MPI | fault {}",
        cfg.algo.name(),
        cfg.workers,
        cfg.fault
    );
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts.clone())?;
    print_run(&run, "wall_s");
    anyhow::ensure!(
        run.records.len() == cfg.epochs,
        "run did not survive the churn events"
    );
    anyhow::ensure!(run.final_acc() > 0.5, "training failed to beat chance");
    println!("threaded churn OK: survived kill:3@12 and join@30\n");

    // --- Part 2: sim plane, sync-MPI vs ESGD hybrid under one kill -------
    for algo in [Algo::named("mpi-SGD"), Algo::named("mpi-ESGD")] {
        let mut cfg = ExperimentConfig::testbed1(algo);
        cfg.variant = "mlp_tiny".into();
        cfg.workers = 4;
        cfg.clients = 2;
        cfg.servers = 1;
        cfg.epochs = 4;
        cfg.samples_per_epoch = 4 * 4 * 8; // 4 iterations per epoch
        cfg.classes = 4;
        cfg.noise = 1.0;
        cfg.interval = 2;
        cfg.fault = "kill:3@7".into();
        println!(
            "churn demo (sim): {} | kill rank 3 at iter 7 of {}",
            algo.name(),
            4 * cfg.epochs
        );
        let run = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts)?;
        print_run(&run, "virt_s");
    }
    println!("churn demo OK");
    Ok(())
}
