//! Fig. 14: Impact of MPI ESGD — long multi-epoch run of mpi-ESGD vs
//! mpi-SGD (the paper reaches 0.67 validation accuracy, with mpi-ESGD
//! dominating acc-vs-time).
//!
//!     cargo run --release --example fig14_esgd_epochs [epochs]

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let runs = mxnet_mpi::figures::fig14(&root.join("artifacts"), &root.join("results"), epochs)?;
    mxnet_mpi::figures::print_acc_vs_time("Fig 14: Impact of MPI ESGD", &runs);
    println!("CSV -> results/fig14_esgd_epochs.csv");
    Ok(())
}
