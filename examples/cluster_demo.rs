//! Cluster-authority demo (ISSUE 9): two concurrent jobs on one shared
//! node pool, one mid-run arrival, one elastic shrink — replayed for
//! real on threads.
//!
//! Part 1 runs a 4-node pool under elastic allocation: j0 arrives alone
//! with a gang of 2 and grows into the idle half of the pool; j1's
//! mid-run arrival (a 4-wide gang that can only fit the whole pool)
//! queues behind the grown allocation, so at its next epoch boundary j0
//! shrinks back to its gang width and j1 is gang-placed into the hole.
//! The virtual-time authority synthesizes that trajectory as a per-job
//! `join`/`kill` plan, and [`mxnet_mpi::cluster::execute`] then replays
//! both jobs *concurrently* on real threads — each through the ordinary
//! `launch_with` path against its own quorum on one `ClusterScheduler`,
//! every worker running one allreduce per iteration across the churn.
//!
//! Part 2 sweeps job-arrival rate with `fig_cluster` (static vs elastic
//! goodput, the PR's headline figure) and writes `fig_cluster.csv`.
//!
//!     cargo run --release --example cluster_demo

use anyhow::ensure;
use mxnet_mpi::cluster::{allreduce_probe, simulate, AllocPolicy, ArrivalPlan, ClusterSpec};
use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    // --- Part 1: two concurrent jobs, mid-run arrival, elastic shrink ---
    let arrivals = "mpi-SGD:2x6@0,mpi-SGD:4x2@9";
    let mut spec =
        ClusterSpec::with_defaults(4, AllocPolicy::Elastic, ArrivalPlan::parse(arrivals)?);
    spec.iters_per_epoch = 4;
    spec.batch = 8;
    spec.compute_s = 1.0;
    spec.bytes = 1 << 20;
    println!("cluster demo: pool of {} nodes, elastic | arrivals {arrivals}", spec.nodes);

    let (outcome, results) = mxnet_mpi::cluster::execute(&spec, allreduce_probe)?;
    let mut t = Table::new(&["job", "gang", "arrive_s", "admit_s", "finish_s", "widths", "plan"]);
    for j in &outcome.jobs {
        t.row(vec![
            j.name.clone(),
            j.base_workers.to_string(),
            format!("{}", j.arrival_s),
            format!("{:.1}", j.admitted_s),
            format!("{:.1}", j.finished_s),
            j.widths.iter().map(|w| w.to_string()).collect::<Vec<_>>().join(">"),
            if j.fault.is_empty() { "-".into() } else { j.fault.render() },
        ]);
    }
    println!("{}", t.render());

    let j0 = &outcome.jobs[0];
    let joins = j0.fault.n_joins();
    let kills = j0.fault.events.len() - joins;
    ensure!(joins > 0, "j0 never grew into the idle nodes: {}", j0.fault.render());
    ensure!(kills > 0, "j0 never shrank for j1's gang: {}", j0.fault.render());
    ensure!(
        outcome.jobs[1].fault.is_empty(),
        "j1 fills the pool — nothing to synthesize"
    );
    // The threaded replay agrees with the virtual-time trajectory: the
    // gang ranks run every planned iteration and their final allreduce
    // sums the last epoch's world; the joiners account for the rest.
    ensure!(results[0].len() == j0.base_workers + joins, "one result per launched rank");
    let (ran, last) = results[0][0];
    ensure!(ran == j0.iters, "rank 0 ran {ran} of {} iterations", j0.iters);
    let want = *j0.widths.last().expect("non-empty trajectory") as f32;
    ensure!(last == want, "final allreduce {last} != last epoch width {want}");
    ensure!(outcome.audit.double_booked == 0, "a node was double-booked");
    ensure!(
        outcome.audit.alloc_free_min == spec.nodes && outcome.audit.alloc_free_max == spec.nodes,
        "node pool not conserved"
    );
    println!(
        "threaded replay OK: j0 grew (+{joins}) and shrank (-{kills}) around j1's \
         mid-run gang; pool conserved over {} audited events\n",
        outcome.audit.snapshots
    );

    // Single-job sanity on the same pool: static allocation never churns.
    let st = simulate(&ClusterSpec {
        policy: AllocPolicy::Static,
        plan: ArrivalPlan::parse(arrivals)?,
        ..spec.clone()
    })?;
    ensure!(st.jobs.iter().all(|j| j.fault.is_empty()), "static policy synthesized churn");
    ensure!(
        outcome.makespan_s < st.makespan_s,
        "elastic makespan {} not below static {}",
        outcome.makespan_s,
        st.makespan_s
    );
    println!(
        "static {:.1}s vs elastic {:.1}s makespan ({:.2}x goodput)\n",
        st.makespan_s,
        outcome.makespan_s,
        outcome.goodput() / st.goodput()
    );

    // --- Part 2: the arrival-rate sweep (the PR's headline figure) ------
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let rows = mxnet_mpi::figures::fig_cluster(Some(&root.join("results")))?;
    let mut t = Table::new(&["interval_s", "jobs", "pool", "static goodput", "elastic goodput", "gain"]);
    for r in &rows {
        ensure!(
            r.elastic_goodput >= r.static_goodput,
            "elastic lost at interval {}s",
            r.arrival_interval_s
        );
        t.row(vec![
            format!("{}", r.arrival_interval_s),
            r.jobs.to_string(),
            r.pool_nodes.to_string(),
            format!("{:.2}", r.static_goodput),
            format!("{:.2}", r.elastic_goodput),
            format!("{:.2}x", r.elastic_goodput / r.static_goodput),
        ]);
    }
    println!("{}", t.render());
    println!("CSV -> results/fig_cluster.csv");
    println!("cluster demo OK");
    Ok(())
}
