//! Fig. 12: ImageNet average epoch time (virtual seconds) for all six
//! parallelization modes. The paper reports ~6x improvement of the MPI
//! modes over the dist (pure PS) modes.
//!
//!     cargo run --release --example fig12_epoch_time [epochs]

use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let bars = mxnet_mpi::figures::fig12(&root.join("artifacts"), &root.join("results"), epochs)?;
    let mut t = Table::new(&["mode", "avg epoch time (s)"]);
    for (label, s) in &bars {
        t.row(vec![label.clone(), format!("{s:.1}")]);
    }
    println!("== Fig 12: Imagenet Avg Epoch time ==\n{}", t.render());
    let get = |l: &str| bars.iter().find(|(x, _)| x == l).unwrap().1;
    println!(
        "dist-SGD / mpi-SGD epoch-time factor: {:.1}x (paper: ~6x)",
        get("dist-SGD") / get("mpi-SGD")
    );
    println!("CSV -> results/fig12_epoch_time.csv");
    Ok(())
}
