//! End-to-end driver (DESIGN.md: the full-system proof).
//!
//! Trains the decoder-only transformer LM on the synthetic token corpus
//! for a few hundred steps through the *entire* stack — launcher, PS
//! servers, MPI clients, KVStore-MPI over the dependency engine, ring
//! collectives, AOT-compiled JAX+Pallas model via PJRT — in pure-MPI
//! mpi-SGD mode (#servers = 0, the Fig. 15/16 configuration), and logs
//! the loss curve to `results/e2e_loss.csv`.
//!
//!     cargo run --release --example e2e_train [steps]

use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::metrics::{write_runs_csv, Table};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let artifacts = root.join("artifacts");
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // 4 workers in one MPI client, no servers: PushPull == tensor
    // allreduce (§4.2.4). Each epoch below is `steps_per_epoch` batches
    // per worker; validation after each.
    let workers = 4u64;
    let steps_per_epoch = 25u64;
    let epochs = (steps / steps_per_epoch).max(1) as usize;

    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "transformer".into();
    cfg.workers = workers as usize;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = epochs;
    cfg.lr = 0.02;
    cfg.momentum = 0.9; // sync mode: momentum on the exact global gradient

    // batch comes from the compiled variant (8 x seq 64); per epoch:
    cfg.samples_per_epoch = workers * steps_per_epoch * 8;
    cfg.eval_samples = 64;

    println!(
        "e2e: training transformer LM ({} params) for {} steps/worker x {} workers, pure-MPI mpi-SGD",
        470_000, steps, cfg.workers
    );
    let t0 = std::time::Instant::now();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts)?;

    let mut t = Table::new(&["epoch", "steps", "wall_s", "train_loss", "val_loss", "tok_acc"]);
    for r in &run.records {
        t.row(vec![
            r.epoch.to_string(),
            ((r.epoch as u64 + 1) * steps_per_epoch).to_string(),
            format!("{:.1}", r.vtime),
            format!("{:.4}", r.train_loss),
            format!("{:.4}", r.val_loss),
            format!("{:.3}", r.val_acc),
        ]);
    }
    println!("{}", t.render());

    let out = root.join("results/e2e_loss.csv");
    write_runs_csv(&out, &[run.clone()])?;
    println!("loss curve -> {}", out.display());
    println!("total wall time: {:.1?}", t0.elapsed());

    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    println!("train loss: {first:.3} -> {last:.3} (uniform = ln(512) = 6.24)");
    anyhow::ensure!(last < first - 0.5, "loss did not fall substantially");
    println!("e2e OK");
    Ok(())
}
