//! Schema validator for the `BENCH_*.json` trajectory files emitted by
//! `cargo bench --bench kernels`. Accepts schema `mxnet-mpi-bench/v1`
//! (through `BENCH_7.json`), `mxnet-mpi-bench/v2` (`BENCH_8.json`: v1
//! plus the `two_tier` device-tier section), and `mxnet-mpi-bench/v3`
//! (`BENCH_9.json` onward: v2 plus the `cluster` goodput sweep). CI runs
//! this against the freshly-regenerated file and fails the build on any
//! missing section, wrong type, or empty measurement list — and, for
//! v2+, on any `two_tier` row where the inter-node wire bytes are not
//! *exactly* 1/k of the flat schedule's (the ISSUE-8 acceptance gate,
//! checked in integer arithmetic); for v3, additionally on any `cluster`
//! row where the node-pool conservation integers are off (`free +
//! allocated` must equal the pool at every audited event, zero double
//! bookings) or where elastic goodput falls below static — strictly
//! above it at the highest swept arrival rate (the ISSUE-9 gate).
//!
//!     cargo run --release --example check_bench -- ../BENCH_9.json

use anyhow::{bail, ensure, Context, Result};
use mxnet_mpi::jsonlite::{parse_file, Value};
use std::path::Path;

fn req_num(v: &Value, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .with_context(|| format!("{key:?} must be a number"))
}

fn req_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.req(key)?
        .as_str()
        .with_context(|| format!("{key:?} must be a string"))
}

/// Require a non-empty array of objects, each carrying the given string
/// keys and (finite, non-negative) numeric keys.
fn req_rows(doc: &Value, key: &str, strs: &[&str], nums: &[&str]) -> Result<()> {
    let rows = doc
        .req(key)?
        .as_arr()
        .with_context(|| format!("{key:?} must be an array"))?;
    ensure!(!rows.is_empty(), "{key:?} must not be empty");
    for (i, row) in rows.iter().enumerate() {
        for s in strs {
            let sv = req_str(row, s).with_context(|| format!("{key}[{i}]"))?;
            ensure!(!sv.is_empty(), "{key}[{i}].{s} must be non-empty");
        }
        for n in nums {
            let x = req_num(row, n).with_context(|| format!("{key}[{i}]"))?;
            ensure!(x.is_finite() && x >= 0.0, "{key}[{i}].{n} must be finite and >= 0");
        }
    }
    Ok(())
}

/// The v2 `two_tier` section: per-k flat-vs-two-tier epoch seconds and
/// per-tier wire bytes, with the exact-integer 1/k ratio gate.
fn check_two_tier(doc: &Value) -> Result<()> {
    req_rows(
        doc,
        "two_tier",
        &[],
        &[
            "devices",
            "flat_epoch_s",
            "two_tier_epoch_s",
            "flat_intra_wire_bytes",
            "flat_inter_wire_bytes",
            "two_tier_intra_wire_bytes",
            "two_tier_inter_wire_bytes",
        ],
    )?;
    let rows = doc.req("two_tier")?.as_arr().expect("checked by req_rows");
    for (i, row) in rows.iter().enumerate() {
        let k = req_num(row, "devices")? as u64;
        ensure!(k >= 1, "two_tier[{i}].devices must be >= 1");
        // Wire bytes are integer-exact by construction; read them back as
        // u64 so the 1/k gate tolerates no float fuzz.
        let flat_inter = req_num(row, "flat_inter_wire_bytes")? as u64;
        let tt_inter = req_num(row, "two_tier_inter_wire_bytes")? as u64;
        ensure!(
            tt_inter * k == flat_inter,
            "two_tier[{i}]: inter wire bytes not exactly 1/k of flat \
             (k={k}, two-tier {tt_inter} * k != flat {flat_inter})"
        );
        let flat_intra = req_num(row, "flat_intra_wire_bytes")? as u64;
        ensure!(flat_intra == 0, "two_tier[{i}]: flat moves no intra-tier bytes");
        if k >= 2 {
            let flat_s = req_num(row, "flat_epoch_s")?;
            let tt_s = req_num(row, "two_tier_epoch_s")?;
            ensure!(
                tt_s < flat_s,
                "two_tier[{i}]: modeled two-tier epoch {tt_s} not below flat {flat_s} at k={k}"
            );
        }
    }
    Ok(())
}

/// The v3 `cluster` section: static-vs-elastic goodput per arrival rate
/// plus the integer pool-conservation audit.
fn check_cluster(doc: &Value) -> Result<()> {
    req_rows(
        doc,
        "cluster",
        &[],
        &[
            "arrival_interval_s",
            "jobs",
            "pool_nodes",
            "static_makespan_s",
            "elastic_makespan_s",
            "static_goodput",
            "elastic_goodput",
            "total_samples",
            "alloc_free_min",
            "alloc_free_max",
            "double_booked",
        ],
    )?;
    let rows = doc.req("cluster")?.as_arr().expect("checked by req_rows");
    let mut min_interval = f64::INFINITY;
    let mut gain_at_min = f64::NAN;
    for (i, row) in rows.iter().enumerate() {
        // The conservation ledger is integer-exact by construction; no
        // float fuzz tolerated.
        let pool = req_num(row, "pool_nodes")? as u64;
        let fmin = req_num(row, "alloc_free_min")? as u64;
        let fmax = req_num(row, "alloc_free_max")? as u64;
        ensure!(
            fmin == pool && fmax == pool,
            "cluster[{i}]: node pool not conserved — free+allocated ranged \
             {fmin}..={fmax} on a {pool}-node pool"
        );
        let booked = req_num(row, "double_booked")? as u64;
        ensure!(booked == 0, "cluster[{i}]: {booked} double-booked node claims");
        ensure!(req_num(row, "total_samples")? > 0.0, "cluster[{i}]: no useful samples");
        let st = req_num(row, "static_goodput")?;
        let el = req_num(row, "elastic_goodput")?;
        ensure!(
            el >= st,
            "cluster[{i}]: elastic goodput {el} below static {st} — elastic \
             allocation must never lose"
        );
        let interval = req_num(row, "arrival_interval_s")?;
        if interval < min_interval {
            min_interval = interval;
            gain_at_min = el - st;
        }
    }
    ensure!(
        gain_at_min > 0.0,
        "cluster: elastic goodput not strictly above static at the highest \
         arrival rate (interval {min_interval}s)"
    );
    Ok(())
}

fn check(path: &Path) -> Result<&'static str> {
    let doc = parse_file(path).with_context(|| format!("reading {}", path.display()))?;
    let schema = match req_str(&doc, "schema")? {
        "mxnet-mpi-bench/v1" => "mxnet-mpi-bench/v1",
        "mxnet-mpi-bench/v2" => "mxnet-mpi-bench/v2",
        "mxnet-mpi-bench/v3" => "mxnet-mpi-bench/v3",
        other => bail!("unknown schema {other:?} (want mxnet-mpi-bench/v1, /v2, or /v3)"),
    };
    ensure!(req_num(&doc, "issue")? >= 1.0, "issue must be a positive PR number");
    let mode = req_str(&doc, "mode")?;
    ensure!(mode == "full" || mode == "smoke", "mode must be full or smoke, got {mode:?}");
    ensure!(req_num(&doc, "threads")? >= 1.0, "threads must be >= 1");
    req_rows(&doc, "epoch", &["algo"], &["modeled_epoch_s", "wire_mb_per_iter"])?;
    req_rows(&doc, "wire_bytes", &["codec"], &["dense_bytes", "wire_bytes"])?;
    req_rows(
        &doc,
        "kernels_us",
        &["name", "shape"],
        &["naive_us", "tiled_us", "speedup"],
    )?;
    req_rows(&doc, "allreduce_us", &["schedule"], &["bytes", "us"])?;
    req_rows(&doc, "codec_us", &["codec"], &["n", "encode_us", "decode_us"])?;
    if schema == "mxnet-mpi-bench/v2" || schema == "mxnet-mpi-bench/v3" {
        check_two_tier(&doc)?;
    }
    if schema == "mxnet-mpi-bench/v3" {
        check_cluster(&doc)?;
    }
    Ok(schema)
}

fn main() -> Result<()> {
    let arg = match std::env::args().nth(1) {
        Some(a) => a,
        None => bail!("usage: check_bench <BENCH_N.json>"),
    };
    let path = Path::new(&arg);
    let schema = check(path)?;
    println!("{}: ok ({schema})", path.display());
    Ok(())
}
