//! Schema validator for the `BENCH_*.json` trajectory files emitted by
//! `cargo bench --bench kernels` (schema `mxnet-mpi-bench/v1`). CI runs
//! this against the freshly-regenerated `BENCH_7.json` and fails the
//! build on any missing section, wrong type, or empty measurement list.
//!
//!     cargo run --release --example check_bench -- ../BENCH_7.json

use anyhow::{bail, ensure, Context, Result};
use mxnet_mpi::jsonlite::{parse_file, Value};
use std::path::Path;

fn req_num(v: &Value, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .with_context(|| format!("{key:?} must be a number"))
}

fn req_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.req(key)?
        .as_str()
        .with_context(|| format!("{key:?} must be a string"))
}

/// Require a non-empty array of objects, each carrying the given string
/// keys and (finite, non-negative) numeric keys.
fn req_rows(doc: &Value, key: &str, strs: &[&str], nums: &[&str]) -> Result<()> {
    let rows = doc
        .req(key)?
        .as_arr()
        .with_context(|| format!("{key:?} must be an array"))?;
    ensure!(!rows.is_empty(), "{key:?} must not be empty");
    for (i, row) in rows.iter().enumerate() {
        for s in strs {
            let sv = req_str(row, s).with_context(|| format!("{key}[{i}]"))?;
            ensure!(!sv.is_empty(), "{key}[{i}].{s} must be non-empty");
        }
        for n in nums {
            let x = req_num(row, n).with_context(|| format!("{key}[{i}]"))?;
            ensure!(x.is_finite() && x >= 0.0, "{key}[{i}].{n} must be finite and >= 0");
        }
    }
    Ok(())
}

fn check(path: &Path) -> Result<()> {
    let doc = parse_file(path).with_context(|| format!("reading {}", path.display()))?;
    ensure!(
        req_str(&doc, "schema")? == "mxnet-mpi-bench/v1",
        "unknown schema (want mxnet-mpi-bench/v1)"
    );
    ensure!(req_num(&doc, "issue")? >= 1.0, "issue must be a positive PR number");
    let mode = req_str(&doc, "mode")?;
    ensure!(mode == "full" || mode == "smoke", "mode must be full or smoke, got {mode:?}");
    ensure!(req_num(&doc, "threads")? >= 1.0, "threads must be >= 1");
    req_rows(&doc, "epoch", &["algo"], &["modeled_epoch_s", "wire_mb_per_iter"])?;
    req_rows(&doc, "wire_bytes", &["codec"], &["dense_bytes", "wire_bytes"])?;
    req_rows(
        &doc,
        "kernels_us",
        &["name", "shape"],
        &["naive_us", "tiled_us", "speedup"],
    )?;
    req_rows(&doc, "allreduce_us", &["schedule"], &["bytes", "us"])?;
    req_rows(&doc, "codec_us", &["codec"], &["n", "encode_us", "decode_us"])?;
    Ok(())
}

fn main() -> Result<()> {
    let arg = match std::env::args().nth(1) {
        Some(a) => a,
        None => bail!("usage: check_bench <BENCH_N.json>"),
    };
    let path = Path::new(&arg);
    check(path)?;
    println!("{}: ok (mxnet-mpi-bench/v1)", path.display());
    Ok(())
}
