//! Fig. 16: Resnet-50 learning curve in the pure-MPI configuration
//! (#servers = 0, mpi-SGD, testbed2 cost model, doubled learning rate for
//! the larger effective batch — the paper uses 0.5 instead of 0.1 and
//! reaches 0.72 validation accuracy).
//!
//!     cargo run --release --example fig16_learning_curve [epochs]

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let runs = mxnet_mpi::figures::fig16(&root.join("artifacts"), &root.join("results"), epochs)?;
    mxnet_mpi::figures::print_acc_vs_time("Fig 16: Resnet-50 Learning curves (pure MPI)", &runs);
    println!("final accuracy: {:.3}", runs[0].final_acc());
    println!("CSV -> results/fig16_learning_curve.csv");
    Ok(())
}
