//! Compression figure: validation accuracy vs (virtual) time for mpi-SGD
//! under each registered gradient codec — identity (dense), int8
//! (per-bucket quantization + error feedback) and topk (sparsification +
//! error feedback) — on the testbed1 configuration. The codec sweep is
//! registry-derived, so a newly registered codec appears automatically.
//!
//!     cargo run --release --example fig_compress [epochs]

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let runs =
        mxnet_mpi::figures::fig_compress(&root.join("artifacts"), &root.join("results"), epochs)?;
    mxnet_mpi::figures::print_acc_vs_time("Compression: acc vs time per codec", &runs);
    for run in &runs {
        println!(
            "{}: final acc {:.3} @ {:.0}s virtual",
            run.label,
            run.final_acc(),
            run.records.last().map(|r| r.vtime).unwrap_or(0.0)
        );
    }
    println!("CSV -> results/fig_compress.csv");
    Ok(())
}
