//! Fig. 13: KVStore-MPI based SGD optimizations — mpi-ESGD vs dist-ESGD vs
//! mpi-SGD vs mpi-ASGD, validation accuracy vs virtual time. The paper's
//! claim: mpi-ESGD performs best (communication-avoiding lazy sync),
//! dist-ESGD worst despite similar epoch time (12 one-worker clients
//! suffer staleness).
//!
//!     cargo run --release --example fig13_esgd [epochs]

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let runs = mxnet_mpi::figures::fig13(&root.join("artifacts"), &root.join("results"), epochs)?;
    mxnet_mpi::figures::print_acc_vs_time("Fig 13: KVStore-MPI based SGD optimizations", &runs);
    for r in &runs {
        println!("{:>10}: final acc {:.3}, avg epoch {:.1}s", r.label, r.final_acc(), r.avg_epoch_time);
    }
    println!("CSV -> results/fig13_esgd.csv");
    Ok(())
}
