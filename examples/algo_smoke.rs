//! Algorithm × codec smoke matrix: one tiny epoch of EVERY registered
//! strategy under EVERY registered gradient codec (identity / int8 /
//! topk) on BOTH execution planes — both sweeps are registry-derived, so
//! a newly registered algorithm or codec is exercised by CI
//! automatically, with no edits here.
//!
//!     cargo run --release --example algo_smoke

use mxnet_mpi::compress::Codec;
use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    let mut t = Table::new(&[
        "algo",
        "codec",
        "grouping",
        "threaded wall_s",
        "threaded acc",
        "sim virt_s",
        "sim acc",
    ]);
    for algo in Algo::all() {
        for codec in Codec::all() {
            let mut cfg = ExperimentConfig::testbed1(algo);
            cfg.variant = "mlp_tiny".into();
            cfg.workers = 4;
            cfg.clients = if algo.is_mpi() { 2 } else { 4 };
            cfg.servers = 1;
            cfg.epochs = 1;
            cfg.samples_per_epoch = 4 * 4 * 8; // 4 batches per worker
            cfg.classes = 4;
            cfg.noise = 1.0;
            cfg.interval = 2;
            cfg.eval_samples = 64;
            cfg.compression = codec.name().into();
            // Tiny model: keep a meaningful survivor count under topk.
            cfg.topk_ratio = 0.25;

            eprintln!("[smoke] {} [{}] (threaded + sim)...", algo.name(), codec.name());
            let thr = mxnet_mpi::trainer::threaded::train(&cfg, artifacts.clone())?;
            anyhow::ensure!(
                thr.records.len() == cfg.epochs,
                "{} [{}]: threaded produced {} records",
                algo.name(),
                codec.name(),
                thr.records.len()
            );
            let sim = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts)?;
            anyhow::ensure!(
                sim.records.len() == cfg.epochs,
                "{} [{}]: sim produced {} records",
                algo.name(),
                codec.name(),
                sim.records.len()
            );
            for r in thr.records.iter().chain(&sim.records) {
                anyhow::ensure!(
                    r.train_loss.is_finite() && r.val_loss.is_finite(),
                    "{} [{}]: non-finite loss",
                    algo.name(),
                    codec.name()
                );
            }
            t.row(vec![
                algo.name().to_string(),
                codec.name().to_string(),
                algo.grouping().name().to_string(),
                format!("{:.2}", thr.records.last().unwrap().vtime),
                format!("{:.3}", thr.final_acc()),
                format!("{:.1}", sim.records.last().unwrap().vtime),
                format!("{:.3}", sim.final_acc()),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "algo smoke matrix OK ({} algorithms x {} codecs x 2 planes)",
        Algo::all().len(),
        Codec::all().len()
    );
    Ok(())
}
