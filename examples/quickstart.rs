//! Quickstart: the smallest end-to-end use of the framework.
//!
//! Launches a hybrid job — 4 DL workers grouped into 2 MPI clients talking
//! to 1 parameter server — and trains the tiny residual-MLP classifier
//! with synchronous mpi-SGD (Fig. 6 of the paper) on the real threaded
//! stack: dependency engine, KVStore-MPI, ring collectives, PJRT-compiled
//! model. Run `make artifacts` first.
//!
//!     cargo run --release --example quickstart

use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 2;
    cfg.servers = 1;
    cfg.epochs = 6;
    cfg.samples_per_epoch = 4 * 8 * 8; // 8 batches per worker per epoch
    cfg.classes = 4;
    cfg.noise = 1.0; // easy task: the quickstart just proves the plumbing
    cfg.lr = 0.1;

    println!(
        "quickstart: {} | {} workers / {} clients / {} servers | variant {}",
        cfg.algo.name(),
        cfg.workers,
        cfg.clients,
        cfg.servers,
        cfg.variant
    );

    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts)?;

    let mut t = Table::new(&["epoch", "wall_s", "train_loss", "val_acc"]);
    for r in &run.records {
        t.row(vec![
            r.epoch.to_string(),
            format!("{:.2}", r.vtime),
            format!("{:.4}", r.train_loss),
            format!("{:.3}", r.val_acc),
        ]);
    }
    println!("{}", t.render());
    println!("final validation accuracy: {:.3}", run.final_acc());
    anyhow::ensure!(run.final_acc() > 0.5, "training failed to beat chance");
    println!("quickstart OK");
    Ok(())
}
