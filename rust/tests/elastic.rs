//! Elastic-membership integration tests: churn-aware training on both
//! planes, PS-backed checkpoint/restore, and the bitwise restore/join
//! properties (hand-rolled proptest harness, as in `proptests.rs`).

use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::engine::Engine;
use mxnet_mpi::kvstore::{KvType, KvWorker};
use mxnet_mpi::launcher::{launch, JobSpec};
use mxnet_mpi::mpisim::World;
use mxnet_mpi::optimizer::Assign;
use mxnet_mpi::ps::{FaultPlan, ServerGroup, SyncMode};
use mxnet_mpi::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

// ---------------------------------------------------------------------------
// Threaded plane
// ---------------------------------------------------------------------------

#[test]
fn threaded_pure_mpi_survives_kill_mid_run() {
    // The acceptance scenario: pure sync-MPI training with a worker killed
    // mid-run reconfigures at the next membership epoch and finishes (the
    // static launcher would deadlock on the first post-kill allreduce).
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = 4;
    cfg.samples_per_epoch = 4 * 8 * 8; // 8 batches/worker/epoch -> 32 iters
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.fault = "kill:3@10".into();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert_eq!(run.records.len(), cfg.epochs, "worker 0 saw every epoch");
    for r in &run.records {
        assert!(r.train_loss.is_finite());
    }
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(last < first, "loss did not improve through churn: {first} -> {last}");
}

#[test]
fn threaded_esgd_hybrid_trains_through_kill_and_straggle() {
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-ESGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 2;
    cfg.servers = 1;
    cfg.epochs = 4;
    cfg.samples_per_epoch = 4 * 4 * 8; // 4 batches/worker/epoch -> 16 iters
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.interval = 2;
    cfg.fault = "kill:3@5,straggle:1@3x2".into();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert_eq!(run.records.len(), cfg.epochs);
    assert!(run.final_acc() > 0.5, "acc {}", run.final_acc());
}

#[test]
fn threaded_pure_mpi_joiner_bootstraps_by_peer_bcast() {
    // Serverless join: the joiner adopts the survivors' replica via the
    // peer broadcast and the run finishes with full records.
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 2;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = 3;
    cfg.samples_per_epoch = 2 * 8 * 8;
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.fault = "join@8".into();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert_eq!(run.records.len(), cfg.epochs);
    assert!(run.records.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn fault_past_iteration_budget_rejected() {
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 2;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = 1;
    cfg.samples_per_epoch = 2 * 2 * 8; // 2 iterations total
    cfg.fault = "join@1000".into();
    let err = mxnet_mpi::trainer::threaded::train(&cfg, artifacts());
    assert!(err.is_err(), "a join that can never fire must be rejected");
}

/// A joiner admitted through the PS checkpoint ends bitwise identical to
/// the never-left ranks: hand-rolled sync data-parallel loop over the
/// elastic launcher, final replicas compared across all live ranks.
#[test]
fn joiner_bootstraps_bitwise_identical_to_survivors() {
    const N: usize = 16;
    const ITERS: u64 = 6;
    let mut spec = JobSpec::from_algo(Algo::named("mpi-SGD"), 3, 1, 1);
    spec.fault = FaultPlan::parse("join@2").unwrap();
    let out = launch(&spec, |ctx| {
        let hub = ctx.hub.clone().expect("elastic job");
        let (mut epochs_done, mut live, start_iter) = match &ctx.join_view {
            Some(v) => (v.epoch, v.live_workers, v.boundary_iter + 1),
            None => (0, 3usize, 0),
        };
        let mut w: Vec<f32>;
        if ctx.join_view.is_some() {
            // Bootstrap from the blob the master saved at the boundary.
            w = ctx.kv.ckpt_load(0).expect("PS checkpoint present");
        } else {
            w = (0..N).map(|i| (i as f32) * 0.25 - 1.0).collect();
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0; N], true);
                ctx.kv.set_optimizer(|| Box::new(Assign));
            }
        }
        for iter in start_iter..ITERS {
            // Deterministic gradient from the (identical) replica.
            let g: Vec<f32> = w.iter().map(|&x| 0.1 * x + 0.05).collect();
            ctx.kv.push(0, g);
            let agg = ctx.kv.pull(0).wait();
            for (wi, ai) in w.iter_mut().zip(&agg) {
                // The client pre-sums m replicas of identical gradients;
                // renormalize by the live count so replicas stay equal
                // across membership epochs.
                *wi -= 0.2 * ai / live as f32;
            }
            if hub.boundary_iter(epochs_done) == Some(iter) {
                ctx.kv.wait_all();
                if hub.ckpt_master(epochs_done, ctx.client_id) == Some(ctx.ps_rank) {
                    ctx.kv.ckpt_save(0, w.clone());
                }
                let handout = hub.reconfigure(ctx.ps_rank);
                live = handout.view.live_workers;
                epochs_done = handout.view.epoch;
                if let Some(comm) = handout.comm {
                    drop(ctx.kv.replace_comm(comm));
                }
            }
        }
        w
    })
    .unwrap();
    assert_eq!(out.len(), 4);
    let reference = &out[0];
    for (rank, w) in out.iter().enumerate() {
        assert_eq!(
            w, reference,
            "rank {rank} diverged bitwise from the never-left replica"
        );
    }
}

// ---------------------------------------------------------------------------
// Checkpoint/restore bitwise property
// ---------------------------------------------------------------------------

/// One synchronous data-parallel run over the PS with per-iteration
/// checkpointing; `kill` = (rank, iter) destroys that rank's local state
/// right after the iteration and restores it from the PS blob. With
/// `devices = k > 1` each rank produces k per-device gradient buffers and
/// folds them through the local tier ([`KvWorker::local_merge`]) before
/// the wire — the ISSUE-8 churn composition. Returns every rank's final
/// replica.
fn sync_run_with_restore(
    p: usize,
    n: usize,
    iters: u64,
    seed: u64,
    devices: usize,
    kill: Option<(usize, u64)>,
) -> Vec<Vec<f32>> {
    let group = ServerGroup::spawn(1, SyncMode::Sync, 1);
    let c0 = group.client();
    c0.init(0, vec![0.0; n]);
    c0.set_optimizer(|| Box::new(Assign));
    let comms = World::create(p);
    let hs: Vec<_> = comms
        .into_iter()
        .map(|comm| {
            let ps = group.client();
            thread::spawn(move || {
                let rank = comm.rank();
                let engine = Arc::new(Engine::new(1));
                let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), Some(ps));
                let mut rng = Rng::new(seed);
                let mut w: Vec<f32> =
                    (0..n).map(|_| (rng.below(41) as i64 - 20) as f32 / 4.0).collect();
                let mut mom = vec![0.0f32; n];
                for iter in 0..iters {
                    // Deterministic, replica-, rank- and device-dependent
                    // per-device gradients, averaged into the one leader
                    // buffer by the local tier (k = 1 skips the fold).
                    let dev_grads: Vec<Vec<f32>> = (0..devices.max(1))
                        .map(|d| {
                            w.iter()
                                .enumerate()
                                .map(|(i, &x)| {
                                    0.25 * x + ((rank * 31 + d * 13 + i) % 7) as f32
                                        - 3.0
                                })
                                .collect()
                        })
                        .collect();
                    let g = kv.local_merge(dev_grads, 0);
                    kv.push(0, g);
                    let agg = kv.pull(0).wait();
                    for i in 0..n {
                        mom[i] = 0.5 * mom[i] + agg[i] / p as f32;
                        w[i] -= 0.05 * mom[i];
                    }
                    // Master persists the replica through the PS, then a
                    // collective orders the save before any restore load.
                    if rank == 0 {
                        kv.ckpt_save(0, w.clone());
                        kv.ckpt_save(1, mom.clone());
                    }
                    let _ = kv.client_allreduce(vec![0.0]).wait();
                    if kill == Some((rank, iter)) {
                        // Fail-stop + restart: the local replica is
                        // discarded wholesale; the rank bootstraps from
                        // the PS checkpoint blobs.
                        w = kv.ckpt_load(0).expect("params blob");
                        mom = kv.ckpt_load(1).expect("momentum blob");
                    }
                }
                kv.wait_all();
                w
            })
        })
        .collect();
    let out: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    group.shutdown();
    out
}

/// Property (satellite): a kill-at-arbitrary-iter + PS-checkpoint restore
/// of sync SGD is bitwise identical to an uninterrupted run, on every
/// rank's parameters.
#[test]
fn prop_kill_restore_bitwise_equals_uninterrupted() {
    for case in 0..12u64 {
        let mut rng = Rng::new(0xE1A5 ^ case);
        let p = 2 + rng.below(3) as usize;
        let n = 4 + rng.below(12) as usize;
        let iters = 2 + rng.below(6);
        let kill_rank = rng.below(p as u64) as usize;
        let kill_iter = rng.below(iters);
        let baseline = sync_run_with_restore(p, n, iters, case, 1, None);
        let restored =
            sync_run_with_restore(p, n, iters, case, 1, Some((kill_rank, kill_iter)));
        // Sync replicas agree with each other...
        for w in &baseline[1..] {
            assert_eq!(w, &baseline[0], "case {case}: baseline replicas diverged");
        }
        // ...and the restored run is bitwise the uninterrupted run.
        assert_eq!(
            restored, baseline,
            "case {case}: p={p} n={n} iters={iters} kill=({kill_rank},{kill_iter})"
        );
    }
}

/// ISSUE-8 churn satellite: the kill+restore bitwise property composes
/// with the device tier. With k per-device buffers folded by
/// `local_merge` before every wire hop, a rank destroyed mid-run and
/// restored from the PS checkpoint still ends bitwise identical to the
/// uninterrupted run — the local tier keeps no hidden state a restart
/// could lose (identity codec; per-device EF is exercised in kvstore unit
/// tests).
#[test]
fn prop_kill_restore_bitwise_with_device_tier() {
    for devices in [2usize, 4] {
        for case in 0..6u64 {
            let mut rng = Rng::new(0xD0D0 ^ case ^ (devices as u64) << 32);
            let p = 2 + rng.below(3) as usize;
            let n = 4 + rng.below(12) as usize;
            let iters = 2 + rng.below(6);
            let kill_rank = rng.below(p as u64) as usize;
            let kill_iter = rng.below(iters);
            let baseline = sync_run_with_restore(p, n, iters, case, devices, None);
            let restored = sync_run_with_restore(
                p,
                n,
                iters,
                case,
                devices,
                Some((kill_rank, kill_iter)),
            );
            for w in &baseline[1..] {
                assert_eq!(
                    w, &baseline[0],
                    "k={devices} case {case}: baseline replicas diverged"
                );
            }
            assert_eq!(
                restored, baseline,
                "k={devices} case {case}: p={p} n={n} iters={iters} \
                 kill=({kill_rank},{kill_iter})"
            );
        }
    }
}

/// ISSUE-8 churn satellite, threaded plane: a worker killed mid-run while
/// every worker carries a k = 4 device tier reconfigures at the next
/// membership epoch and finishes training — the elastic machinery and the
/// device split compose with no special cases.
#[test]
fn threaded_device_tier_trains_through_kill() {
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.devices = 4; // mlp_tiny batch 8 -> four 2-row device shards
    cfg.epochs = 4;
    cfg.samples_per_epoch = 4 * 8 * 8; // 8 batches/worker/epoch -> 32 iters
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.fault = "kill:3@10".into();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert_eq!(run.records.len(), cfg.epochs, "worker 0 saw every epoch");
    for r in &run.records {
        assert!(r.train_loss.is_finite());
    }
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(last < first, "loss did not improve through churn: {first} -> {last}");
}

// ---------------------------------------------------------------------------
// Sim plane
// ---------------------------------------------------------------------------

fn sim_churn_cfg(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::testbed1(algo);
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 2;
    cfg.servers = 1;
    cfg.epochs = 4;
    cfg.samples_per_epoch = 4 * 4 * 8; // 4 iters/epoch -> 16 iters
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.interval = 2;
    cfg.fault = "kill:3@7".into();
    cfg
}

#[test]
fn sim_sync_mpi_reconfigures_and_stays_deterministic() {
    let cfg = sim_churn_cfg(Algo::named("mpi-SGD"));
    let a = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    let b = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    assert_eq!(a.records.len(), cfg.epochs);
    let mut prev = 0.0;
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.vtime, rb.vtime, "churned sim must stay deterministic");
        assert_eq!(ra.train_loss, rb.train_loss);
        assert!(ra.vtime > prev);
        prev = ra.vtime;
    }
    // The global membership barrier prices a visible stall: the churn
    // epoch (epoch 1, kill at iter 7 of 4/epoch) costs more than the
    // epoch before it on the virtual clock.
    let d0 = a.records[0].vtime;
    let d1 = a.records[1].vtime - a.records[0].vtime;
    assert!(
        d1 > d0 + cfg.cost_params().reconfig_alpha * 0.5,
        "no reconfiguration stall visible: epoch0 {d0}s epoch1 {d1}s"
    );
}

#[test]
fn sim_esgd_hybrid_loss_improves_through_churn() {
    let cfg = sim_churn_cfg(Algo::named("mpi-ESGD"));
    let run = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    assert_eq!(run.records.len(), cfg.epochs);
    // Monotone improvement through the churn event (15% slack for the
    // plateau near convergence).
    for pair in run.records.windows(2) {
        assert!(
            pair[1].train_loss <= pair[0].train_loss * 1.15,
            "loss regressed through churn: {} -> {}",
            pair[0].train_loss,
            pair[1].train_loss
        );
    }
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(last < first);
    assert!(run.final_acc() > 0.5, "acc {}", run.final_acc());
}

#[test]
fn sim_straggler_slows_only_sync_modes_globally() {
    // A 4x straggler on one worker: sync-MPI epoch time inflates by ~the
    // straggle factor (lockstep gates on the slowest member); the ESGD
    // hybrid's *other* client keeps its own pace, so its epoch time grows
    // far less — §2's decoupling argument priced on the virtual clock.
    let run = |algo: Algo, fault: &str| {
        let mut cfg = sim_churn_cfg(algo);
        cfg.fault = fault.into();
        mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts())
            .unwrap()
            .avg_epoch_time
    };
    let sgd_clean = run(Algo::named("mpi-SGD"), "");
    let sgd_straggled = run(Algo::named("mpi-SGD"), "straggle:3@0x4");
    let esgd_clean = run(Algo::named("mpi-ESGD"), "");
    let esgd_straggled = run(Algo::named("mpi-ESGD"), "straggle:3@0x4");
    let sgd_blowup = sgd_straggled / sgd_clean;
    let esgd_blowup = esgd_straggled / esgd_clean;
    assert!(sgd_blowup > 1.5, "sync blowup only {sgd_blowup}");
    assert!(
        esgd_blowup < sgd_blowup,
        "hybrid should degrade more gracefully: esgd {esgd_blowup} vs sgd {sgd_blowup}"
    );
}
