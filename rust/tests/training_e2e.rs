//! End-to-end training: the full stack on the transformer LM, plus
//! virtual-time plane determinism and paper-shape checks.

use mxnet_mpi::config::{Algo, ExperimentConfig};
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn transformer_lm_trains_end_to_end_pure_mpi() {
    // 2 workers, one MPI client, no servers: pushpull == allreduce.
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "transformer_tiny".into();
    cfg.workers = 2;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = 3;
    cfg.samples_per_epoch = 2 * 10 * 4; // 10 batches per worker per epoch
    cfg.lr = 0.4; // plain SGD (no momentum): a small LM needs a hot lr

    cfg.eval_samples = 32;
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    // Uniform loss = ln(64) ~ 4.16; the corpus has ~2 bits of conditional
    // entropy, so the loss must fall measurably within 3 epochs.
    assert!(first > 3.0, "init loss {first}");
    assert!(last < first - 0.3, "loss {first} -> {last}");
}

#[test]
fn sim_plane_is_deterministic() {
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-ESGD"));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 2;
    cfg.servers = 1;
    cfg.epochs = 2;
    cfg.samples_per_epoch = 4 * 4 * 8;
    cfg.classes = 4;
        cfg.noise = 1.0;
    let a = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    let b = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.vtime, rb.vtime);
        assert_eq!(ra.train_loss, rb.train_loss);
        assert_eq!(ra.val_acc, rb.val_acc);
    }
}

#[test]
fn paper_shape_mpi_modes_faster_per_epoch() {
    // Fig. 12 shape at reduced scale: MPI grouping beats pure PS on epoch
    // time for both SGD and ASGD.
    let modes = [
        Algo::named("dist-SGD"),
        Algo::named("mpi-SGD"),
        Algo::named("dist-ASGD"),
        Algo::named("mpi-ASGD"),
    ];
    let runs: Vec<_> = modes
        .into_iter()
        .map(|algo| {
            let mut cfg = ExperimentConfig::testbed1(algo);
            cfg.variant = "mlp_tiny".into();
            cfg.epochs = 1;
            cfg.samples_per_epoch = 12 * 4 * 8;
            cfg.classes = 4;
        cfg.noise = 1.0;
            mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap()
        })
        .collect();
    let t = |i: usize| runs[i].avg_epoch_time;
    assert!(t(1) < t(0) / 2.0, "mpi-SGD {} !<< dist-SGD {}", t(1), t(0));
    assert!(t(3) < t(2) / 2.0, "mpi-ASGD {} !<< dist-ASGD {}", t(3), t(2));
}

#[test]
fn paper_shape_fewer_clients_reduce_staleness() {
    // §2.3 / Fig. 11: grouping async workers into fewer MPI clients
    // reduces parameter staleness — mpi-ASGD (2 clients of 6) must not
    // converge worse than dist-ASGD (12 one-worker clients) at equal
    // epochs.
    let acc = |algo: Algo| {
        let mut cfg = ExperimentConfig::testbed1(algo);
        cfg.variant = "mlp_tiny".into();
        cfg.epochs = 3;
        cfg.samples_per_epoch = 12 * 4 * 8;
        cfg.classes = 4;
        cfg.noise = 1.0;
        cfg.lr = 0.1;
        mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts())
            .unwrap()
            .final_acc()
    };
    let grouped = acc(Algo::named("mpi-ASGD"));
    let scattered = acc(Algo::named("dist-ASGD"));
    assert!(
        grouped >= scattered - 0.02,
        "mpi-ASGD {grouped} trails dist-ASGD {scattered}"
    );
}

#[test]
fn virtual_time_axis_monotone_and_positive() {
    for algo in [Algo::named("dist-ESGD"), Algo::named("mpi-ESGD")] {
        let mut cfg = ExperimentConfig::testbed1(algo);
        cfg.variant = "mlp_tiny".into();
        cfg.epochs = 3;
        cfg.samples_per_epoch = 12 * 2 * 8;
        cfg.classes = 4;
        cfg.noise = 1.0;
        let run = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
        assert_eq!(run.records.len(), 3, "{}", algo.name());
        let mut prev = 0.0;
        for r in &run.records {
            assert!(r.vtime > prev, "{}: vtime not monotone", algo.name());
            prev = r.vtime;
        }
    }
}
