//! Compression-plane integration tests: the identity-codec bitwise
//! regression, end-to-end convergence under lossy codecs with error
//! feedback on both planes, and the wire-byte savings on the virtual
//! clock.

use mxnet_mpi::compress::Codec;
use mxnet_mpi::config::{Algo, ExperimentConfig};
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Small hybrid (PS + MPI clients) config on the tiny MLP.
fn tiny_cfg(algo: &str, epochs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::testbed1(Algo::named(algo));
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = 2;
    cfg.servers = 1;
    cfg.epochs = epochs;
    cfg.samples_per_epoch = 4 * 4 * 8; // 4 batches/worker/epoch
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.interval = 2;
    cfg.eval_samples = 64;
    cfg
}

#[test]
fn identity_codec_is_bitwise_the_pre_compression_sim_plane() {
    // `compression = "identity"` must leave the virtual-time plane on the
    // exact pre-compression code paths: records bitwise-equal to a config
    // that never mentions compression (the default), vtime included.
    let base = tiny_cfg("mpi-SGD", 2);
    let mut explicit = base.clone();
    explicit.compression = "identity".into();
    let a = mxnet_mpi::trainer::sim::simulate(&base, &artifacts()).unwrap();
    let b = mxnet_mpi::trainer::sim::simulate(&explicit, &artifacts()).unwrap();
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.vtime, rb.vtime);
        assert_eq!(ra.train_loss, rb.train_loss);
        assert_eq!(ra.val_loss, rb.val_loss);
        assert_eq!(ra.val_acc, rb.val_acc);
    }
}

#[test]
fn lossy_codecs_converge_within_tolerance_of_dense_sim() {
    // The acceptance criterion: int8/topk with error feedback reach a
    // final accuracy within tolerance of the uncompressed run (sim plane:
    // deterministic, so the comparison is stable run to run).
    let acc = |compression: &str, ratio: f64| {
        let mut cfg = tiny_cfg("mpi-SGD", 4);
        cfg.compression = compression.into();
        cfg.topk_ratio = ratio;
        mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts())
            .unwrap()
            .final_acc()
    };
    let dense = acc("identity", 0.01);
    assert!(dense > 0.4, "dense baseline too weak to compare: {dense}");
    let int8 = acc("int8", 0.01);
    let topk = acc("topk", 0.25);
    assert!(
        int8 >= dense - 0.1,
        "int8 {int8} trails dense {dense} beyond tolerance"
    );
    assert!(
        topk >= dense - 0.2,
        "topk {topk} trails dense {dense} beyond tolerance"
    );
}

#[test]
fn compressed_pushes_shrink_the_virtual_clock() {
    // Same training volume, smaller wire: both lossy codecs finish their
    // epochs in less virtual time than dense (the PS push moves the
    // codec's wire bytes and pays its γ; dense pays full bytes + incast).
    let t = |compression: &str| {
        let mut cfg = tiny_cfg("mpi-SGD", 2);
        cfg.compression = compression.into();
        mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts())
            .unwrap()
            .records
            .last()
            .unwrap()
            .vtime
    };
    let dense = t("identity");
    let int8 = t("int8");
    let topk = t("topk");
    assert!(int8 < dense, "int8 {int8} !< dense {dense}");
    assert!(topk < dense, "topk {topk} !< dense {dense}");
}

#[test]
fn threaded_e2e_transformer_trains_under_int8() {
    // The threaded e2e path (pure MPI, fused buckets through the engine)
    // with int8 + error feedback: loss must fall like the dense run's.
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "transformer_tiny".into();
    cfg.workers = 2;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = 3;
    cfg.samples_per_epoch = 2 * 10 * 4;
    cfg.lr = 0.4;
    cfg.eval_samples = 32;
    cfg.compression = "int8".into();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(first > 3.0, "init loss {first}");
    assert!(last < first - 0.3, "int8 loss {first} -> {last}");
}

#[test]
fn threaded_e2e_transformer_trains_under_topk() {
    // Top-k (25% + error feedback) on the same e2e path: sparser updates,
    // so a slightly looser bound — but the loss must still fall clearly.
    let mut cfg = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
    cfg.variant = "transformer_tiny".into();
    cfg.workers = 2;
    cfg.clients = 1;
    cfg.servers = 0;
    cfg.epochs = 3;
    cfg.samples_per_epoch = 2 * 10 * 4;
    cfg.lr = 0.4;
    cfg.eval_samples = 32;
    cfg.compression = "topk".into();
    cfg.topk_ratio = 0.25;
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(first > 3.0, "init loss {first}");
    assert!(last < first - 0.2, "topk loss {first} -> {last}");
}

#[test]
fn threaded_hybrid_with_servers_trains_compressed() {
    // Compressed pushes through the real PS servers (decode before
    // aggregation) on the threaded stack, per codec.
    for compression in ["int8", "topk"] {
        let mut cfg = tiny_cfg("mpi-SGD", 2);
        cfg.compression = compression.into();
        cfg.topk_ratio = 0.25;
        let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
        assert_eq!(run.records.len(), cfg.epochs, "{compression}");
        for r in &run.records {
            assert!(r.train_loss.is_finite(), "{compression}: non-finite loss");
        }
        let first = run.records.first().unwrap().train_loss;
        let last = run.records.last().unwrap().train_loss;
        assert!(
            last < first,
            "{compression}: loss did not improve ({first} -> {last})"
        );
    }
}

#[test]
fn model_averaging_syncs_stay_dense_under_lossy_codecs() {
    // The averaging family's PS pushes carry model *snapshots* the
    // workers adopt wholesale; they bypass the codec (KvWorker::push_model)
    // on both planes. Under topk this is the difference between training
    // and collapse: a sparsified snapshot would zero ~75% of every
    // replica at each sync.
    let mut cfg = tiny_cfg("local-sgd", 4);
    cfg.compression = "topk".into();
    cfg.topk_ratio = 0.25;
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(last < first, "threaded local-sgd+topk: {first} -> {last}");
    assert!(run.final_acc() > 0.4, "threaded acc {}", run.final_acc());
    // Sim plane mirrors the dense-snapshot rule: lossy local-sgd stays
    // within tolerance of dense local-sgd.
    let acc = |compression: &str| {
        let mut cfg = tiny_cfg("local-sgd", 4);
        cfg.compression = compression.into();
        cfg.topk_ratio = 0.25;
        mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts())
            .unwrap()
            .final_acc()
    };
    let dense = acc("identity");
    let topk = acc("topk");
    assert!(topk >= dense - 0.2, "sim local-sgd topk {topk} vs dense {dense}");
}

#[test]
fn compression_composes_with_elastic_membership() {
    // A kill mid-run under a lossy codec: reconfiguration and error
    // feedback coexist (residuals survive the world swap; the run
    // finishes renormalized with finite losses).
    let mut cfg = tiny_cfg("mpi-SGD", 4);
    cfg.compression = "int8".into();
    cfg.fault = "kill:3@5".into();
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert_eq!(run.records.len(), cfg.epochs);
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(last < first, "loss did not improve through churn: {first} -> {last}");
}

#[test]
fn codec_registry_drives_config_and_figures_sweep() {
    // The registry is the single source of codec names: config parses
    // every registered name, and the fig_compress sweep covers them all.
    for codec in Codec::all() {
        let mut cfg = tiny_cfg("mpi-SGD", 1);
        cfg.compression = codec.name().into();
        let parsed = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(parsed.codec().name(), codec.name());
    }
    assert_eq!(Codec::all().len(), 3);
}
