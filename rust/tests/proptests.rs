//! Property-based tests (own harness: the offline build has no proptest).
//!
//! Each property runs `CASES` random cases from the deterministic
//! SplitMix64 generator; a failing case prints its seed so it can be
//! replayed by fixing the loop index.

use mxnet_mpi::collectives::{chunk_bounds, multi_ring_allreduce, ring_allreduce};
use mxnet_mpi::engine::Engine;
use mxnet_mpi::jsonlite::{self, Value};
use mxnet_mpi::mpisim::{Comm, World};
use mxnet_mpi::util::Rng;
use std::sync::{Arc, Mutex};
use std::thread;

const CASES: u64 = 40;

fn run_world<F, R>(size: usize, f: F) -> Vec<R>
where
    F: Fn(Comm) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    let comms = World::create(size);
    let hs: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let f = f.clone();
            thread::spawn(move || f(c))
        })
        .collect();
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Property: bucket ring allreduce == the naive gather-reduce-bcast
/// allreduce, for random rank counts, lengths and payloads.
#[test]
fn prop_ring_allreduce_equals_naive() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xA11CE ^ case);
        let p = 1 + rng.below(6) as usize;
        let len = rng.below(300) as usize;
        let rings = 1 + rng.below(4) as usize;
        // Integer-valued payloads: f32 sums are exact, so equality is
        // bitwise regardless of reduction order.
        let payload = move |rank: usize| -> Vec<f32> {
            let mut r = Rng::new(case * 1000 + rank as u64);
            (0..len).map(|_| (r.below(201) as i64 - 100) as f32).collect()
        };
        let ring = run_world(p, move |mut c| {
            let mut d = payload(c.rank());
            multi_ring_allreduce(&mut c, &mut d, rings);
            d
        });
        let naive = run_world(p, move |mut c| {
            let mut d = payload(c.rank());
            c.allreduce_naive(&mut d);
            d
        });
        assert_eq!(ring, naive, "case {case} p={p} len={len} rings={rings}");
    }
}

/// Property: repeated collectives on the same comm never cross-talk.
#[test]
fn prop_repeated_collectives_consistent() {
    for case in 0..CASES / 4 {
        let mut rng = Rng::new(0xBEEF ^ case);
        let p = 2 + rng.below(4) as usize;
        let iters = 1 + rng.below(5) as usize;
        let out = run_world(p, move |mut c| {
            let mut acc = Vec::new();
            for i in 0..iters {
                let mut d = vec![(c.rank() + i) as f32; 7];
                ring_allreduce(&mut c, &mut d);
                acc.push(d[0]);
            }
            acc
        });
        for i in 0..iters {
            let expect: f32 = (0..p).map(|r| (r + i) as f32).sum();
            for o in &out {
                assert_eq!(o[i], expect, "case {case} iter {i}");
            }
        }
    }
}

/// Property: chunk_bounds is a partition for any (len, p).
#[test]
fn prop_chunk_bounds_partition() {
    for case in 0..CASES * 10 {
        let mut rng = Rng::new(case);
        let len = rng.below(10_000) as usize;
        let p = 1 + rng.below(64) as usize;
        let mut prev = 0;
        let mut sizes = Vec::new();
        for i in 0..p {
            let (s, e) = chunk_bounds(len, p, i);
            assert_eq!(s, prev);
            assert!(e >= s);
            sizes.push(e - s);
            prev = e;
        }
        assert_eq!(prev, len);
        // Near-equal: max-min <= 1.
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1);
    }
}

/// Property: the engine serializes mutations per var in push order, for
/// random dependency graphs.
#[test]
fn prop_engine_mutation_order_per_var() {
    for case in 0..CASES / 2 {
        let mut rng = Rng::new(0xE16 ^ case);
        let threads = 1 + rng.below(4) as usize;
        let n_vars = 1 + rng.below(6) as usize;
        let n_ops = 50 + rng.below(100) as usize;
        let e = Engine::new(threads);
        let vars: Vec<_> = (0..n_vars).map(|_| e.new_var()).collect();
        let logs: Vec<Arc<Mutex<Vec<usize>>>> =
            (0..n_vars).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
        let mut expected: Vec<Vec<usize>> = vec![Vec::new(); n_vars];
        for op in 0..n_ops {
            let m = rng.below(n_vars as u64) as usize;
            let r = rng.below(n_vars as u64) as usize;
            expected[m].push(op);
            let log = logs[m].clone();
            e.push(move || log.lock().unwrap().push(op), &[vars[r]], &[vars[m]]);
        }
        e.wait_all();
        for v in 0..n_vars {
            assert_eq!(*logs[v].lock().unwrap(), expected[v], "case {case} var {v}");
        }
    }
}

/// Property: jsonlite round-trips random values exactly.
#[test]
fn prop_jsonlite_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 1),
            2 => Value::Num((rng.below(2_000_001) as i64 - 1_000_000) as f64 / 64.0),
            3 => {
                let n = rng.below(12) as usize;
                Value::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Value::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES * 5 {
        let mut rng = Rng::new(0x15A ^ case);
        let v = gen(&mut rng, 3);
        for text in [v.to_json(), v.to_json_pretty()] {
            let back = jsonlite::parse(&text).unwrap_or_else(|e| {
                panic!("case {case}: parse failed: {e}\n{text}")
            });
            assert_eq!(back, v, "case {case}");
        }
    }
}

/// Property: PS sync rounds compute exactly sum-of-pushes regardless of
/// worker interleaving (threads race freely).
#[test]
fn prop_ps_sync_round_exact() {
    use mxnet_mpi::optimizer::{Sgd, SgdHyper};
    use mxnet_mpi::ps::{ServerGroup, SyncMode};
    for case in 0..CASES / 4 {
        let mut rng = Rng::new(0x95 ^ case);
        let workers = 2 + rng.below(5) as usize;
        let rounds = 1 + rng.below(4) as usize;
        let group = ServerGroup::spawn(1 + rng.below(3) as usize, SyncMode::Sync, workers);
        let c0 = group.client();
        c0.init(0, vec![0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let hs: Vec<_> = (0..workers)
            .map(|w| {
                let mut c = group.client();
                thread::spawn(move || {
                    let mut last = 0.0;
                    for _ in 0..rounds {
                        c.push(0, vec![(w + 1) as f32]);
                        last = c.pull(0)[0];
                    }
                    last
                })
            })
            .collect();
        let per_round: f32 = (1..=workers).map(|w| w as f32).sum();
        let finals: Vec<f32> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        // Every worker's final pull reflects at least its own last round
        // and at most the global last round.
        for f in finals {
            assert_eq!(f, -per_round * rounds as f32, "case {case}");
        }
        group.shutdown();
    }
}

/// Property: Gaussian-mixture data is bitwise reproducible and batches
/// agree with per-sample materialization.
#[test]
fn prop_data_batches_match_samples() {
    use mxnet_mpi::data::GaussianMixture;
    for case in 0..CASES {
        let mut rng = Rng::new(0xDA7A ^ case);
        let dim = 1 + rng.below(32) as usize;
        let classes = 1 + rng.below(8) as usize;
        let d = GaussianMixture::new(dim, classes, 0.7, case);
        let start = rng.below(1000);
        let b = d.batch(start, 5);
        for i in 0..5 {
            let mut x = vec![0.0; dim];
            let y = d.sample(start + i as u64, &mut x);
            assert_eq!(&b.x[i * dim..(i + 1) * dim], &x[..], "case {case}");
            assert_eq!(b.y[i], y);
        }
    }
}
