//! Property-based tests (own harness: the offline build has no proptest).
//!
//! Each property runs `CASES` random cases from the deterministic
//! SplitMix64 generator; a failing case prints its seed so it can be
//! replayed by fixing the loop index.

use mxnet_mpi::collectives::{
    chunk_bounds, halving_doubling_allreduce_pipelined, hierarchical_allreduce_pipelined,
    multi_ring_allreduce, multi_ring_allreduce_pipelined, ring_allreduce,
};
use mxnet_mpi::engine::Engine;
use mxnet_mpi::jsonlite::{self, Value};
use mxnet_mpi::mpisim::{Comm, Request, World};
use mxnet_mpi::util::Rng;
use std::sync::{Arc, Mutex};
use std::thread;

const CASES: u64 = 40;

fn run_world<F, R>(size: usize, f: F) -> Vec<R>
where
    F: Fn(Comm) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    let comms = World::create(size);
    let hs: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let f = f.clone();
            thread::spawn(move || f(c))
        })
        .collect();
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Property: bucket ring allreduce == the naive gather-reduce-bcast
/// allreduce, for random rank counts, lengths and payloads.
#[test]
fn prop_ring_allreduce_equals_naive() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xA11CE ^ case);
        let p = 1 + rng.below(6) as usize;
        let len = rng.below(300) as usize;
        let rings = 1 + rng.below(4) as usize;
        // Integer-valued payloads: f32 sums are exact, so equality is
        // bitwise regardless of reduction order.
        let payload = move |rank: usize| -> Vec<f32> {
            let mut r = Rng::new(case * 1000 + rank as u64);
            (0..len).map(|_| (r.below(201) as i64 - 100) as f32).collect()
        };
        let ring = run_world(p, move |mut c| {
            let mut d = payload(c.rank());
            multi_ring_allreduce(&mut c, &mut d, rings);
            d
        });
        let naive = run_world(p, move |mut c| {
            let mut d = payload(c.rank());
            c.allreduce_naive(&mut d);
            d
        });
        assert_eq!(ring, naive, "case {case} p={p} len={len} rings={rings}");
    }
}

/// Property: `wait_any` completes every posted irecv exactly once with the
/// right payload, regardless of the (random) send order — out-of-order
/// completion of the request set.
#[test]
fn prop_wait_any_out_of_order_completion() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x3A17 ^ case);
        let n_msgs = 1 + rng.below(12) as usize;
        // Random send permutation, shared by both ranks via the seed.
        let mut order: Vec<usize> = (0..n_msgs).collect();
        for i in (1..n_msgs).rev() {
            order.swap(i, rng.below(i as u64 + 1) as usize);
        }
        let order = Arc::new(order);
        let ord = order.clone();
        let out = run_world(2, move |mut c| {
            if c.rank() == 0 {
                for &m in ord.iter() {
                    c.send(1, m as u64, vec![m as f32, case as f32]);
                }
                Vec::new()
            } else {
                let mut reqs: Vec<Request> =
                    (0..n_msgs).map(|m| c.irecv(0, m as u64)).collect();
                let mut tags: Vec<usize> = (0..n_msgs).collect();
                let mut got = vec![None; n_msgs];
                while !reqs.is_empty() {
                    let (i, data) = c.wait_any(&mut reqs);
                    let tag = tags.remove(i);
                    assert!(got[tag].is_none(), "case {case}: tag {tag} completed twice");
                    got[tag] = Some(data);
                }
                got.into_iter().map(Option::unwrap).collect()
            }
        });
        for (m, data) in out[1].iter().enumerate() {
            assert_eq!(data[..], [m as f32, case as f32], "case {case} msg {m}");
        }
    }
}

/// Property: (source, tag) matching under interleaved irecvs — random
/// posting order across two senders and several tags, random send
/// interleave; each (from, tag) stream must match FIFO per posting order.
#[test]
fn prop_tag_matching_interleaved_irecvs() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x7A6 ^ case);
        let tags = 1 + rng.below(4) as u64;
        let per_stream = 1 + rng.below(4) as usize;
        // Receiver posts, per (sender, tag) stream, `per_stream` irecvs in
        // a random global interleave; senders send in index order. The
        // i-th posted irecv of a stream must get the i-th sent payload.
        let mut posts: Vec<(usize, u64)> = Vec::new();
        for from in 0..2usize {
            for t in 0..tags {
                for _ in 0..per_stream {
                    posts.push((from, t));
                }
            }
        }
        for i in (1..posts.len()).rev() {
            posts.swap(i, rng.below(i as u64 + 1) as usize);
        }
        let posts = Arc::new(posts);
        let ps = posts.clone();
        let out = run_world(3, move |mut c| {
            match c.rank() {
                0 | 1 => {
                    let from = c.rank();
                    for t in 0..tags {
                        for i in 0..per_stream {
                            c.send(
                                2,
                                t,
                                vec![from as f32, t as f32, i as f32, case as f32],
                            );
                        }
                    }
                    Vec::new()
                }
                _ => {
                    let mut reqs = Vec::new();
                    let mut meta = Vec::new();
                    let mut seen = std::collections::HashMap::new();
                    for &(from, t) in ps.iter() {
                        let idx = seen.entry((from, t)).or_insert(0usize);
                        reqs.push(c.irecv(from, t));
                        meta.push((from, t, *idx));
                        *idx += 1;
                    }
                    let mut results = Vec::new();
                    while !reqs.is_empty() {
                        let (i, data) = c.wait_any(&mut reqs);
                        let m = meta.remove(i);
                        results.push((m, data));
                    }
                    results
                        .into_iter()
                        .map(|((from, t, idx), data)| {
                            assert_eq!(
                                data[..],
                                [from as f32, t as f32, idx as f32, case as f32],
                                "case {case}: stream ({from},{t}) posting {idx}"
                            );
                            data[0]
                        })
                        .collect()
                }
            }
        });
        assert_eq!(out[2].len(), 2 * tags as usize * per_stream);
    }
}

/// Property: every chunk-pipelined schedule equals the blocking ring
/// bitwise on adversarial shapes — empty buffers, 1 element, lengths below
/// the rank count, odd lengths, non-power-of-two worlds — across random
/// pipeline depths.
#[test]
fn prop_pipelined_schedules_match_blocking_ring_bitwise() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x9199 ^ case);
        let p = [1usize, 2, 3, 4, 5, 7, 8][rng.below(7) as usize];
        let len = [0usize, 1, p.saturating_sub(1), 2, 17, 257, 1031][rng.below(7) as usize];
        let chunks = 1 + rng.below(8) as usize;
        let group = 1 + rng.below(4) as usize;
        let rings = 1 + rng.below(3) as usize;
        let payload = move |rank: usize| -> Vec<f32> {
            let mut r = Rng::new(case * 7919 + rank as u64);
            (0..len).map(|_| (r.below(201) as i64 - 100) as f32).collect()
        };
        let want = run_world(p, move |mut c| {
            let mut d = payload(c.rank());
            ring_allreduce(&mut c, &mut d); // blocking baseline
            d
        });
        for algo in 0..3usize {
            let out = run_world(p, move |mut c| {
                let mut d = payload(c.rank());
                match algo {
                    0 => multi_ring_allreduce_pipelined(&mut c, &mut d, rings, chunks),
                    1 => halving_doubling_allreduce_pipelined(&mut c, &mut d, chunks),
                    _ => hierarchical_allreduce_pipelined(&mut c, &mut d, group, chunks),
                }
                d
            });
            for (r, d) in out.iter().enumerate() {
                assert_eq!(
                    d[..],
                    want[r][..],
                    "case {case} algo {algo} p={p} len={len} chunks={chunks}"
                );
            }
        }
    }
}

/// Property: repeated collectives on the same comm never cross-talk.
#[test]
fn prop_repeated_collectives_consistent() {
    for case in 0..CASES / 4 {
        let mut rng = Rng::new(0xBEEF ^ case);
        let p = 2 + rng.below(4) as usize;
        let iters = 1 + rng.below(5) as usize;
        let out = run_world(p, move |mut c| {
            let mut acc = Vec::new();
            for i in 0..iters {
                let mut d = vec![(c.rank() + i) as f32; 7];
                ring_allreduce(&mut c, &mut d);
                acc.push(d[0]);
            }
            acc
        });
        for i in 0..iters {
            let expect: f32 = (0..p).map(|r| (r + i) as f32).sum();
            for o in &out {
                assert_eq!(o[i], expect, "case {case} iter {i}");
            }
        }
    }
}

/// Property: chunk_bounds is a partition for any (len, p).
#[test]
fn prop_chunk_bounds_partition() {
    for case in 0..CASES * 10 {
        let mut rng = Rng::new(case);
        let len = rng.below(10_000) as usize;
        let p = 1 + rng.below(64) as usize;
        let mut prev = 0;
        let mut sizes = Vec::new();
        for i in 0..p {
            let (s, e) = chunk_bounds(len, p, i);
            assert_eq!(s, prev);
            assert!(e >= s);
            sizes.push(e - s);
            prev = e;
        }
        assert_eq!(prev, len);
        // Near-equal: max-min <= 1.
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1);
    }
}

/// Property: the engine serializes mutations per var in push order, for
/// random dependency graphs.
#[test]
fn prop_engine_mutation_order_per_var() {
    for case in 0..CASES / 2 {
        let mut rng = Rng::new(0xE16 ^ case);
        let threads = 1 + rng.below(4) as usize;
        let n_vars = 1 + rng.below(6) as usize;
        let n_ops = 50 + rng.below(100) as usize;
        let e = Engine::new(threads);
        let vars: Vec<_> = (0..n_vars).map(|_| e.new_var()).collect();
        let logs: Vec<Arc<Mutex<Vec<usize>>>> =
            (0..n_vars).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
        let mut expected: Vec<Vec<usize>> = vec![Vec::new(); n_vars];
        for op in 0..n_ops {
            let m = rng.below(n_vars as u64) as usize;
            let r = rng.below(n_vars as u64) as usize;
            expected[m].push(op);
            let log = logs[m].clone();
            e.push(move || log.lock().unwrap().push(op), &[vars[r]], &[vars[m]]);
        }
        e.wait_all();
        for v in 0..n_vars {
            assert_eq!(*logs[v].lock().unwrap(), expected[v], "case {case} var {v}");
        }
    }
}

/// Property: jsonlite round-trips random values exactly.
#[test]
fn prop_jsonlite_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 1),
            2 => Value::Num((rng.below(2_000_001) as i64 - 1_000_000) as f64 / 64.0),
            3 => {
                let n = rng.below(12) as usize;
                Value::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Value::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES * 5 {
        let mut rng = Rng::new(0x15A ^ case);
        let v = gen(&mut rng, 3);
        for text in [v.to_json(), v.to_json_pretty()] {
            let back = jsonlite::parse(&text).unwrap_or_else(|e| {
                panic!("case {case}: parse failed: {e}\n{text}")
            });
            assert_eq!(back, v, "case {case}");
        }
    }
}

/// Property: PS sync rounds compute exactly sum-of-pushes regardless of
/// worker interleaving (threads race freely).
#[test]
fn prop_ps_sync_round_exact() {
    use mxnet_mpi::optimizer::{Sgd, SgdHyper};
    use mxnet_mpi::ps::{ServerGroup, SyncMode};
    for case in 0..CASES / 4 {
        let mut rng = Rng::new(0x95 ^ case);
        let workers = 2 + rng.below(5) as usize;
        let rounds = 1 + rng.below(4) as usize;
        let group = ServerGroup::spawn(1 + rng.below(3) as usize, SyncMode::Sync, workers);
        let c0 = group.client();
        c0.init(0, vec![0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let hs: Vec<_> = (0..workers)
            .map(|w| {
                let mut c = group.client();
                thread::spawn(move || {
                    let mut last = 0.0;
                    for _ in 0..rounds {
                        c.push(0, vec![(w + 1) as f32]);
                        last = c.pull(0)[0];
                    }
                    last
                })
            })
            .collect();
        let per_round: f32 = (1..=workers).map(|w| w as f32).sum();
        let finals: Vec<f32> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        // Every worker's final pull reflects at least its own last round
        // and at most the global last round.
        for f in finals {
            assert_eq!(f, -per_round * rounds as f32, "case {case}");
        }
        group.shutdown();
    }
}

/// Property: Gaussian-mixture data is bitwise reproducible and batches
/// agree with per-sample materialization.
#[test]
fn prop_data_batches_match_samples() {
    use mxnet_mpi::data::GaussianMixture;
    for case in 0..CASES {
        let mut rng = Rng::new(0xDA7A ^ case);
        let dim = 1 + rng.below(32) as usize;
        let classes = 1 + rng.below(8) as usize;
        let d = GaussianMixture::new(dim, classes, 0.7, case);
        let start = rng.below(1000);
        let b = d.batch(start, 5);
        for i in 0..5 {
            let mut x = vec![0.0; dim];
            let y = d.sample(start + i as u64, &mut x);
            assert_eq!(&b.x[i * dim..(i + 1) * dim], &x[..], "case {case}");
            assert_eq!(b.y[i], y);
        }
    }
}

/// Property (regression, satellite of the racecheck PR): `wait_any` is
/// fair across posting orders — it drains every posted request exactly
/// once, the returned index always names the request that actually
/// completed, and a request whose sender stays silent until every other
/// payload has been consumed still completes (no starvation, no spin).
/// Pure test: `wait_any` itself is deliberately unmodified.
#[test]
fn prop_wait_any_fair_across_posting_orders() {
    for case in 0..CASES {
        let p = 2 + (case % 4) as usize; // 2..=5 ranks, rank 0 receives
        let per = 1 + (case % 3) as usize; // messages per sender
        // With >= 2 senders, the highest rank holds its sends until told.
        let late = if p > 2 { Some(p - 1) } else { None };
        let results = run_world(p, move |mut c| {
            let rank = c.rank();
            if rank != 0 {
                let mut rng = Rng::new(0xFA1A ^ (case * 131 + rank as u64));
                let mut tags: Vec<u64> = (0..per).map(|j| (rank * 16 + j) as u64).collect();
                for i in (1..tags.len()).rev() {
                    let j = rng.below((i + 1) as u64) as usize;
                    tags.swap(i, j);
                }
                if Some(rank) == late {
                    c.recv(0, 7); // the go-signal: everyone else drained
                }
                for tag in tags {
                    c.send(0, tag, vec![rank as f32, tag as f32]);
                }
                return Vec::new();
            }
            // Rank 0: post one irecv per expected message, in a shuffled
            // order, then drain everything through wait_any.
            let mut rng = Rng::new(0x9A17 ^ case * 7919);
            let mut roster: Vec<(usize, u64)> = (1..p)
                .flat_map(|s| (0..per).map(move |j| (s, (s * 16 + j) as u64)))
                .collect();
            for i in (1..roster.len()).rev() {
                let j = rng.below((i + 1) as u64) as usize;
                roster.swap(i, j);
            }
            let mut reqs: Vec<Request> = Vec::new();
            let mut expect: Vec<(usize, u64)> = Vec::new();
            for &(s, tag) in &roster {
                reqs.push(c.irecv(s, tag));
                expect.push((s, tag));
            }
            let mut got: Vec<(usize, u64)> = Vec::new();
            let late_count = late.map_or(0, |_| per);
            while reqs.len() > late_count {
                let (i, data) = c.wait_any(&mut reqs);
                let (s, tag) = expect.remove(i);
                assert_ne!(
                    Some(s),
                    late,
                    "case {case}: wait_any returned a request whose message was never sent"
                );
                assert_eq!(data, vec![s as f32, tag as f32], "case {case}: index/payload mismatch");
                got.push((s, tag));
            }
            if let Some(ls) = late {
                c.send(ls, 7, Vec::new());
                while !reqs.is_empty() {
                    let (i, data) = c.wait_any(&mut reqs);
                    let (s, tag) = expect.remove(i);
                    assert_eq!(s, ls, "case {case}: only late-sender requests should remain");
                    assert_eq!(data, vec![s as f32, tag as f32], "case {case}");
                    got.push((s, tag));
                }
            }
            got
        });
        let mut got = results.into_iter().next().expect("rank 0 result");
        got.sort_unstable();
        let mut want: Vec<(usize, u64)> = (1..p)
            .flat_map(|s| (0..per).map(move |j| (s, (s * 16 + j) as u64)))
            .collect();
        want.sort_unstable();
        assert_eq!(got, want, "case {case}: every payload exactly once");
    }
}
