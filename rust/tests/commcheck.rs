//! Integration tests for the static communication-schedule verifier
//! (`analysis` / the `commcheck` CLI gate).
//!
//! Property style matches `proptests.rs` (own harness, no proptest crate):
//! each property draws `CASES` random configurations from the
//! deterministic SplitMix64 generator, and a failing case prints enough
//! to replay it by fixing the loop index.

use mxnet_mpi::analysis::{
    check_config, check_engine_plans, mutants, ScheduleId, CHUNK_SWEEP, P_SWEEP,
};
use mxnet_mpi::kvstore::bucket_issue_plan;
use mxnet_mpi::util::Rng;

const CASES: u64 = 40;

/// Property: an arbitrary draw of (schedule, P, chunks) from the swept
/// space verifies clean — no deadlock, tag-window, coverage, or
/// conservation finding on any registered schedule at any swept size.
#[test]
fn prop_random_schedule_config_verifies_clean() {
    let registry = ScheduleId::registry();
    for case in 0..CASES {
        let mut rng = Rng::new(0xC0117C4EC ^ case);
        let id = &registry[rng.below(registry.len() as u64) as usize];
        let p = P_SWEEP[rng.below(P_SWEEP.len() as u64) as usize];
        let chunks = CHUNK_SWEEP[rng.below(CHUNK_SWEEP.len() as u64) as usize];
        let diags = check_config(id, p, chunks);
        assert!(
            diags.is_empty(),
            "case {case}: {} p={p} chunks={chunks} produced findings:\n{}",
            id.name(),
            diags.iter().map(|d| format!("  {d}\n")).collect::<String>()
        );
    }
}

/// The hardest swept corner explicitly: largest non-power-of-two world,
/// deepest pipeline, lossy fused codec path.
#[test]
fn worst_corner_fused_topk_p17_chunks8_is_clean() {
    let id = ScheduleId::FusedBuckets {
        fusion_bytes: 64,
        codec: mxnet_mpi::compress::Codec::named("topk"),
    };
    let diags = check_config(&id, 17, 8);
    assert!(diags.is_empty(), "{:?}", diags.iter().map(|d| d.to_string()).collect::<Vec<_>>());
}

/// Every seeded mutant — drop-send, shift-tag (in and out of family),
/// truncate-chunk, leak-request — must be caught with one of its expected
/// diagnostic classes. A verifier that misses a planted bug is worse than
/// no verifier.
#[test]
fn every_seeded_mutant_is_caught_with_expected_class() {
    let outcomes = mutants::run_mutant_suite();
    assert_eq!(outcomes.len(), 6, "seeded suite shrank");
    for o in &outcomes {
        assert!(
            o.caught,
            "mutant {} escaped: expected one of {:?}, found {:?}",
            o.label, o.expected, o.found
        );
        assert!(!o.found.is_empty(), "mutant {} produced no diagnostics at all", o.label);
    }
}

/// The engine-plan analyses (coverage, determinism, issue order) pass on
/// the real `bucket_issue_plan` over the curated case matrix.
#[test]
fn engine_plans_verify_clean() {
    let report = check_engine_plans();
    assert!(report.configs_checked > 0);
    assert!(
        report.ok(),
        "{}",
        report.diagnostics.iter().map(|d| format!("{d}\n")).collect::<String>()
    );
}

/// Property: for arbitrary key lengths and fusion caps, the bucket issue
/// plan covers every key exactly once, with disjoint in-order ranges
/// issued back to front (the §4.2 deadlock rule requires every rank to
/// derive this identical order).
#[test]
fn prop_bucket_issue_plan_covers_exactly_once_in_reverse() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xB7C4E7 ^ case);
        let n = 1 + rng.below(12) as usize;
        let lens: Vec<usize> = (0..n).map(|_| rng.below(64) as usize).collect();
        let fusion_bytes = [0usize, 8, 64, 1 << 20][rng.below(4) as usize];
        let plan = bucket_issue_plan(&lens, fusion_bytes);
        let mut hits = vec![0usize; n];
        for &(i, j) in &plan {
            assert!(i < j && j <= n, "case {case}: malformed bucket ({i}, {j}) of {n}");
            for h in &mut hits[i..j] {
                *h += 1;
            }
        }
        assert!(
            hits.iter().all(|&h| h == 1),
            "case {case}: lens={lens:?} cap={fusion_bytes} hits={hits:?}"
        );
        for w in plan.windows(2) {
            assert!(
                w[1].1 <= w[0].0,
                "case {case}: buckets issued out of back-to-front order: {plan:?}"
            );
        }
        // Determinism: recomputation yields the identical plan.
        assert_eq!(plan, bucket_issue_plan(&lens, fusion_bytes), "case {case}");
    }
}
