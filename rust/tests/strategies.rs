//! The SyncStrategy API's contract tests: cross-plane bitwise equivalence
//! for every registered synchronous strategy, the communication-avoiding
//! behaviour of the new algorithms (BMUF, Local SGD), elastic
//! compatibility through the trait-declared sync boundaries, and the
//! registry-derived documentation invariants.
//!
//! Hand-rolled proptest harness (no proptest crate offline), as in
//! `proptests.rs`: each property runs random cases from the deterministic
//! SplitMix64 generator; a failing case prints its parameters.

use mxnet_mpi::config::{Algo, ExperimentConfig, Grouping};
use mxnet_mpi::util::Rng;
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A tiny config on the mlp_tiny variant (batch 8): `bpw` batches per
/// worker per epoch.
fn tiny(algo: Algo, workers: usize, clients: usize, servers: usize, bpw: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::testbed1(algo);
    cfg.variant = "mlp_tiny".into();
    cfg.workers = workers;
    cfg.clients = clients;
    cfg.servers = servers;
    cfg.samples_per_epoch = workers as u64 * bpw * 8;
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.eval_samples = 32;
    cfg
}

/// Job shapes whose aggregation fan-ins are all <= 2 summands, so every
/// f32 fold on the threaded plane (arrival-order PS sums, ring reductions)
/// is order-independent bitwise — the domain on which the cross-plane
/// property is exact rather than approximate.
fn shapes_for(algo: Algo) -> Vec<(usize, usize, usize)> {
    match algo.grouping() {
        // Dist: one worker per client (the framework forces clients ==
        // workers), two workers, hybrid PS.
        Grouping::Dist => vec![(2, 2, 1), (2, 2, 2)],
        Grouping::Mpi => {
            let mut v = vec![(2, 1, 1), (4, 2, 1), (4, 2, 2)];
            if algo == Algo::named("mpi-SGD") {
                // Pure MPI (PushPull == allreduce) only exists for the
                // gradient-aggregation strategy; the model-averaging
                // family stores its global model on the PS.
                v.push((2, 1, 0));
            }
            v
        }
    }
}

/// Property (satellite): for every registered *synchronous* strategy, the
/// sim plane and the threaded plane produce bitwise-identical weight
/// trajectories from the same seed/config. Until this refactor the
/// invariant was only claimed in doc comments; now it is the load-bearing
/// proof that both planes run the same algorithm through one
/// `SyncStrategy` object.
#[test]
fn prop_sync_strategies_bitwise_identical_across_planes() {
    for algo in Algo::all() {
        if !algo.strategy().synchronous() {
            continue;
        }
        let shapes = shapes_for(algo);
        for case in 0..6u64 {
            let mut rng = Rng::new(0x57A7 ^ case ^ (algo.name().len() as u64) << 8);
            let (workers, clients, servers) =
                shapes[rng.below(shapes.len() as u64) as usize];
            let bpw = 2 + rng.below(3); // 2..=4 batches/worker/epoch
            let mut cfg = tiny(algo, workers, clients, servers, bpw);
            cfg.epochs = 1 + rng.below(2) as usize;
            cfg.lr = [0.05f32, 0.1, 0.2][rng.below(3) as usize];
            cfg.momentum = [0.0f32, 0.3][rng.below(2) as usize];
            cfg.interval = 1 + rng.below(3) as usize;
            cfg.warmup_iters = [0usize, 2][rng.below(2) as usize];
            cfg.block_momentum = [0.25f32, 0.5][rng.below(2) as usize];
            cfg.seed = 1000 + case;
            let label = format!(
                "{} case {case}: w={workers} c={clients} s={servers} bpw={bpw} \
                 lr={} mom={} interval={} warmup={}",
                algo.name(),
                cfg.lr,
                cfg.momentum,
                cfg.interval,
                cfg.warmup_iters
            );

            let (t_run, t_w) =
                mxnet_mpi::trainer::threaded::train_with_weights(&cfg, artifacts())
                    .unwrap_or_else(|e| panic!("{label}: threaded failed: {e}"));
            let (s_run, s_w) =
                mxnet_mpi::trainer::sim::simulate_with_weights(&cfg, &artifacts())
                    .unwrap_or_else(|e| panic!("{label}: sim failed: {e}"));

            assert_eq!(t_run.records.len(), s_run.records.len(), "{label}");
            for (tr, sr) in t_run.records.iter().zip(&s_run.records) {
                // Validation metrics are computed from the epoch-end
                // weights by the one shared evaluator: bitwise equality
                // here means the weight *trajectories* agree, epoch by
                // epoch, not just the final state.
                assert_eq!(tr.epoch, sr.epoch, "{label}");
                assert!(
                    tr.val_loss.to_bits() == sr.val_loss.to_bits(),
                    "{label}: epoch {} val_loss {} vs {}",
                    tr.epoch,
                    tr.val_loss,
                    sr.val_loss
                );
                assert!(
                    tr.val_acc.to_bits() == sr.val_acc.to_bits(),
                    "{label}: epoch {} val_acc {} vs {}",
                    tr.epoch,
                    tr.val_acc,
                    sr.val_acc
                );
            }
            assert_eq!(t_w.len(), s_w.len(), "{label}");
            for (i, (a, b)) in t_w.iter().zip(&s_w).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{label}: weight {i} diverged: {a} vs {b}"
                );
            }
        }
    }
}

/// ISSUE-8 satellite: the cross-plane bitwise property must survive the
/// device tier. With `devices = 4` every worker batch is split into four
/// b/4-row shards, each shard's gradient computed separately, and the
/// shards merged by the shared `device_local_merge` fold — on *both*
/// planes, in the same order — so weight trajectories stay bitwise equal
/// for every registered synchronous strategy.
#[test]
fn prop_sync_strategies_bitwise_identical_across_planes_with_devices() {
    for algo in Algo::all() {
        if !algo.strategy().synchronous() {
            continue;
        }
        let shapes = shapes_for(algo);
        for case in 0..2u64 {
            let mut rng = Rng::new(0xDE71CE ^ case ^ (algo.name().len() as u64) << 8);
            let (workers, clients, servers) =
                shapes[rng.below(shapes.len() as u64) as usize];
            let mut cfg = tiny(algo, workers, clients, servers, 2 + rng.below(2));
            cfg.devices = 4; // mlp_tiny batch 8 -> four 2-row device shards
            cfg.epochs = 2;
            cfg.lr = 0.1;
            cfg.momentum = [0.0f32, 0.3][rng.below(2) as usize];
            cfg.interval = 1 + rng.below(3) as usize;
            cfg.seed = 4000 + case;
            let label = format!(
                "{} case {case}: w={workers} c={clients} s={servers} devices=4",
                algo.name()
            );

            let (t_run, t_w) =
                mxnet_mpi::trainer::threaded::train_with_weights(&cfg, artifacts())
                    .unwrap_or_else(|e| panic!("{label}: threaded failed: {e}"));
            let (s_run, s_w) =
                mxnet_mpi::trainer::sim::simulate_with_weights(&cfg, &artifacts())
                    .unwrap_or_else(|e| panic!("{label}: sim failed: {e}"));

            assert_eq!(t_run.records.len(), s_run.records.len(), "{label}");
            for (tr, sr) in t_run.records.iter().zip(&s_run.records) {
                assert!(
                    tr.val_loss.to_bits() == sr.val_loss.to_bits(),
                    "{label}: epoch {} val_loss {} vs {}",
                    tr.epoch,
                    tr.val_loss,
                    sr.val_loss
                );
            }
            assert_eq!(t_w.len(), s_w.len(), "{label}");
            for (i, (a, b)) in t_w.iter().zip(&s_w).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{label}: weight {i} diverged: {a} vs {b}"
                );
            }
        }
    }
}

/// One row of MXNet's kvstore-type table (SNIPPETS.md §KVStore), derived
/// from configured state rather than re-hardcoded: `#ex per device` from
/// the device-tier batch split (b/k), `#ex per update` from the
/// strategy's declared §5 mini-batch, and `max delay` from the
/// strategy's synchrony flag.
fn kvstore_table_row(
    cfg: &ExperimentConfig,
) -> (usize, usize, usize, usize, &'static str) {
    let s = cfg.algo.strategy();
    (
        cfg.devices,
        cfg.workers,
        cfg.batch / cfg.devices.max(1),
        s.mini_batch(cfg),
        if s.synchronous() { "0" } else { "inf" },
    )
}

/// ISSUE-8 satellite: reproduce the MXNet two-level-KVStore table
/// (SNIPPETS.md) as assertions against the configured state — for batch
/// b = 8, k = 4 devices, n = 3 workers:
///
/// | kvstore type | #devices | #workers | #ex per device | #ex per update | max delay |
/// |--------------|----------|----------|----------------|----------------|-----------|
/// | `local`      | k        | 1        | b / k          | b              | 0         |
/// | `dist_sync`  | k        | n        | b / k          | b × n          | 0         |
/// | `dist_async` | k        | n        | b / k          | b              | inf       |
///
/// The same table is mirrored in README.md's device-tier section, pinned
/// here so docs and accounting cannot drift.
#[test]
fn kvstore_type_table_matches_mxnet_docs() {
    use mxnet_mpi::kvstore::KvType;
    let (b, k, n) = (8usize, 4usize, 3usize);

    // `local`: one machine, k devices, no PS — the device tier alone.
    let mut local = tiny(Algo::named("mpi-SGD"), 1, 1, 0, 2);
    local.batch = b;
    local.devices = k;
    assert_eq!(kvstore_table_row(&local), (k, 1, b / k, b, "0"));

    // `dist_sync`: n workers, every update aggregates all n batches.
    let mut dist_sync = tiny(Algo::named("dist-SGD"), n, n, 1, 2);
    dist_sync.batch = b;
    dist_sync.devices = k;
    assert_eq!(dist_sync.algo.kv_type(), KvType::DistSync);
    assert_eq!(kvstore_table_row(&dist_sync), (k, n, b / k, b * n, "0"));

    // `dist_async`: n workers, each update is one worker's batch, delay
    // unbounded.
    let mut dist_async = tiny(Algo::named("dist-ASGD"), n, n, 1, 2);
    dist_async.batch = b;
    dist_async.devices = k;
    assert_eq!(dist_async.algo.kv_type(), KvType::DistAsync);
    assert_eq!(kvstore_table_row(&dist_async), (k, n, b / k, b, "inf"));

    // The README mirror: same rows, same columns.
    let readme = std::fs::read_to_string(
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../README.md"),
    )
    .expect("README.md at the repo root");
    for row in [
        "| `local`      | k | 1 | b / k | b     | 0         |",
        "| `dist_sync`  | k | n | b / k | b × n | 0         |",
        "| `dist_async` | k | n | b / k | b     | inf       |",
    ] {
        assert!(readme.contains(row), "README.md kvstore table is missing row {row:?}");
    }
}

/// Both new communication-avoiding strategies learn on both planes with a
/// genuinely lazy sync schedule.
#[test]
fn bmuf_and_local_sgd_learn_on_both_planes() {
    for name in ["bmuf", "local-sgd"] {
        let algo = Algo::named(name);
        let mut cfg = tiny(algo, 4, 2, 1, 6);
        cfg.epochs = 3;
        cfg.interval = 4;
        let sim = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts())
            .unwrap_or_else(|e| panic!("{name} sim failed: {e}"));
        assert_eq!(sim.records.len(), cfg.epochs, "{name}");
        assert!(sim.final_acc() > 0.5, "{name} sim acc {}", sim.final_acc());
        let thr = mxnet_mpi::trainer::threaded::train(&cfg, artifacts())
            .unwrap_or_else(|e| panic!("{name} threaded failed: {e}"));
        assert_eq!(thr.records.len(), cfg.epochs, "{name}");
        assert!(thr.final_acc() > 0.5, "{name} threaded acc {}", thr.final_acc());
    }
}

/// The communication-avoiding claim, priced on the virtual clock: with a
/// lazy interval, Local SGD's epoch time beats synchronous SGD's (which
/// pays a PS round every iteration), and turning the warmup all the way up
/// (averaging every iteration) gives the time back.
#[test]
fn lazy_averaging_avoids_communication_on_the_clock() {
    let base = |algo: &str| {
        let mut cfg = tiny(Algo::named(algo), 4, 2, 1, 4);
        cfg.epochs = 2;
        cfg.interval = 8;
        cfg
    };
    let t_sgd = mxnet_mpi::trainer::sim::simulate(&base("mpi-SGD"), &artifacts())
        .unwrap()
        .avg_epoch_time;
    let t_lazy = mxnet_mpi::trainer::sim::simulate(&base("local-sgd"), &artifacts())
        .unwrap()
        .avg_epoch_time;
    let mut eager = base("local-sgd");
    eager.warmup_iters = 10_000; // warmup never ends: average every iteration
    let t_eager = mxnet_mpi::trainer::sim::simulate(&eager, &artifacts())
        .unwrap()
        .avg_epoch_time;
    assert!(
        t_lazy < t_sgd * 0.7,
        "lazy averaging should beat per-iteration sync: {t_lazy} vs {t_sgd}"
    );
    assert!(
        t_lazy < t_eager,
        "full warmup must cost communication time: lazy {t_lazy} vs eager {t_eager}"
    );
    let t_bmuf = mxnet_mpi::trainer::sim::simulate(&base("bmuf"), &artifacts())
        .unwrap()
        .avg_epoch_time;
    assert!(
        t_bmuf < t_sgd * 0.7,
        "bmuf should avoid communication too: {t_bmuf} vs {t_sgd}"
    );
}

/// The new strategies ride PR 3's elastic membership machinery with no
/// special cases: boundaries come from `SyncStrategy::sync_every`, so a
/// kill mid-run reconfigures at the next averaging boundary and training
/// finishes renormalized — on both planes.
#[test]
fn local_sgd_trains_through_a_kill_on_both_planes() {
    let mut cfg = tiny(Algo::named("local-sgd"), 4, 2, 1, 4);
    cfg.epochs = 4;
    cfg.interval = 2;
    cfg.fault = "kill:3@5".into();
    let thr = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert_eq!(thr.records.len(), cfg.epochs);
    assert!(thr.records.iter().all(|r| r.train_loss.is_finite()));
    let a = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    let b = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    assert_eq!(a.records.len(), cfg.epochs);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.vtime, rb.vtime, "churned local-sgd sim must stay deterministic");
        assert_eq!(ra.train_loss, rb.train_loss);
    }
}

/// BMUF with η = 0 degenerates to plain Local SGD (no warmup): same wire
/// protocol, and the filter `Δ = 0·Δ + (w̄ - G); G += Δ` stores
/// `G + (w̄ - G)` — the average up to one f32 rounding per element, not
/// bitwise (catastrophic-cancellation corner), so this asserts tight
/// approximate equality. Cross-strategy sanity for the shared seam.
#[test]
fn bmuf_eta_zero_matches_local_sgd() {
    let mk = |name: &str| {
        let mut cfg = tiny(Algo::named(name), 4, 2, 1, 3);
        cfg.epochs = 2;
        cfg.interval = 2;
        cfg.block_momentum = 0.0;
        cfg.warmup_iters = 0;
        cfg
    };
    let (_, w_bmuf) =
        mxnet_mpi::trainer::sim::simulate_with_weights(&mk("bmuf"), &artifacts()).unwrap();
    let (_, w_lsgd) =
        mxnet_mpi::trainer::sim::simulate_with_weights(&mk("local-sgd"), &artifacts())
            .unwrap();
    for (i, (a, b)) in w_bmuf.iter().zip(&w_lsgd).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "weight {i}: bmuf(eta=0) {a} !~ local-sgd {b}"
        );
    }
}

/// Serverless runs of the model-averaging family must fail loudly (the
/// global model lives on the PS) rather than silently never syncing.
#[test]
fn model_averaging_without_servers_is_rejected() {
    for name in ["bmuf", "local-sgd"] {
        let mut cfg = tiny(Algo::named(name), 2, 1, 0, 2);
        cfg.epochs = 1;
        assert!(
            mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).is_err(),
            "{name} sim accepted servers=0"
        );
        assert!(
            mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).is_err(),
            "{name} threaded accepted servers=0"
        );
    }
}

/// Doc satellite: the README algorithm table must cover every registered
/// algorithm — derived docs can lag code, this pins them together.
#[test]
fn readme_lists_every_registered_algorithm() {
    let readme = std::fs::read_to_string(
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../README.md"),
    )
    .expect("README.md at the repo root");
    for name in Algo::names() {
        assert!(
            readme.contains(name),
            "README.md algorithm table is missing {name}"
        );
    }
}
