//! Integration tests for the racecheck gate: the library sweep stays
//! clean at integration budgets, the CLI gate passes end to end, and a
//! printed schedule seed round-trips through `--seed` reproducing the
//! diagnostic bit for bit.

use mxnet_mpi::analysis::racecheck::{
    run_mutant_suite, run_racecheck, scenario_names, Budget,
};
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_mxnet-mpi"))
}

#[test]
fn every_scenario_is_clean_under_integration_budget() {
    let budget = Budget { dfs: 96, random: 16, step_cap: 20_000 };
    let report = run_racecheck(&budget, None);
    assert_eq!(report.scenarios, scenario_names().len());
    assert!(report.executions > 0);
    let lines: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(report.ok(), "racecheck found real findings:\n{}", lines.join("\n"));
}

#[test]
fn cli_gate_passes_and_proves_its_mutants() {
    let out = bin()
        .args(["racecheck", "--max-execs", "48"])
        .output()
        .expect("run mxnet-mpi racecheck");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "racecheck gate failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("racecheck: OK"), "missing OK summary:\n{stdout}");
    assert!(stdout.contains("seeded mutants caught"), "missing mutant tally:\n{stdout}");
    assert!(!stdout.contains("ESCAPED"), "a seeded mutant escaped:\n{stdout}");
}

#[test]
fn cli_scenario_filter_scopes_the_sweep() {
    let out = bin()
        .args(["racecheck", "--scenario", "engine-wait-var", "--max-execs", "24"])
        .output()
        .expect("run mxnet-mpi racecheck");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "filtered racecheck failed:\n{stdout}");
    assert!(stdout.contains("1 scenario(s)"), "filter did not scope the sweep:\n{stdout}");

    let out = bin()
        .args(["racecheck", "--scenario", "no-such-scenario"])
        .output()
        .expect("run mxnet-mpi racecheck");
    assert!(!out.status.success(), "an unknown scenario name must be an error");
}

#[test]
fn printed_seed_round_trips_through_cli_bitwise() {
    // Harvest a real diagnostic (and its printed seed) from a seeded
    // mutant, then feed the seed back through the CLI: the replay must
    // exit non-zero and print the byte-identical diagnostic line.
    let outcomes = run_mutant_suite(&Budget::quick());
    let o = outcomes
        .iter()
        .find(|o| o.label == "channel-cycle")
        .expect("channel-cycle mutant registered");
    assert!(o.caught, "channel-cycle mutant escaped the quick budget");
    let diag = o.diag.as_ref().expect("caught mutant carries a diagnostic");
    let expected_line = format!("  FINDING {diag}");

    let out = bin()
        .args(["racecheck", "--seed", &diag.seed])
        .output()
        .expect("run mxnet-mpi racecheck --seed");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        !out.status.success(),
        "replaying a failing seed must exit non-zero:\n{stdout}"
    );
    assert!(
        stdout.lines().any(|l| l == expected_line),
        "replay must reproduce the diagnostic bitwise\nwant: {expected_line}\ngot:\n{stdout}"
    );
}
