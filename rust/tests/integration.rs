//! Cross-module integration: launcher + PS + MPI clients + KVStore +
//! engine + PJRT, exercised through the real threaded trainer on the tiny
//! model, for every §5 algorithm.

use mxnet_mpi::collectives::AlgoKind;
use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::kvstore::{KvType, KvWorker};
use mxnet_mpi::launcher::{launch, JobSpec};
use mxnet_mpi::netsim::CostParams;
use mxnet_mpi::ps::SyncMode;
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tiny_cfg(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::testbed1(algo);
    cfg.variant = "mlp_tiny".into();
    cfg.workers = 4;
    cfg.clients = if algo.is_mpi() { 2 } else { 4 };
    cfg.servers = 1;
    cfg.epochs = 3;
    cfg.samples_per_epoch = 4 * 6 * 8; // 6 batches per worker per epoch
    cfg.classes = 4;
    cfg.noise = 1.0;
    cfg.lr = 0.1;
    cfg.interval = 4;
    cfg
}

#[test]
fn threaded_training_every_registered_algorithm_learns() {
    // Derived from the registry: a newly registered strategy is exercised
    // here (and by the CI smoke matrix) automatically.
    for algo in Algo::all() {
        let cfg = tiny_cfg(algo);
        let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts())
            .unwrap_or_else(|e| panic!("{} failed: {e}", algo.name()));
        assert_eq!(run.records.len(), cfg.epochs, "{}", algo.name());
        let first = run.records.first().unwrap().train_loss;
        let last = run.records.last().unwrap().train_loss;
        // The tiny task saturates fast: either the loss fell or the model
        // is already at high accuracy (async runs are nondeterministic at
        // the noise floor).
        assert!(
            last < first || run.final_acc() > 0.6,
            "{}: no progress ({first} -> {last}, acc {})",
            algo.name(),
            run.final_acc()
        );
        // Async modes are genuinely nondeterministic (real thread
        // interleaving drives staleness); accept a weaker-but-real signal.
        // The lazy-averaging family syncs rarely, so it sits in between.
        let floor = match algo.name() {
            "dist-SGD" | "mpi-SGD" => 0.6,
            "local-sgd" | "bmuf" => 0.45,
            _ => 0.3,
        };
        assert!(
            run.final_acc() > floor,
            "{}: no learning signal (acc {})",
            algo.name(),
            run.final_acc()
        );
    }
}

#[test]
fn threaded_pure_mpi_mode_trains() {
    let mut cfg = tiny_cfg(Algo::named("mpi-SGD"));
    cfg.servers = 0;
    cfg.clients = 1;
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    assert!(run.final_acc() > 0.3);
}

#[test]
fn threaded_training_under_each_collective_schedule() {
    // The collective knob must be trainable end-to-end for every schedule:
    // ring, halving-doubling, hierarchical, and the autotuner.
    for coll in ["ring", "halving_doubling", "hierarchical", "auto"] {
        let mut cfg = tiny_cfg(Algo::named("mpi-SGD"));
        cfg.servers = 0;
        cfg.clients = 1;
        cfg.workers = 4;
        cfg.epochs = 2;
        cfg.collective = coll.into();
        cfg.fusion_bytes = 4096; // force several fused buckets per step
        let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts())
            .unwrap_or_else(|e| panic!("collective {coll} failed: {e}"));
        assert_eq!(run.records.len(), 2, "{coll}");
        assert!(
            run.final_acc() > 0.3,
            "collective {coll}: no learning signal (acc {})",
            run.final_acc()
        );
    }
}

#[test]
fn sync_sgd_is_deterministic_across_runs() {
    // The same job twice must give bit-identical loss curves (sync mode
    // has no nondeterminism despite real threads).
    let cfg = tiny_cfg(Algo::named("mpi-SGD"));
    let a = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let b = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.train_loss, rb.train_loss);
        assert_eq!(ra.val_acc, rb.val_acc);
    }
}

#[test]
fn sim_matches_threaded_numerics_for_sync_sgd() {
    // The virtual-time plane and the threaded plane implement the same
    // synchronous algorithm; with identical configs their *numerics*
    // (losses per epoch) must agree closely (both sum the same 4 worker
    // gradients per iteration; the only difference is f32 reduction
    // order: ring-chunk order vs flat).
    let cfg = tiny_cfg(Algo::named("mpi-SGD"));
    let threaded = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let sim = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts()).unwrap();
    for (a, b) in threaded.records.iter().zip(&sim.records) {
        // train_loss is reported over worker 0's shard (threaded) vs the
        // all-client average (sim) — same trajectory, different batches;
        // validation accuracy is computed from the same global weights
        // and must agree tightly.
        assert!(
            (a.train_loss - b.train_loss).abs() < 0.5,
            "epoch {}: {} vs {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
        assert!((a.val_acc - b.val_acc).abs() < 0.02, "epoch {}", a.epoch);
    }
}

#[test]
fn kvstore_local_roundtrip_through_engine() {
    let engine = std::sync::Arc::new(mxnet_mpi::engine::Engine::new(2));
    let kv = KvWorker::create(KvType::Local, engine, None, None);
    kv.init(0, vec![0.0; 16], true);
    for _ in 0..10 {
        kv.push(0, vec![0.5; 16]);
    }
    let v = kv.pull(0).wait();
    assert!(v.iter().all(|&x| (x - 5.0).abs() < 1e-6));
}

#[test]
fn launcher_runs_many_small_jobs_without_leaking() {
    for _ in 0..5 {
        let spec = JobSpec {
            workers: 4,
            servers: 1,
            clients: 2,
            ktype: KvType::SyncMpi,
            server_mode: SyncMode::Sync,
            engine_threads: 1,
            collective: AlgoKind::Auto,
            fusion_bytes: 1 << 20,
            rings: 2,
            group: 2,
            devices: 1,
            cost: CostParams::testbed1(),
            codec: mxnet_mpi::compress::Codec::identity(),
            topk_ratio: 0.01,
            fault: mxnet_mpi::ps::FaultPlan::none(),
            reconfig_every: 1,
        };
        let out = launch(&spec, |ctx| {
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0; 8], true);
            }
            ctx.kv.push(0, vec![1.0; 8]);
            ctx.kv.pull(0).wait()[0]
        })
        .unwrap();
        assert_eq!(out.len(), 4);
    }
}

#[test]
fn esgd_huge_interval_still_learns_locally() {
    // With a huge INTERVAL the ESGD client never syncs after init; local
    // SGD inside the client must still reduce the loss.
    let mut cfg = tiny_cfg(Algo::named("mpi-ESGD"));
    cfg.interval = 10_000;
    let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts()).unwrap();
    let first = run.records.first().unwrap().train_loss;
    let last = run.records.last().unwrap().train_loss;
    assert!(last < first);
}

#[test]
fn config_json_file_round_trip_drives_trainer() {
    let cfg = tiny_cfg(Algo::named("dist-ASGD"));
    let tmp = std::env::temp_dir().join("mxnetmpi_cfg_test.json");
    std::fs::write(&tmp, cfg.to_json().to_json_pretty()).unwrap();
    let loaded = ExperimentConfig::load(&tmp).unwrap();
    assert_eq!(loaded.algo, Algo::named("dist-ASGD"));
    assert_eq!(loaded.workers, 4);
    let _ = std::fs::remove_file(tmp);
}
