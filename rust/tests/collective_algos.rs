//! Collective-algorithm equivalence suite: every pluggable schedule (and
//! the fused-bucket path) must produce bitwise-identical sums to the ring
//! baseline, for adversarial shapes — empty buffers, single elements,
//! lengths below the rank count, odd lengths, large buffers, and
//! non-power-of-two worlds. Integer-valued payloads keep f32 sums exact,
//! so equality is bitwise regardless of reduction order.

use mxnet_mpi::collectives::{
    allreduce_with, fused_allreduce, ring_allreduce, sim, AlgoKind,
};
use mxnet_mpi::mpisim::{Comm, World};
use mxnet_mpi::netsim::CostParams;
use mxnet_mpi::util::Rng;
use std::thread;

fn run_world<F, R>(size: usize, f: F) -> Vec<R>
where
    F: Fn(Comm) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    let comms = World::create(size);
    let hs: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let f = f.clone();
            thread::spawn(move || f(c))
        })
        .collect();
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Integer payload in [-100, 100], deterministic per (case, rank).
fn payload(case: u64, rank: usize, len: usize) -> Vec<f32> {
    let mut r = Rng::new(case.wrapping_mul(7919) ^ rank as u64);
    (0..len)
        .map(|_| (r.below(201) as i64 - 100) as f32)
        .collect()
}

fn ring_oracle(case: u64, p: usize, len: usize) -> Vec<f32> {
    let out = run_world(p, move |mut c| {
        let mut d = payload(case, c.rank(), len);
        ring_allreduce(&mut c, &mut d);
        d
    });
    for d in &out {
        assert_eq!(d[..], out[0][..], "ring ranks disagree");
    }
    out.into_iter().next().unwrap()
}

#[test]
fn all_algorithms_match_ring_baseline() {
    let params = CostParams::testbed1();
    let mut case = 0u64;
    for p in [1usize, 2, 3, 4, 8] {
        // Sizes: 0, 1, < p, odd, large (prime-ish to exercise remainders).
        for len in [0usize, 1, p.saturating_sub(1), 257, 4113] {
            case += 1;
            let want = ring_oracle(case, p, len);
            for kind in [
                AlgoKind::Ring,
                AlgoKind::HalvingDoubling,
                AlgoKind::Hierarchical,
                AlgoKind::TwoTier,
                AlgoKind::Auto,
            ] {
                let pr = params.clone();
                let out = run_world(p, move |mut c| {
                    let mut d = payload(case, c.rank(), len);
                    allreduce_with(kind, &mut c, &mut d, 2, 2, &pr);
                    d
                });
                for (r, d) in out.iter().enumerate() {
                    assert_eq!(
                        d[..],
                        want[..],
                        "{} p={p} len={len} rank={r}",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn two_tier_matches_ring_at_every_device_and_thread_count() {
    // ISSUE-8 acceptance: the two-tier schedule must be bitwise identical
    // to the flat ring on order-independent payloads at every device count
    // (including k=1, k not dividing p, and k >= p) and every compute
    // thread count. Integer payloads make f32 sums exact, so any
    // reassociation the device tier introduced would show up as a diff.
    let mut case = 5000u64;
    for threads in [1usize, 4] {
        mxnet_mpi::runtime::par::set_threads(threads);
        for p in [2usize, 4, 8] {
            for devices in [1usize, 2, 3, 4, 8] {
                for len in [0usize, 1, 257] {
                    case += 1;
                    let want = ring_oracle(case, p, len);
                    let mut params = CostParams::testbed1();
                    params.devices = devices;
                    params.pipeline_chunks = 3;
                    let pr = params.clone();
                    let out = run_world(p, move |mut c| {
                        let mut d = payload(case, c.rank(), len);
                        allreduce_with(AlgoKind::TwoTier, &mut c, &mut d, 2, 2, &pr);
                        d
                    });
                    for (r, d) in out.iter().enumerate() {
                        assert_eq!(
                            d[..],
                            want[..],
                            "two_tier p={p} k={devices} len={len} threads={threads} rank={r}"
                        );
                    }
                }
            }
        }
    }
    mxnet_mpi::runtime::par::set_threads(0);
}

#[test]
fn randomized_fused_buckets_match_ring_baseline() {
    // Random key layouts (many tiny keys + occasional big ones) fused at
    // random caps must equal the unfused per-key ring results.
    let params = CostParams::testbed1();
    for case in 0..12u64 {
        let mut rng = Rng::new(0xF05E ^ case);
        let p = [1usize, 2, 3, 4, 8][rng.below(5) as usize];
        let n_keys = 1 + rng.below(7) as usize;
        let lens: Vec<usize> = (0..n_keys)
            .map(|_| match rng.below(4) {
                0 => rng.below(4) as usize,          // 0..3 floats
                1 => 1 + rng.below(16) as usize,     // tiny
                2 => 64 + rng.below(512) as usize,   // medium
                _ => 2048 + rng.below(4096) as usize, // large
            })
            .collect();
        let fusion_bytes = [0usize, 64, 1024, 1 << 20][rng.below(4) as usize];
        let kind = [
            AlgoKind::Ring,
            AlgoKind::HalvingDoubling,
            AlgoKind::Hierarchical,
            AlgoKind::TwoTier,
            AlgoKind::Auto,
        ][rng.below(5) as usize];

        let want: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(k, &len)| ring_oracle(case * 100 + k as u64, p, len))
            .collect();

        let lens2 = lens.clone();
        let pr = params.clone();
        let out = run_world(p, move |mut c| {
            let mut bufs: Vec<Vec<f32>> = lens2
                .iter()
                .enumerate()
                .map(|(k, &len)| payload(case * 100 + k as u64, c.rank(), len))
                .collect();
            fused_allreduce(kind, &mut c, &mut bufs, fusion_bytes, 2, 2, &pr);
            bufs
        });
        for bufs in &out {
            for (k, buf) in bufs.iter().enumerate() {
                assert_eq!(
                    buf[..],
                    want[k][..],
                    "case {case} {} p={p} fusion={fusion_bytes} key {k}",
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn pipelined_depths_match_ring_baseline_bitwise() {
    // Every schedule at every pipeline depth — including depths exceeding
    // the chunk size — must equal the blocking (chunks=1) ring bitwise on
    // adversarial shapes: empty, 1 element, below the rank count, odd,
    // non-power-of-two worlds.
    let mut case = 1000u64;
    for p in [1usize, 2, 3, 5, 8] {
        for len in [0usize, 1, p.saturating_sub(1), 257] {
            case += 1;
            let want = ring_oracle(case, p, len);
            for chunks in [1usize, 2, 3, 8, 64] {
                let mut params = CostParams::testbed1();
                params.pipeline_chunks = chunks;
                for kind in AlgoKind::DATA_PATH {
                    let pr = params.clone();
                    let out = run_world(p, move |mut c| {
                        let mut d = payload(case, c.rank(), len);
                        allreduce_with(kind, &mut c, &mut d, 2, 2, &pr);
                        d
                    });
                    for (r, d) in out.iter().enumerate() {
                        assert_eq!(
                            d[..],
                            want[..],
                            "{} p={p} len={len} chunks={chunks} rank={r}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn select_best_crossover_hd_small_ring_large() {
    // The autotuner's acceptance shape: halving-doubling below the α/β
    // crossover, ring above it (§6.2 cost formalism; Shi et al. 1711.05979).
    for params in [CostParams::minsky(), CostParams::testbed1()] {
        let p = 16;
        let (small, _) = sim::select_best(4 << 10, p, &params);
        assert_eq!(small, AlgoKind::HalvingDoubling, "small-message winner");
        let (large, _) = sim::select_best(64 << 20, p, &params);
        assert_eq!(large, AlgoKind::Ring, "large-message winner");
    }
}

#[test]
fn modeled_seconds_cross_exactly_where_select_best_says() {
    let params = CostParams::minsky();
    let p = 16;
    for shift in 10..27 {
        let bytes = 1usize << shift;
        let ring = sim::network_allreduce_seconds(AlgoKind::Ring, p, bytes, &params);
        let hd =
            sim::network_allreduce_seconds(AlgoKind::HalvingDoubling, p, bytes, &params);
        let (best, best_s) = sim::select_best(bytes, p, &params);
        assert!(best_s <= ring && best_s <= hd);
        if best == AlgoKind::Ring {
            assert!(ring <= hd, "select_best says ring but hd is cheaper at {bytes}");
        }
        if best == AlgoKind::HalvingDoubling {
            assert!(hd <= ring, "select_best says hd but ring is cheaper at {bytes}");
        }
    }
}
