//! Perf-plane property suite (ISSUE 7): the parallel/tiled kernels must
//! be *bitwise* equal to the scalar reference at every shape (the
//! determinism contract that keeps the cross-plane equivalence
//! properties independent of the `threads` knob), and the zero-copy
//! fused arena path must be bitwise equal to the allocating copy path
//! for every registered codec while doing zero allocations per push
//! once warm.

use mxnet_mpi::collectives::{
    fused_allreduce_compressed, fused_allreduce_compressed_with_arena, AlgoKind, FusionArena,
};
use mxnet_mpi::compress::{Codec, EfState};
use mxnet_mpi::engine::Engine;
use mxnet_mpi::kvstore::{KvType, KvWorker};
use mxnet_mpi::mpisim::{Comm, World};
use mxnet_mpi::netsim::CostParams;
use mxnet_mpi::runtime::{native, par};
use mxnet_mpi::util::Rng;
use std::sync::Arc;
use std::thread;

fn run_world<F, R>(size: usize, f: F) -> Vec<R>
where
    F: Fn(Comm) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    let comms = World::create(size);
    let hs: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let f = f.clone();
            thread::spawn(move || f(c))
        })
        .collect();
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Non-integer payload: bitwise equality below is meaningful only if
/// reordered f32 summation would actually produce different bits.
fn payload(seed: u64, len: usize) -> Vec<f32> {
    let mut r = Rng::new(seed.wrapping_mul(0x9E37_79B9) | 1);
    (0..len).map(|_| r.normal() as f32 * 0.7).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run `f` once on the scalar path and once with the parallel path
/// forced (4 threads, zero work threshold), and require bitwise
/// identity. Restores the global knobs afterwards; concurrent tests in
/// this binary observing intermediate knob values stay correct because
/// the knobs are bitwise-invisible — which is exactly the property under
/// test.
fn scalar_vs_parallel<T: Fn() -> Vec<f32>>(label: &str, f: T) {
    par::set_threads(1);
    let scalar = f();
    par::set_min_work(0);
    par::set_threads(4);
    let parallel = f();
    par::set_threads(0);
    par::set_min_work(par::DEFAULT_MIN_WORK);
    assert_eq!(bits(&scalar), bits(&parallel), "{label}: parallel != scalar");
}

#[test]
fn parallel_kernels_match_scalar_bitwise_odd_shapes() {
    let shapes = [1usize, 3, 17, 64, 130];
    for &m in &shapes {
        for &k in &shapes {
            for &n in &shapes {
                let x = payload(m as u64 * 31 + k as u64, m * k);
                let w = payload(k as u64 * 37 + n as u64, k * n);
                let dy = payload(m as u64 * 41 + n as u64, m * n);
                let lbl = format!("m={m} k={k} n={n}");
                scalar_vs_parallel(&format!("matmul {lbl}"), || {
                    native::matmul(&x, &w, m, k, n)
                });
                scalar_vs_parallel(&format!("matmul_tn {lbl}"), || {
                    native::matmul_tn(&x, &dy, m, k, n)
                });
                scalar_vs_parallel(&format!("matmul_nt {lbl}"), || {
                    native::matmul_nt(&dy, &w, m, n, k)
                });
            }
        }
    }
}

#[test]
fn parallel_rowwise_kernels_match_scalar_bitwise() {
    for &rows in &[1usize, 3, 17, 130] {
        for &d in &[1usize, 3, 17, 64, 130] {
            let x = payload(rows as u64 * 13 + d as u64, rows * d);
            let dy = payload(rows as u64 * 17 + d as u64, rows * d);
            let scale = payload(d as u64 + 5, d);
            let bias = payload(d as u64 + 9, d);
            let lbl = format!("rows={rows} d={d}");

            scalar_vs_parallel(&format!("ln_fwd {lbl}"), || {
                let (y, xhat, rstd) = native::ln_fwd(&x, &scale, &bias, rows, d);
                [y, xhat, rstd].concat()
            });
            let (_, xhat, rstd) = native::ln_fwd(&x, &scale, &bias, rows, d);
            scalar_vs_parallel(&format!("ln_bwd {lbl}"), || {
                let (dx, ds, db) = native::ln_bwd(&dy, &scale, &xhat, &rstd, rows, d);
                [dx, ds, db].concat()
            });
            scalar_vs_parallel(&format!("col_sum {lbl}"), || native::col_sum(&dy, rows, d));
            scalar_vs_parallel(&format!("add_bias {lbl}"), || {
                let mut y = x.clone();
                native::add_bias(&mut y, &bias, rows, d);
                y
            });
            scalar_vs_parallel(&format!("gelu {lbl}"), || {
                let (y, t) = native::gelu_fwd(&x);
                let dx = native::gelu_bwd(&dy, &x, &t);
                [y, t, dx].concat()
            });
            let labels: Vec<i32> = (0..rows).map(|i| (i % d) as i32).collect();
            scalar_vs_parallel(&format!("softmax_xent {lbl}"), || {
                let (loss, dl, correct) = native::softmax_xent(&x, &labels, rows, d);
                let mut out = dl;
                out.push(loss);
                out.push(correct as f32);
                out
            });
        }
    }
}

#[test]
fn fused_arena_path_matches_copy_path_for_every_codec() {
    for codec_id in Codec::all() {
        let params = CostParams::testbed1();
        let out = run_world(3, move |mut c| {
            let codec = codec_id.build(0.25);
            let mut ef_arena = EfState::new();
            let mut ef_copy = EfState::new();
            let mut arena = FusionArena::new();
            let lens = [5usize, 9, 2, 33, 1];
            let ef_keys: Vec<u64> = (0..lens.len() as u64).collect();
            let mut grows_after_warmup = 0;
            for iter in 0..3u64 {
                let mut bufs_a: Vec<Vec<f32>> = lens
                    .iter()
                    .enumerate()
                    .map(|(k, &l)| payload(iter * 1000 + k as u64 * 10 + c.rank() as u64, l))
                    .collect();
                let mut bufs_b = bufs_a.clone();
                fused_allreduce_compressed_with_arena(
                    AlgoKind::Ring,
                    &mut c,
                    &mut bufs_a,
                    &ef_keys,
                    256,
                    &*codec,
                    &mut ef_arena,
                    2,
                    2,
                    &params,
                    &mut arena,
                );
                fused_allreduce_compressed(
                    AlgoKind::Ring,
                    &mut c,
                    &mut bufs_b,
                    &ef_keys,
                    256,
                    &*codec,
                    &mut ef_copy,
                    2,
                    2,
                    &params,
                );
                for (k, (a, b)) in bufs_a.iter().zip(&bufs_b).enumerate() {
                    assert_eq!(
                        bits(a),
                        bits(b),
                        "codec {} iter {iter} key {k}: arena path != copy path",
                        codec.name()
                    );
                }
                if iter == 0 {
                    grows_after_warmup = arena.grows();
                }
            }
            (arena.grows(), grows_after_warmup)
        });
        for (final_grows, warm_grows) in out {
            assert_eq!(
                final_grows, warm_grows,
                "codec {}: arena grew after warmup",
                codec_id.name()
            );
        }
    }
}

#[test]
fn pushpull_fused_reuses_arena() {
    // The CI allocation gate: after the first fused push sizes the
    // arena, later pushes of the same key layout must not grow it —
    // zero gather allocations per push.
    let outs = run_world(3, |comm| {
        let engine = Arc::new(Engine::new(1));
        let mut kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
        kv.algo = AlgoKind::Ring;
        kv.fusion_bytes = 1 << 20;
        let push = |kv: &KvWorker, round: usize| {
            let keyed: Vec<(usize, Vec<f32>)> = (0..6)
                .map(|k| (k, vec![(round + k + 1) as f32; 7 + k]))
                .collect();
            kv.pushpull_fused(keyed).wait()
        };
        push(&kv, 0);
        let warm = kv.fusion_arena_grows();
        for round in 1..6 {
            push(&kv, round);
        }
        (warm, kv.fusion_arena_grows())
    });
    for (warm, after) in outs {
        assert!(warm >= 1, "first push never sized the arena");
        assert_eq!(warm, after, "fused path allocated per push");
    }
}
