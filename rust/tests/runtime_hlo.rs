//! Integration: the AOT bridge. Loads the real artifacts produced by
//! `make artifacts` and checks numerics against the Python-side oracle
//! semantics (losses finite, gradients descend, kernels match Rust math).

use mxnet_mpi::data::GaussianMixture;
use mxnet_mpi::optimizer::SgdHyper;
use mxnet_mpi::runtime::{Model, ModelMeta, Runtime, XData};
use mxnet_mpi::tensor::max_abs_diff;
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load_tiny() -> Model {
    let rt = Runtime::cpu().expect("pjrt cpu client");
    Model::load(&rt, &artifacts(), "mlp_tiny").expect("load mlp_tiny artifacts")
}

fn tiny_batch(meta: &ModelMeta, seed: u64) -> (XData, Vec<i32>) {
    let batch = meta.batch_size();
    let dim = meta.x_shape[1] as usize;
    let data = GaussianMixture::new(dim, 4, 0.5, seed);
    let b = data.batch(seed * 100, batch);
    (XData::F32(b.x), b.y)
}

#[test]
fn meta_loads_and_validates() {
    let meta = ModelMeta::load(&artifacts(), "mlp_tiny").unwrap();
    assert_eq!(meta.params, 4324);
    assert_eq!(meta.x_dtype, "float32");
    assert_eq!(meta.segments.total_size(), meta.params);
    assert!(meta.segments.len() >= 4);
    let init = meta.init_params().unwrap();
    assert_eq!(init.len(), meta.params);
    assert!(init.iter().all(|v| v.is_finite()));
}

#[test]
fn unknown_variant_errors() {
    assert!(ModelMeta::load(&artifacts(), "nope").is_err());
}

#[test]
fn grad_step_runs_and_descends() {
    let model = load_tiny();
    let mut params = model.meta.init_params().unwrap();
    let (x, y) = tiny_batch(&model.meta, 1);
    let (loss0, grads) = model.grad_step(&params, &x, &y).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0);
    assert_eq!(grads.len(), params.len());
    // Manual SGD step on the same batch must reduce the loss.
    for (p, g) in params.iter_mut().zip(&grads) {
        *p -= 0.05 * g;
    }
    let (loss1, _) = model.grad_step(&params, &x, &y).unwrap();
    assert!(loss1 < loss0, "{loss1} !< {loss0}");
}

#[test]
fn eval_step_counts_in_range() {
    let model = load_tiny();
    let params = model.meta.init_params().unwrap();
    let (x, y) = tiny_batch(&model.meta, 2);
    let (loss, correct) = model.eval_step(&params, &x, &y).unwrap();
    assert!(loss.is_finite());
    assert!(correct >= 0 && correct <= model.meta.batch_size() as i32);
}

#[test]
fn compiled_sgd_kernel_matches_rust_math() {
    let model = load_tiny();
    let n = model.meta.params;
    let mut w_hlo: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
    let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).cos()).collect();
    let mut m_hlo = vec![0.1f32; n];
    let hyper = SgdHyper { lr: 0.05, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 / 64.0 };

    let mut w_rs = w_hlo.clone();
    let mut m_rs = vec![0.1f32; n];
    for _ in 0..2 {
        model.sgd_update(&mut w_hlo, &g, &mut m_hlo, &hyper).unwrap();
        // Rust reference math (same formula as optimizer::Sgd).
        for i in 0..n {
            let g_eff = hyper.rescale * g[i] + hyper.weight_decay * w_rs[i];
            m_rs[i] = hyper.momentum * m_rs[i] + g_eff;
            w_rs[i] -= hyper.lr * m_rs[i];
        }
    }
    assert!(max_abs_diff(&w_hlo, &w_rs) < 1e-5);
    assert!(max_abs_diff(&m_hlo, &m_rs) < 1e-5);
}

#[test]
fn compiled_elastic_kernels_match_equations() {
    let model = load_tiny();
    let n = model.meta.params;
    let w0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin()).collect();
    let c0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).cos()).collect();
    let alpha = 0.25f32;

    let mut c_hlo = c0.clone();
    model.elastic1(&mut c_hlo, &w0, alpha).unwrap();
    let mut w_hlo = w0.clone();
    model.elastic2(&mut w_hlo, &c0, alpha).unwrap();

    for i in 0..n {
        let c_ref = c0[i] + alpha * (w0[i] - c0[i]);
        let w_ref = w0[i] - alpha * (w0[i] - c0[i]);
        assert!((c_hlo[i] - c_ref).abs() < 1e-6);
        assert!((w_hlo[i] - w_ref).abs() < 1e-6);
    }
}

#[test]
fn model_service_shared_across_threads() {
    use mxnet_mpi::runtime::service::ModelService;
    let svc = ModelService::spawn(artifacts(), "mlp_tiny").unwrap();
    let params = svc.meta.init_params().unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let h = svc.handle();
            let params = params.clone();
            std::thread::spawn(move || {
                let batch = h.meta.batch_size();
                let dim = h.meta.x_shape[1] as usize;
                let data = GaussianMixture::new(dim, 4, 0.5, 7);
                let b = data.batch(i * 64, batch);
                let (loss, grads) = h.grad_step(&params, XData::F32(b.x), b.y).unwrap();
                assert!(loss.is_finite());
                grads.len()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), params.len());
    }
}

#[test]
fn deterministic_grad_same_inputs() {
    let model = load_tiny();
    let params = model.meta.init_params().unwrap();
    let (x, y) = tiny_batch(&model.meta, 3);
    let (l1, g1) = model.grad_step(&params, &x, &y).unwrap();
    let (l2, g2) = model.grad_step(&params, &x, &y).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn transformer_variant_loads_and_runs() {
    use mxnet_mpi::data::TinyCorpus;
    let rt = Runtime::cpu().unwrap();
    let model = Model::load(&rt, &artifacts(), "transformer_tiny").unwrap();
    let meta = &model.meta;
    assert_eq!(meta.x_dtype, "int32");
    let batch = meta.batch_size();
    let seq = meta.x_shape[1] as usize;
    let vocab = 64;
    let corpus = TinyCorpus::new(vocab, 5);
    let (x, y) = corpus.batch_tokens(0, batch, seq);
    let params = meta.init_params().unwrap();
    let (loss, grads) = model.grad_step(&params, &XData::I32(x), &y).unwrap();
    // Near-uniform logits at init: loss ~ ln(vocab).
    assert!((loss - (vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    assert_eq!(grads.len(), params.len());
}
