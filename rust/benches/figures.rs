//! Figure benches: regenerate the paper's collective-performance figures
//! (15, 17–19, 20) and the §7.3 intra-node bandwidth table from the §6
//! cost models, printing paper-style rows and writing CSVs under
//! `results/`.
//!
//!     cargo bench --bench figures

use mxnet_mpi::figures;
use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");

    // --- Figs 17-19: tensor allreduce bandwidth at 4/16/64 MB ----------
    for (fig, mb) in [(17usize, 4usize), (18, 16), (19, 64)] {
        let rows = figures::fig17_19(mb << 20, Some(&out))?;
        let mut t = Table::new(&["design", "workers", "seconds", "GB/s"]);
        for r in &rows {
            t.row(vec![
                r.design_label.clone(),
                r.p.to_string(),
                format!("{:.6}", r.seconds),
                format!("{:.2}", r.gbps),
            ]);
        }
        println!("== Fig {fig}: tensor allreduce @ {mb} MB ==\n{}", t.render());
    }

    // --- Fig 20: IBM ring vs Baidu ring --------------------------------
    let rows = figures::fig20(Some(&out))?;
    let mut t = Table::new(&["message MB", "IBM ring (s)", "Baidu ring (s)", "factor"]);
    for (mb, i, b, f) in &rows {
        t.row(vec![
            mb.to_string(),
            format!("{i:.5}"),
            format!("{b:.5}"),
            format!("{f:.1}x"),
        ]);
    }
    println!("== Fig 20: IBMRing-vs-BaiduRing (32 GPUs) ==\n{}", t.render());

    // --- Fig 15: ResNet-50 scaling --------------------------------------
    let rows = figures::fig15(Some(&out))?;
    let mut t = Table::new(&[
        "nodes",
        "weak ring (s/epoch)",
        "strong ring",
        "weak reg",
        "strong reg",
    ]);
    for (n, w, s, rw, rs) in &rows {
        t.row(vec![
            n.to_string(),
            format!("{w:.0}"),
            format!("{s:.0}"),
            format!("{rw:.0}"),
            format!("{rs:.0}"),
        ]);
    }
    println!("== Fig 15: Resnet-50 Scaling behavior ==\n{}", t.render());

    // --- §7.3 intra-node tensor op bandwidths ---------------------------
    let mut t = Table::new(&["operation", "GB/s (paper §7.3)"]);
    for (name, gbps) in figures::intranode_table() {
        t.row(vec![name.to_string(), format!("{gbps:.1}")]);
    }
    println!("== §7.3 intra-node tensor collectives ==\n{}", t.render());

    // --- Ablations (DESIGN.md design choices) ---------------------------
    ablations(&out)?;

    println!("CSVs -> {}", out.display());
    Ok(())
}

/// Ablation studies over the §6 design knobs: ring count (the Fig. 9
/// multi-ring overlap), the TCP-incast coefficient (the §2.3 hot-spot
/// mechanism) and the PS-transport bandwidth, each swept in isolation.
fn ablations(out: &std::path::PathBuf) -> anyhow::Result<()> {
    use mxnet_mpi::collectives::sim::{simulate, Design};
    use mxnet_mpi::netsim::{CostParams, PsFabric};

    // 1. Ring count: diminishing returns past 2 rings (latency terms grow
    //    linearly while the hidden reduction is already hidden).
    let params = CostParams::minsky();
    let mut t = Table::new(&["rings", "allreduce 64MB p=16 (ms)", "vs 1 ring"]);
    let base = simulate(Design::RingIbm { rings: 1 }, 16, 64 << 20, &params).seconds;
    for rings in [1usize, 2, 4, 8] {
        let s = simulate(Design::RingIbm { rings }, 16, 64 << 20, &params).seconds;
        t.row(vec![
            rings.to_string(),
            format!("{:.3}", s * 1e3),
            format!("{:.2}x", base / s),
        ]);
    }
    println!("== Ablation: multi-ring count ==\n{}", t.render());

    // 2. Incast coefficient: how much of the dist-vs-mpi epoch gap comes
    //    from fan-in collapse vs plain serialization.
    let mut t = Table::new(&["incast", "12-worker push wave (ms)", "vs mpi (2 masters)"]);
    for incast in [0.0f64, 0.25, 0.5, 1.0] {
        let mut p = CostParams::testbed1();
        p.ps_incast = incast;
        let wave = |workers: usize| {
            let mut f = PsFabric::new(2, workers, p.clone());
            let mut last: f64 = 0.0;
            for w in 0..workers {
                last = last.max(f.push(0.0, w, 102 << 20));
            }
            last
        };
        t.row(vec![
            format!("{incast:.2}"),
            format!("{:.0}", wave(12) * 1e3),
            format!("{:.1}x", wave(12) / wave(2)),
        ]);
    }
    println!("== Ablation: PS ingress incast ==\n{}", t.render());

    let mut csv = mxnet_mpi::metrics::Csv::create(
        &out.join("ablation_rings.csv"),
        "rings,seconds",
    )?;
    for rings in [1usize, 2, 4, 8] {
        let s = simulate(Design::RingIbm { rings }, 16, 64 << 20, &params).seconds;
        csv.row(&[rings.to_string(), format!("{s:.6}")])?;
    }
    Ok(())
}
