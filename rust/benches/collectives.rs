//! Micro-benchmarks of the *real* (data-moving) substrates: ring
//! allreduce / tensor allreduce over mpisim, the dependency engine, the
//! PS round path and PJRT kernel dispatch. Wall-clock, own harness (the
//! offline build has no criterion); each measurement reports the median
//! of `REPS` runs after warmup.
//!
//!     cargo bench --bench collectives

use mxnet_mpi::collectives::{
    multi_ring_allreduce, ring_allreduce, sim as csim, AlgoKind,
};
use mxnet_mpi::compress::Compressor as _;
use mxnet_mpi::engine::Engine;
use mxnet_mpi::metrics::Table;
use mxnet_mpi::mpisim::World;
use mxnet_mpi::netsim::CostParams;
use mxnet_mpi::tensor::NodeTensor;
use std::sync::Arc;
use std::time::Instant;

const REPS: usize = 7;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

/// Run `f` REPS times (plus one warmup); return median seconds.
fn bench<F: FnMut()>(mut f: F) -> f64 {
    f();
    median(
        (0..REPS)
            .map(|_| {
                let t = Instant::now();
                f();
                t.elapsed().as_secs_f64()
            })
            .collect(),
    )
}

fn bench_ring_allreduce(t: &mut Table) {
    for p in [2usize, 4, 8] {
        for len in [1 << 14, 1 << 18, 1 << 21] {
            let s = bench(|| {
                let comms = World::create(p);
                let hs: Vec<_> = comms
                    .into_iter()
                    .map(|mut c| {
                        std::thread::spawn(move || {
                            let mut d = vec![c.rank() as f32; len];
                            ring_allreduce(&mut c, &mut d);
                            d[0]
                        })
                    })
                    .collect();
                for h in hs {
                    h.join().unwrap();
                }
            });
            let bytes = len * 4;
            t.row(vec![
                format!("ring_allreduce p={p}"),
                mxnet_mpi::util::fmt_bytes(bytes),
                format!("{:.3}", s * 1e3),
                format!("{:.2}", bytes as f64 * 2.0 / s / 1e9),
            ]);
        }
    }
}

fn bench_multi_ring(t: &mut Table) {
    let len = 1 << 21;
    for rings in [1usize, 2, 4] {
        let s = bench(|| {
            let comms = World::create(4);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    std::thread::spawn(move || {
                        let mut d = vec![c.rank() as f32; len];
                        multi_ring_allreduce(&mut c, &mut d, rings);
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        });
        t.row(vec![
            format!("multi_ring rings={rings} p=4"),
            mxnet_mpi::util::fmt_bytes(len * 4),
            format!("{:.3}", s * 1e3),
            format!("{:.2}", (len * 4) as f64 * 2.0 / s / 1e9),
        ]);
    }
}

/// Wall-clock comparison of the three pluggable schedules on the real
/// mpisim data path (ring / halving-doubling / hierarchical).
fn bench_algo_schedules(t: &mut Table) {
    let params = CostParams::testbed1();
    for p in [4usize, 8] {
        for len in [1 << 10, 1 << 16, 1 << 20] {
            for kind in AlgoKind::DATA_PATH {
                let pr = params.clone();
                let s = bench(|| {
                    let comms = World::create(p);
                    let hs: Vec<_> = comms
                        .into_iter()
                        .map(|mut c| {
                            let pr = pr.clone();
                            std::thread::spawn(move || {
                                let mut d = vec![c.rank() as f32; len];
                                mxnet_mpi::collectives::allreduce_with(
                                    kind, &mut c, &mut d, 2, 2, &pr,
                                );
                                d[0]
                            })
                        })
                        .collect();
                    for h in hs {
                        h.join().unwrap();
                    }
                });
                let bytes = len * 4;
                t.row(vec![
                    format!("{} p={p}", kind.name()),
                    mxnet_mpi::util::fmt_bytes(bytes),
                    format!("{:.3}", s * 1e3),
                    format!("{:.2}", bytes as f64 * 2.0 / s / 1e9),
                ]);
            }
        }
    }
}

/// Modeled seconds per schedule across message sizes (α-β-γ cost models at
/// the data path's pipeline depth): prints the select_best winner per row,
/// making the small-message halving-doubling → large-message ring
/// crossover visible, plus the blocking (chunks=1) ring for comparison.
fn report_modeled_crossover() {
    let params = CostParams::minsky();
    let p = 16;
    let k = params.pipeline_chunks;
    let mut t = Table::new(&[
        "bytes",
        "ring s",
        "halving-doubling s",
        "hierarchical s",
        "blocking ring s",
        "best",
    ]);
    for shift in [10usize, 12, 14, 16, 18, 20, 22, 24, 26] {
        let bytes = 1usize << shift;
        let secs: Vec<f64> = AlgoKind::DATA_PATH
            .into_iter()
            .map(|k| csim::network_allreduce_seconds(k, p, bytes, &params))
            .collect();
        let blocking =
            csim::network_allreduce_seconds_chunked(AlgoKind::Ring, p, bytes, 1, &params);
        let (best, _) = csim::select_best(bytes, p, &params);
        t.row(vec![
            mxnet_mpi::util::fmt_bytes(bytes),
            format!("{:.3e}", secs[0]),
            format!("{:.3e}", secs[1]),
            format!("{:.3e}", secs[2]),
            format!("{:.3e}", blocking),
            best.name().to_string(),
        ]);
    }
    println!(
        "== modeled allreduce seconds, p={p}, pipeline chunks={k} (select_best winner) ==\n{}",
        t.render()
    );
}

/// Blocking vs DAG-embedded-overlapped modeled iteration/epoch time: one
/// fused allreduce after backward vs per-bucket collectives issued as
/// gradients become ready (arXiv:1802.06949). ResNet-50-analog traffic
/// (102 MB, 4 MiB fusion buckets, 0.35 s/batch compute).
fn report_overlap_epoch_table() {
    let params = CostParams::testbed1();
    let bytes = 102usize << 20;
    let fusion = 4usize << 20;
    let compute = 0.35f64;
    let buckets = (bytes + fusion - 1) / fusion;
    let batches_per_epoch = 16.0; // per worker, testbed1 config analog
    let mut t = Table::new(&[
        "workers/client",
        "blocking step s",
        "overlapped step s",
        "blocking epoch s",
        "overlapped epoch s",
        "improvement",
    ]);
    for m in [2usize, 4, 6, 12] {
        let blocking_comm =
            csim::tensor_allreduce_seconds(AlgoKind::Auto, m, bytes, 2, &params);
        let per_msg = bytes / buckets;
        let bucketed_comm = buckets as f64
            * csim::tensor_allreduce_seconds(AlgoKind::Auto, m, per_msg, 2, &params);
        let blocking_step = compute + blocking_comm;
        let overlapped_step = csim::overlapped_step_seconds(compute, bucketed_comm, buckets)
            .min(blocking_step);
        t.row(vec![
            m.to_string(),
            format!("{blocking_step:.4}"),
            format!("{overlapped_step:.4}"),
            format!("{:.2}", blocking_step * batches_per_epoch),
            format!("{:.2}", overlapped_step * batches_per_epoch),
            format!("{:.1}%", (1.0 - overlapped_step / blocking_step) * 100.0),
        ]);
    }
    println!(
        "== blocking vs overlapped modeled epoch time (102 MB grads, {buckets} fusion buckets) ==\n{}",
        t.render()
    );
}

/// Registry-derived strategy table: sync cadence, PS-bound traffic and a
/// modeled epoch time per registered algorithm. Rows (including `bmuf` /
/// `local-sgd`) appear here automatically on registration — the table can
/// never lag the algorithm set. The wire column prices the configured
/// codec (identity by default: wire == dense; see the compression table
/// below for the per-codec reductions).
fn report_strategy_table() {
    use mxnet_mpi::config::{Algo, ExperimentConfig};
    let mut t = Table::new(&[
        "algo",
        "grouping",
        "server",
        "syncs/iter",
        "PS MB/iter/master",
        "wire MB/iter/master",
        "modeled epoch s",
    ]);
    for algo in Algo::all() {
        let cfg = ExperimentConfig::testbed1(algo);
        let s = algo.strategy();
        let syncs = s.syncs_per_iter(&cfg);
        let p = cfg.cost_params();
        let iters = cfg.samples_per_epoch as f64 / (cfg.workers as f64 * cfg.batch as f64);
        // Model-snapshot pushes (ESGD/local-sgd/bmuf syncs) are always
        // dense; gradient pushes move the configured codec's wire bytes.
        let wire_bytes = if s.pushes_model() {
            cfg.virtual_model_bytes as f64
        } else {
            cfg.build_compressor()
                .wire_bytes(cfg.virtual_model_bytes / 4) as f64
        };
        // Rough α-β epoch model: compute + the PS round-trip traffic the
        // strategy actually schedules (compressed push + dense pull).
        let epoch_s = iters
            * (cfg.compute_s_per_batch
                + syncs * (wire_bytes + cfg.virtual_model_bytes as f64) * p.beta_net);
        t.row(vec![
            algo.name().to_string(),
            algo.grouping().name().to_string(),
            format!("{:?}", s.server_mode()),
            format!("{syncs:.3}"),
            format!(
                "{:.1}",
                cfg.virtual_model_bytes as f64 * syncs / (1 << 20) as f64
            ),
            format!("{:.1}", wire_bytes * syncs / (1 << 20) as f64),
            format!("{epoch_s:.1}"),
        ]);
    }
    println!(
        "== registered strategies (registry-derived; comm volume x cadence) ==\n{}",
        t.render()
    );
}

/// Registry-derived compression table: dense vs wire bytes per codec for
/// ResNet-50-scale gradients (102 MB), the reduction factor, and the
/// modeled PS push seconds (wire transfer + codec γ) against dense — the
/// bytes-on-the-wire savings the compression plane buys per codec.
fn report_compression_table() {
    use mxnet_mpi::compress::{codec_seconds, Codec};
    let params = CostParams::testbed1();
    let dense_bytes = 102usize << 20;
    let n = dense_bytes / 4;
    let topk_ratio = 0.01;
    let mut t = Table::new(&[
        "codec",
        "dense MB",
        "wire MB",
        "reduction",
        "PS push s (dense)",
        "PS push s (codec)",
    ]);
    let dense_s = dense_bytes as f64 * params.beta_ps;
    for codec in Codec::all() {
        let built = codec.build(topk_ratio);
        let wire = built.wire_bytes(n);
        let push_s = wire as f64 * params.beta_ps + codec_seconds(&*built, dense_bytes, &params);
        t.row(vec![
            codec.name().to_string(),
            format!("{:.1}", dense_bytes as f64 / (1 << 20) as f64),
            format!("{:.1}", wire as f64 / (1 << 20) as f64),
            format!("{:.1}x", dense_bytes as f64 / wire as f64),
            format!("{dense_s:.4}"),
            format!("{push_s:.4}"),
        ]);
    }
    println!(
        "== gradient codecs (registry-derived; 102 MB grads, topk ratio {topk_ratio}) ==\n{}",
        t.render()
    );
}

/// Wall-clock blocking (dense) vs compressed allreduce on the real mpisim
/// data path, one row per registered codec; the size column shows the
/// actual wire bytes each rank fans out (what moves through mpisim).
fn bench_compressed_allreduce(t: &mut Table) {
    use mxnet_mpi::compress::{Codec, EfState};
    let p = 4;
    let len = 1 << 18;
    let params = CostParams::testbed1();
    for codec in Codec::all() {
        let wire_bytes = codec.build(0.01).wire_bytes(len);
        let pr = params.clone();
        let s = bench(|| {
            let comms = World::create(p);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    let pr = pr.clone();
                    let built = codec.build(0.01);
                    std::thread::spawn(move || {
                        let mut ef = EfState::new();
                        let mut d = vec![c.rank() as f32 + 0.5; len];
                        mxnet_mpi::collectives::compressed_allreduce(
                            AlgoKind::Ring,
                            &mut c,
                            &mut d,
                            &*built,
                            0,
                            &mut ef,
                            2,
                            2,
                            &pr,
                        );
                        d[0]
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        });
        t.row(vec![
            format!("compressed_allreduce {} p={p}", codec.name()),
            mxnet_mpi::util::fmt_bytes(wire_bytes),
            format!("{:.3}", s * 1e3),
            format!("{:.2}", (len * 4) as f64 * 2.0 / s / 1e9),
        ]);
    }
}

fn bench_tensor_allreduce(t: &mut Table) {
    let len = 1 << 20;
    let s = bench(|| {
        let comms = World::create(4);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let mut nt = NodeTensor::from_vecs(vec![vec![1.0f32; len]; 2]);
                    mxnet_mpi::collectives::tensor_allreduce(
                        &mut c,
                        &mut nt,
                        2,
                        mxnet_mpi::collectives::HostReduce::Host,
                    );
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    });
    t.row(vec![
        "tensor_allreduce p=4 g=2".into(),
        mxnet_mpi::util::fmt_bytes(len * 4),
        format!("{:.3}", s * 1e3),
        format!("{:.2}", (len * 4) as f64 * 2.0 / s / 1e9),
    ]);
}

fn bench_engine(t: &mut Table) {
    for threads in [1usize, 2, 4] {
        let n_ops = 20_000;
        let s = bench(|| {
            let e = Engine::new(threads);
            let vars: Vec<_> = (0..64).map(|_| e.new_var()).collect();
            let sink = Arc::new(std::sync::atomic::AtomicU64::new(0));
            for i in 0..n_ops {
                let s = sink.clone();
                let r = vars[i % 64];
                let m = vars[(i * 7 + 3) % 64];
                e.push(
                    move || {
                        s.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    },
                    &[r],
                    &[m],
                );
            }
            e.wait_all();
        });
        t.row(vec![
            format!("engine threads={threads}"),
            format!("{n_ops} ops"),
            format!("{:.3}", s * 1e3),
            format!("{:.2} Mops/s", n_ops as f64 / s / 1e6),
        ]);
    }
}

fn bench_ps_round(t: &mut Table) {
    use mxnet_mpi::optimizer::{Sgd, SgdHyper};
    use mxnet_mpi::ps::{ServerGroup, SyncMode};
    let len = 1 << 18;
    for workers in [2usize, 4, 8] {
        let s = bench(|| {
            let group = ServerGroup::spawn(2, SyncMode::Sync, workers);
            let c0 = group.client();
            for k in 0..4 {
                c0.init(k, vec![0.0; len]);
            }
            c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(0.1, 1.0))));
            let hs: Vec<_> = (0..workers)
                .map(|_| {
                    let mut c = group.client();
                    std::thread::spawn(move || {
                        for _ in 0..4 {
                            for k in 0..4 {
                                c.push(k, vec![1.0; len]);
                            }
                            for k in 0..4 {
                                let _ = c.pull(k);
                            }
                        }
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            group.shutdown();
        });
        t.row(vec![
            format!("ps_sync_round w={workers} s=2 k=4"),
            mxnet_mpi::util::fmt_bytes(len * 4),
            format!("{:.3}", s * 1e3),
            format!("{:.1} rounds/s", 4.0 / s),
        ]);
    }
}

fn bench_pjrt(t: &mut Table) {
    use mxnet_mpi::runtime::{Model, Runtime, XData};
    let arts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::cpu().expect("pjrt");
    for variant in ["mlp_tiny", "mlp"] {
        let model = match Model::load(&rt, &arts, variant) {
            Ok(m) => m,
            Err(_) => continue, // artifacts not built for this variant
        };
        let params = model.meta.init_params().unwrap();
        let data = mxnet_mpi::data::GaussianMixture::new(
            model.meta.x_shape[1] as usize,
            16,
            1.0,
            1,
        );
        let b = data.batch(0, model.meta.batch_size());
        let x = XData::F32(b.x);
        let s = bench(|| {
            let _ = model.grad_step(&params, &x, &b.y).unwrap();
        });
        t.row(vec![
            format!("pjrt grad_step {variant}"),
            format!("{} params", model.meta.params),
            format!("{:.3}", s * 1e3),
            format!("{:.2} steps/s", 1.0 / s),
        ]);
        let mut w = params.clone();
        let g = params.clone();
        let mut m = vec![0.0; w.len()];
        let hyper = mxnet_mpi::optimizer::SgdHyper::plain(0.1, 1.0);
        let s = bench(|| {
            model.sgd_update(&mut w, &g, &mut m, &hyper).unwrap();
        });
        t.row(vec![
            format!("pjrt sgd_update {variant}"),
            format!("{} params", model.meta.params),
            format!("{:.3}", s * 1e3),
            format!("{:.2} steps/s", 1.0 / s),
        ]);
    }
}

/// Wall-clock blocking (chunks=1) vs pipelined (preset chunks) schedules
/// on the real mpisim data path.
fn bench_pipelined_vs_blocking(t: &mut Table) {
    use mxnet_mpi::collectives::{
        halving_doubling_allreduce_pipelined, multi_ring_allreduce_pipelined,
    };
    let p = 4;
    let len = 1 << 20;
    for (label, chunks) in [("blocking", 1usize), ("pipelined k=4", 4)] {
        for algo in ["ring", "hd"] {
            let s = bench(|| {
                let comms = World::create(p);
                let hs: Vec<_> = comms
                    .into_iter()
                    .map(|mut c| {
                        std::thread::spawn(move || {
                            let mut d = vec![c.rank() as f32; len];
                            match algo {
                                "ring" => multi_ring_allreduce_pipelined(&mut c, &mut d, 2, chunks),
                                _ => halving_doubling_allreduce_pipelined(&mut c, &mut d, chunks),
                            }
                            d[0]
                        })
                    })
                    .collect();
                for h in hs {
                    h.join().unwrap();
                }
            });
            let bytes = len * 4;
            t.row(vec![
                format!("{algo} {label} p={p}"),
                mxnet_mpi::util::fmt_bytes(bytes),
                format!("{:.3}", s * 1e3),
                format!("{:.2}", bytes as f64 * 2.0 / s / 1e9),
            ]);
        }
    }
}

fn main() {
    report_modeled_crossover();
    report_overlap_epoch_table();
    report_strategy_table();
    report_compression_table();
    println!("== real-substrate microbenchmarks (median of {REPS}) ==");
    let mut t = Table::new(&["bench", "size", "median ms", "rate"]);
    bench_ring_allreduce(&mut t);
    bench_multi_ring(&mut t);
    bench_pipelined_vs_blocking(&mut t);
    bench_compressed_allreduce(&mut t);
    bench_algo_schedules(&mut t);
    bench_tensor_allreduce(&mut t);
    bench_engine(&mut t);
    bench_ps_round(&mut t);
    bench_pjrt(&mut t);
    println!("{}", t.render());
}
