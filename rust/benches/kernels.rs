//! Compute-plane kernel benchmarks (ISSUE 7) plus the ISSUE-8 device
//! tier and the ISSUE-9 cluster authority: tiled/parallel kernels vs the
//! seed scalar implementations, codec encode/decode, allreduce by
//! schedule (now including `two_tier`), the modeled epoch/wire summary,
//! the flat-vs-two-tier epoch and per-tier wire-byte table, and the
//! static-vs-elastic cluster goodput sweep — emitted as `BENCH_9.json` at
//! the repo root (schema `mxnet-mpi-bench/v3`, validated in CI by
//! `examples/check_bench.rs`, which also gates on
//! `inter_wire_bytes(two_tier, k) * k == inter_wire_bytes(flat)` exactly
//! and on the cluster node-pool conservation integers).
//!
//!     cargo bench --bench kernels               # full shapes, REPS=7
//!     BENCH_SMOKE=1 cargo bench --bench kernels # CI short-iteration mode
//!
//! The `naive_*` baselines below are verbatim copies of the seed scalar
//! kernels (pre-parallel `runtime/native.rs`), kept so the before/after
//! speedup column measures the tiled multi-threaded rewrite against the
//! exact code it replaced. `benches/KERNEL_TABLE.md` holds a checked-in
//! reference run of the table this prints.

use mxnet_mpi::compress::{Codec, Compressed};
use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::jsonlite::Value;
use mxnet_mpi::metrics::Table;
use mxnet_mpi::mpisim::World;
use mxnet_mpi::netsim::CostParams;
use mxnet_mpi::runtime::native;
use mxnet_mpi::util::Rng;
use std::time::Instant;

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok()
}

fn reps() -> usize {
    if smoke() {
        3
    } else {
        7
    }
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

/// Run `f` reps times (plus one warmup); return median seconds.
fn bench<F: FnMut()>(mut f: F) -> f64 {
    f();
    median(
        (0..reps())
            .map(|_| {
                let t = Instant::now();
                f();
                t.elapsed().as_secs_f64()
            })
            .collect(),
    )
}

fn payload(seed: u64, len: usize) -> Vec<f32> {
    let mut r = Rng::new(seed.wrapping_mul(0x9E37_79B9) | 1);
    (0..len).map(|_| r.normal() as f32 * 0.7).collect()
}

// ---------------------------------------------------------------------------
// Seed scalar baselines (verbatim pre-parallel kernels)
// ---------------------------------------------------------------------------

fn naive_matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    for i in 0..m {
        let yrow = &mut y[i * n..(i + 1) * n];
        for l in 0..k {
            let a = x[i * k + l];
            if a != 0.0 {
                let wrow = &w[l * n..(l + 1) * n];
                for j in 0..n {
                    yrow[j] += a * wrow[j];
                }
            }
        }
    }
    y
}

fn naive_matmul_tn(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut g = vec![0.0f32; k * n];
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for l in 0..k {
            let a = x[i * k + l];
            if a != 0.0 {
                let grow = &mut g[l * n..(l + 1) * n];
                for j in 0..n {
                    grow[j] += a * dyrow[j];
                }
            }
        }
    }
    g
}

fn naive_matmul_nt(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for l in 0..k {
            let wrow = &w[l * n..(l + 1) * n];
            let mut s = 0.0f32;
            for j in 0..n {
                s += dyrow[j] * wrow[j];
            }
            dx[i * k + l] = s;
        }
    }
    dx
}

fn naive_ln_fwd(x: &[f32], scale: &[f32], bias: &[f32], rows: usize, d: usize) -> Vec<f32> {
    const LN_EPS: f32 = 1e-5;
    let mut y = vec![0.0f32; rows * d];
    let dn = d as f32;
    for i in 0..rows {
        let row = &x[i * d..(i + 1) * d];
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= dn;
        let mut var = 0.0f32;
        for &v in row {
            var += (v - mu) * (v - mu);
        }
        var /= dn;
        let r = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..d {
            y[i * d + j] = (row[j] - mu) * r * scale[j] + bias[j];
        }
    }
    y
}

fn naive_gelu_fwd(x: &[f32]) -> Vec<f32> {
    let c0 = (2.0f32 / std::f32::consts::PI).sqrt();
    let mut y = vec![0.0f32; x.len()];
    for i in 0..x.len() {
        let v = x[i];
        let u = c0 * (v + 0.044715 * v * v * v);
        y[i] = 0.5 * v * (1.0 + u.tanh());
    }
    y
}

fn naive_softmax_xent(logits: &[f32], y: &[i32], rows: usize, v: usize) -> (f32, Vec<f32>) {
    let mut dl = vec![0.0f32; rows * v];
    let mut loss = 0.0f64;
    for i in 0..rows {
        let row = &logits[i * v..(i + 1) * v];
        let gold = y[i] as usize;
        let mut mx = f32::NEG_INFINITY;
        for &x in row {
            if x > mx {
                mx = x;
            }
        }
        let mut z = 0.0f32;
        for &x in row {
            z += (x - mx).exp();
        }
        loss += (z.ln() + mx - row[gold]) as f64;
        let drow = &mut dl[i * v..(i + 1) * v];
        for j in 0..v {
            drow[j] = (row[j] - mx).exp() / z;
        }
        drow[gold] -= 1.0;
    }
    let inv = 1.0 / rows as f32;
    for d in dl.iter_mut() {
        *d *= inv;
    }
    ((loss / rows as f64) as f32, dl)
}

// ---------------------------------------------------------------------------
// Sections
// ---------------------------------------------------------------------------

struct KernelRow {
    name: &'static str,
    shape: String,
    naive_us: f64,
    tiled_us: f64,
}

impl KernelRow {
    fn speedup(&self) -> f64 {
        self.naive_us / self.tiled_us.max(1e-9)
    }
}

/// Per-kernel seed-vs-tiled timings at the seed sizes and the 4–8×
/// transformer shapes the acceptance table quotes.
fn bench_kernels() -> Vec<KernelRow> {
    let mut rows = Vec::new();
    // (m, k, n): seed-model scale, then transformer scale (batch*seq ×
    // d_model × d_ff analog). Smoke mode shrinks the large shape so CI
    // stays fast while exercising the same code paths.
    let large = if smoke() { (128, 128, 256) } else { (512, 256, 1024) };
    for (m, k, n) in [(64usize, 64usize, 64usize), large] {
        let x = payload(1, m * k);
        let w = payload(2, k * n);
        let dy = payload(3, m * n);
        let shape = format!("{m}x{k}x{n}");
        let naive = bench(|| {
            naive_matmul(&x, &w, m, k, n);
        });
        let tiled = bench(|| {
            native::matmul(&x, &w, m, k, n);
        });
        rows.push(KernelRow {
            name: "matmul",
            shape: shape.clone(),
            naive_us: naive * 1e6,
            tiled_us: tiled * 1e6,
        });
        let naive = bench(|| {
            naive_matmul_tn(&x, &dy, m, k, n);
        });
        let tiled = bench(|| {
            native::matmul_tn(&x, &dy, m, k, n);
        });
        rows.push(KernelRow {
            name: "matmul_tn",
            shape: shape.clone(),
            naive_us: naive * 1e6,
            tiled_us: tiled * 1e6,
        });
        let naive = bench(|| {
            naive_matmul_nt(&dy, &w, m, n, k);
        });
        let tiled = bench(|| {
            native::matmul_nt(&dy, &w, m, n, k);
        });
        rows.push(KernelRow {
            name: "matmul_nt",
            shape,
            naive_us: naive * 1e6,
            tiled_us: tiled * 1e6,
        });
    }
    let (rl, dl) = if smoke() { (512, 128) } else { (4096, 256) };
    for (rows_n, d) in [(64usize, 64usize), (rl, dl)] {
        let x = payload(4, rows_n * d);
        let scale = payload(5, d);
        let bias = payload(6, d);
        let shape = format!("{rows_n}x{d}");
        let naive = bench(|| {
            naive_ln_fwd(&x, &scale, &bias, rows_n, d);
        });
        let tiled = bench(|| {
            native::ln_fwd(&x, &scale, &bias, rows_n, d);
        });
        rows.push(KernelRow {
            name: "ln_fwd",
            shape: shape.clone(),
            naive_us: naive * 1e6,
            tiled_us: tiled * 1e6,
        });
        let naive = bench(|| {
            naive_gelu_fwd(&x);
        });
        let tiled = bench(|| {
            native::gelu_fwd(&x);
        });
        rows.push(KernelRow {
            name: "gelu_fwd",
            shape: shape.clone(),
            naive_us: naive * 1e6,
            tiled_us: tiled * 1e6,
        });
        let labels: Vec<i32> = (0..rows_n).map(|i| (i % d) as i32).collect();
        let naive = bench(|| {
            naive_softmax_xent(&x, &labels, rows_n, d);
        });
        let tiled = bench(|| {
            native::softmax_xent(&x, &labels, rows_n, d);
        });
        rows.push(KernelRow {
            name: "softmax_xent",
            shape,
            naive_us: naive * 1e6,
            tiled_us: tiled * 1e6,
        });
    }
    rows
}

/// Wall-clock allreduce by pluggable schedule on the real mpisim path.
fn bench_allreduce() -> Vec<(String, usize, f64)> {
    let params = CostParams::testbed1();
    let len = if smoke() { 1 << 12 } else { 1 << 16 };
    let mut out = Vec::new();
    for kind in mxnet_mpi::collectives::AlgoKind::DATA_PATH {
        let pr = params.clone();
        let s = bench(|| {
            let comms = World::create(4);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    let pr = pr.clone();
                    std::thread::spawn(move || {
                        let mut d = vec![c.rank() as f32; len];
                        mxnet_mpi::collectives::allreduce_with(kind, &mut c, &mut d, 2, 2, &pr);
                        d[0]
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        });
        out.push((kind.name().to_string(), len * 4, s * 1e6));
    }
    out
}

/// Encode/decode wall-clock for every registered codec.
fn bench_codecs() -> Vec<(String, usize, f64, f64)> {
    let n = if smoke() { 1 << 12 } else { 1 << 18 };
    let data = payload(7, n);
    let mut out = Vec::new();
    for codec in Codec::all() {
        let built = codec.build(0.01);
        let enc = bench(|| {
            built.compress(&data);
        });
        let compressed = built.compress(&data);
        let wire = compressed.to_wire();
        let dec = bench(|| {
            Compressed::from_wire(&wire).unwrap().decompress();
        });
        out.push((codec.name().to_string(), n, enc * 1e6, dec * 1e6));
    }
    out
}

/// Modeled epoch seconds per registered algorithm (testbed1 analog) and
/// modeled wire bytes per codec — the trajectory numbers BENCH_*.json
/// tracks across PRs.
fn modeled_sections() -> (Vec<Value>, Vec<Value>) {
    let mut epoch = Vec::new();
    for algo in Algo::all() {
        let cfg = ExperimentConfig::testbed1(algo);
        let s = algo.strategy();
        let syncs = s.syncs_per_iter(&cfg);
        let p = cfg.cost_params();
        let iters = cfg.samples_per_epoch as f64 / (cfg.workers as f64 * cfg.batch as f64);
        let wire_bytes = if s.pushes_model() {
            cfg.virtual_model_bytes as f64
        } else {
            cfg.build_compressor().wire_bytes(cfg.virtual_model_bytes / 4) as f64
        };
        let epoch_s = iters
            * (cfg.compute_s_per_batch
                + syncs * (wire_bytes + cfg.virtual_model_bytes as f64) * p.beta_net);
        epoch.push(Value::obj(vec![
            ("algo", Value::str(algo.name())),
            ("modeled_epoch_s", Value::num(epoch_s)),
            ("wire_mb_per_iter", Value::num(wire_bytes * syncs / (1 << 20) as f64)),
        ]));
    }
    let dense_bytes = 102usize << 20;
    let wire = Codec::all()
        .into_iter()
        .map(|codec| {
            Value::obj(vec![
                ("codec", Value::str(codec.name())),
                ("dense_bytes", Value::num(dense_bytes as f64)),
                ("wire_bytes", Value::num(codec.build(0.01).wire_bytes(dense_bytes / 4) as f64)),
            ])
        })
        .collect();
    (epoch, wire)
}

/// The ISSUE-8 device-tier section: flat vs two-tier modeled epoch
/// seconds and per-tier wire bytes per k, from the same model behind
/// `fig_twotier` (the mpi-SGD/identity slice — the headline dense
/// comparison the CI ratio gate checks).
fn two_tier_section() -> Vec<Value> {
    mxnet_mpi::figures::fig_twotier(None)
        .expect("fig_twotier model")
        .into_iter()
        .filter(|r| r.strategy == "mpi-SGD" && r.codec == "identity")
        .map(|r| {
            Value::obj(vec![
                ("devices", Value::num(r.devices as f64)),
                ("flat_epoch_s", Value::num(r.flat_epoch_s)),
                ("two_tier_epoch_s", Value::num(r.two_tier_epoch_s)),
                ("flat_intra_wire_bytes", Value::num(r.flat_intra_bytes as f64)),
                ("flat_inter_wire_bytes", Value::num(r.flat_inter_bytes as f64)),
                ("two_tier_intra_wire_bytes", Value::num(r.two_tier_intra_bytes as f64)),
                ("two_tier_inter_wire_bytes", Value::num(r.two_tier_inter_bytes as f64)),
            ])
        })
        .collect()
}

/// The ISSUE-9 cluster section: the `fig_cluster` arrival-rate sweep —
/// aggregate goodput under static vs elastic allocation plus the integer
/// pool-conservation audit the CI gate checks exactly.
fn cluster_section() -> Vec<Value> {
    mxnet_mpi::figures::fig_cluster(None)
        .expect("fig_cluster model")
        .into_iter()
        .map(|r| {
            Value::obj(vec![
                ("arrival_interval_s", Value::num(r.arrival_interval_s)),
                ("jobs", Value::num(r.jobs as f64)),
                ("pool_nodes", Value::num(r.pool_nodes as f64)),
                ("static_makespan_s", Value::num(r.static_makespan_s)),
                ("elastic_makespan_s", Value::num(r.elastic_makespan_s)),
                ("static_goodput", Value::num(r.static_goodput)),
                ("elastic_goodput", Value::num(r.elastic_goodput)),
                ("total_samples", Value::num(r.total_samples as f64)),
                ("alloc_free_min", Value::num(r.alloc_free_min as f64)),
                ("alloc_free_max", Value::num(r.alloc_free_max as f64)),
                ("double_booked", Value::num(r.double_booked as f64)),
            ])
        })
        .collect()
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    mxnet_mpi::runtime::par::set_threads(0);
    let mode = if smoke() { "smoke" } else { "full" };
    println!("== compute-plane kernels, mode={mode}, threads={threads} ==");

    let kernels = bench_kernels();
    let mut t = Table::new(&["kernel", "shape", "seed us", "tiled us", "speedup"]);
    for r in &kernels {
        t.row(vec![
            r.name.to_string(),
            r.shape.clone(),
            format!("{:.1}", r.naive_us),
            format!("{:.1}", r.tiled_us),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    println!("{}", t.render());

    let allreduce = bench_allreduce();
    let codecs = bench_codecs();
    let (epoch, wire) = modeled_sections();
    let two_tier = two_tier_section();

    let mut tt = Table::new(&[
        "devices",
        "flat epoch_s",
        "two-tier epoch_s",
        "intra B/node",
        "inter B/node (flat -> two-tier)",
    ]);
    for row in &two_tier {
        let get = |k: &str| row.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        tt.row(vec![
            format!("{}", get("devices") as u64),
            format!("{:.4}", get("flat_epoch_s")),
            format!("{:.4}", get("two_tier_epoch_s")),
            format!("{}", get("two_tier_intra_wire_bytes") as u64),
            format!(
                "{} -> {}",
                get("flat_inter_wire_bytes") as u64,
                get("two_tier_inter_wire_bytes") as u64
            ),
        ]);
    }
    println!("== two-tier device tier (mpi-SGD, identity) ==");
    println!("{}", tt.render());

    let cluster = cluster_section();
    let mut ct = Table::new(&[
        "interval_s",
        "jobs",
        "pool",
        "static goodput",
        "elastic goodput",
        "gain",
    ]);
    for row in &cluster {
        let get = |k: &str| row.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        ct.row(vec![
            format!("{}", get("arrival_interval_s")),
            format!("{}", get("jobs") as u64),
            format!("{}", get("pool_nodes") as u64),
            format!("{:.2}", get("static_goodput")),
            format!("{:.2}", get("elastic_goodput")),
            format!("{:.2}x", get("elastic_goodput") / get("static_goodput").max(1e-12)),
        ]);
    }
    println!("== cluster goodput: static vs elastic allocation ==");
    println!("{}", ct.render());

    let doc = Value::obj(vec![
        ("schema", Value::str("mxnet-mpi-bench/v3")),
        ("issue", Value::num(9.0)),
        ("mode", Value::str(mode)),
        ("threads", Value::num(threads as f64)),
        ("epoch", Value::Arr(epoch)),
        ("wire_bytes", Value::Arr(wire)),
        ("two_tier", Value::Arr(two_tier)),
        ("cluster", Value::Arr(cluster)),
        (
            "kernels_us",
            Value::Arr(
                kernels
                    .iter()
                    .map(|r| {
                        Value::obj(vec![
                            ("name", Value::str(r.name)),
                            ("shape", Value::str(&r.shape)),
                            ("naive_us", Value::num(r.naive_us)),
                            ("tiled_us", Value::num(r.tiled_us)),
                            ("speedup", Value::num(r.speedup())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "allreduce_us",
            Value::Arr(
                allreduce
                    .iter()
                    .map(|(sched, bytes, us)| {
                        Value::obj(vec![
                            ("schedule", Value::str(sched)),
                            ("bytes", Value::num(*bytes as f64)),
                            ("us", Value::num(*us)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "codec_us",
            Value::Arr(
                codecs
                    .iter()
                    .map(|(codec, n, enc, dec)| {
                        Value::obj(vec![
                            ("codec", Value::str(codec)),
                            ("n", Value::num(*n as f64)),
                            ("encode_us", Value::num(*enc)),
                            ("decode_us", Value::num(*dec)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);

    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_9.json");
    std::fs::write(&path, doc.to_json_pretty() + "\n").expect("write BENCH_9.json");
    println!("wrote {}", path.display());
}
