//! Parameter-server substrate: scheduler, servers, worker-side client.
//!
//! Mirrors MXNET's PS architecture (§3.2, §4.1): a *scheduler* task that
//! every worker/server registers with, N *server* tasks each owning a shard
//! of the KVStore (key -> server by modulo, like ps-lite key sharding), and
//! worker-side `ZPush`/`ZPull` primitives. Transport is in-process channels
//! (the LSF/TCP substitution, DESIGN.md §2); the protocol — registration,
//! per-key aggregation rounds, optimizer shipped to the server via
//! `set_optimizer` — follows the paper.
//!
//! Synchronous mode: a server aggregates `expected_pushes` gradients per
//! key per round, applies the shipped optimizer once, then answers the
//! round's pulls. Pulls carry the worker's push round so a fast worker
//! can never steal a slow worker's round (no deadlock, no silent
//! staleness) — see `ServerMsg::Pull::after_round`.
//!
//! Asynchronous mode: every push is applied immediately (the §2.3
//! staleness regime); pulls answer with whatever is current.

use anyhow::{bail, Context, Result};
use crate::optimizer::Optimizer;
use crate::util::sync::{channel_named, Builder, Condvar, JoinHandle, Mutex, Receiver, Sender};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Arc;

pub type Key = usize;

/// Server aggregation discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Aggregate `expected_pushes` per key, update once, release pulls.
    Sync,
    /// Apply every push immediately.
    Async,
}

enum ServerMsg {
    /// Initialize a key (rank 0 in the PS namespace does this, §4.2.1).
    Init { key: Key, value: Vec<f32> },
    /// Push a gradient (or weights, for elastic averaging) for a key.
    Push { key: Key, data: Vec<f32> },
    /// Push a codec-compressed payload (the gradient-compression plane):
    /// the server decodes *before* aggregation, so compressed and dense
    /// pushes mix freely within a round. The wire is self-describing
    /// ([`crate::compress::Compressed::from_wire`]) — the server needs no
    /// codec object.
    PushCompressed { key: Key, wire: Vec<f32> },
    /// Pull the value of a key once `after_round` rounds have completed
    /// (workers pass their own push count; async mode ignores it).
    Pull { key: Key, after_round: u64, reply: Sender<Vec<f32>> },
    /// Ship an optimizer to the server (KVStore.set_optimizer, §3.2).
    SetOptimizer(Box<dyn Optimizer>),
    /// Retarget the sync quorum after a membership epoch (elasticity):
    /// rounds already satisfied by the new, smaller quorum complete
    /// immediately, so a shrunken job can never wedge on a dead worker's
    /// missing push.
    SetExpectedPushes(usize),
    /// Persist a checkpoint blob (elastic restore path). Blobs live in a
    /// namespace separate from the optimizer-managed store: no rounds, no
    /// aggregation — last write wins, like the master replica files the
    /// paper's PS keeps for restarted tasks.
    SaveBlob { key: Key, value: Vec<f32> },
    /// Fetch a checkpoint blob (None if never saved).
    LoadBlob { key: Key, reply: Sender<Option<Vec<f32>>> },
    Shutdown,
}

/// One PS server task: owns its key shard, runs on its own thread.
struct ServerState {
    mode: SyncMode,
    expected_pushes: usize,
    optimizer: Box<dyn Optimizer>,
    store: HashMap<Key, Vec<f32>>,
    /// Per-key gradient aggregation buffer + count (sync mode).
    agg: HashMap<Key, (Vec<f32>, usize)>,
    /// Completed aggregation rounds per key.
    rounds: HashMap<Key, u64>,
    /// Pulls parked until their round completes: key -> (round, reply).
    parked: HashMap<Key, Vec<(u64, Sender<Vec<f32>>)>>,
    /// Messages that raced ahead of their key's Init (workers may push as
    /// soon as the scheduler releases the job, §4.1.2); replayed on Init.
    pre_init: HashMap<Key, Vec<ServerMsg>>,
    /// Checkpoint blobs (elastic restore): outside the optimizer store.
    blobs: HashMap<Key, Vec<f32>>,
}

impl ServerState {
    fn on_push(&mut self, key: Key, data: Vec<f32>) {
        match self.mode {
            SyncMode::Async => {
                let w = self.store.get_mut(&key).expect("push before init");
                self.optimizer.update(key, w, &data);
                *self.rounds.entry(key).or_insert(0) += 1;
                self.release(key);
            }
            SyncMode::Sync => {
                let (buf, count) = self.agg.entry(key).or_insert_with(|| (Vec::new(), 0));
                if buf.is_empty() {
                    *buf = data;
                } else {
                    crate::tensor::add_assign(buf, &data);
                }
                *count += 1;
                self.maybe_complete_round(key);
            }
        }
    }

    /// Complete `key`'s sync round if its aggregation quorum is met —
    /// either a push arrived (the normal path) or the quorum shrank under
    /// it (SetExpectedPushes after a membership epoch).
    fn maybe_complete_round(&mut self, key: Key) {
        let full = self
            .agg
            .get(&key)
            .is_some_and(|(_, count)| *count >= self.expected_pushes);
        if full {
            let (buf, _) = self
                .agg
                .remove(&key)
                .unwrap_or_else(|| panic!("sync round completed for key {key} with no aggregate"));
            let w = self.store.get_mut(&key).expect("push before init");
            self.optimizer.update(key, w, &buf);
            *self.rounds.entry(key).or_insert(0) += 1;
            self.release(key);
        }
    }

    fn release(&mut self, key: Key) {
        let done = *self.rounds.get(&key).unwrap_or(&0);
        if let Some(parked) = self.parked.get_mut(&key) {
            let mut keep = Vec::new();
            for (round, reply) in parked.drain(..) {
                if round <= done {
                    let _ = reply.send(self.store[&key].clone());
                } else {
                    keep.push((round, reply));
                }
            }
            *parked = keep;
        }
    }

    fn on_pull(&mut self, key: Key, after_round: u64, reply: Sender<Vec<f32>>) {
        let done = *self.rounds.get(&key).unwrap_or(&0);
        let ready = match self.mode {
            SyncMode::Async => true,
            SyncMode::Sync => after_round <= done,
        };
        if ready {
            let _ = reply.send(self.store.get(&key).expect("pull before init").clone());
        } else {
            self.parked.entry(key).or_default().push((after_round, reply));
        }
    }

    fn handle(&mut self, msg: ServerMsg) -> bool {
        match msg {
            ServerMsg::Init { key, value } => {
                self.store.insert(key, value);
                // Replay anything that raced ahead of the init.
                if let Some(queued) = self.pre_init.remove(&key) {
                    for m in queued {
                        self.handle(m);
                    }
                }
            }
            ServerMsg::Push { key, data } => {
                if self.store.contains_key(&key) {
                    self.on_push(key, data);
                } else {
                    self.pre_init
                        .entry(key)
                        .or_default()
                        .push(ServerMsg::Push { key, data });
                }
            }
            ServerMsg::PushCompressed { key, wire } => {
                if self.store.contains_key(&key) {
                    let data = crate::compress::Compressed::from_wire(&wire)
                        .expect("malformed compressed push payload")
                        .decompress();
                    self.on_push(key, data);
                } else {
                    self.pre_init
                        .entry(key)
                        .or_default()
                        .push(ServerMsg::PushCompressed { key, wire });
                }
            }
            ServerMsg::Pull { key, after_round, reply } => {
                if self.store.contains_key(&key) {
                    self.on_pull(key, after_round, reply);
                } else {
                    self.pre_init
                        .entry(key)
                        .or_default()
                        .push(ServerMsg::Pull { key, after_round, reply });
                }
            }
            ServerMsg::SetOptimizer(opt) => self.optimizer = opt,
            ServerMsg::SetExpectedPushes(n) => {
                self.expected_pushes = n.max(1);
                // A shrink can complete rounds that were waiting on a
                // departed worker's push: re-check every open aggregation.
                let open: Vec<Key> = self.agg.keys().copied().collect();
                for key in open {
                    self.maybe_complete_round(key);
                }
            }
            ServerMsg::SaveBlob { key, value } => {
                self.blobs.insert(key, value);
            }
            ServerMsg::LoadBlob { key, reply } => {
                let _ = reply.send(self.blobs.get(&key).cloned());
            }
            ServerMsg::Shutdown => return false,
        }
        true
    }

    fn run(mut self, rx: Receiver<ServerMsg>) {
        while let Ok(msg) = rx.recv() {
            if !self.handle(msg) {
                break;
            }
        }
    }
}

/// Handle to a running group of PS server threads.
pub struct ServerGroup {
    txs: Vec<Sender<ServerMsg>>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerGroup {
    /// Spawn `n_servers` server tasks. `expected_pushes` is the number of
    /// pushes per key per sync round (= #workers for dist modes, #clients
    /// for MPI modes — the §4 contention knob).
    pub fn spawn(n_servers: usize, mode: SyncMode, expected_pushes: usize) -> Self {
        let mut txs = Vec::new();
        let mut threads = Vec::new();
        for s in 0..n_servers {
            let (tx, rx) = channel_named("ps.server");
            let state = ServerState {
                mode,
                expected_pushes: expected_pushes.max(1),
                optimizer: Box::new(crate::optimizer::Sgd::new(
                    crate::optimizer::SgdHyper::plain(0.1, 1.0),
                )),
                store: HashMap::new(),
                agg: HashMap::new(),
                rounds: HashMap::new(),
                parked: HashMap::new(),
                pre_init: HashMap::new(),
                blobs: HashMap::new(),
            };
            threads.push(
                Builder::new()
                    .name(format!("ps-server-{s}"))
                    .spawn(move || state.run(rx))
                    .expect("spawn ps server thread"),
            );
            txs.push(tx);
        }
        Self { txs, threads }
    }

    pub fn n_servers(&self) -> usize {
        self.txs.len()
    }

    /// A worker-side client endpoint.
    pub fn client(&self) -> PsClient {
        PsClient { servers: self.txs.clone(), push_rounds: HashMap::new() }
    }

    /// Stop all server threads (remaining messages are processed first).
    /// Idempotent; also runs from `Drop`, so a panicking worker thread
    /// that unwinds past its `ServerGroup` cannot leave server threads
    /// parked forever and wedge the test harness.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        for tx in self.txs.drain(..) {
            let _ = tx.send(ServerMsg::Shutdown);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServerGroup {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Worker-side PS endpoint: ZPush / ZPull over the sharded servers.
///
/// Keys are routed `key % n_servers` (ps-lite style). The client tracks its
/// own per-key push count so synchronous pulls wait for exactly the round
/// this worker contributed to.
#[derive(Clone)]
pub struct PsClient {
    servers: Vec<Sender<ServerMsg>>,
    push_rounds: HashMap<Key, u64>,
}

impl PsClient {
    fn server(&self, key: Key) -> &Sender<ServerMsg> {
        &self.servers[key % self.servers.len()]
    }

    /// Initialize a key on its server (call once, from PS rank 0).
    pub fn init(&self, key: Key, value: Vec<f32>) {
        self.server(key)
            .send(ServerMsg::Init { key, value })
            .expect("server gone");
    }

    /// ZPush: send a gradient/weight contribution for `key`.
    pub fn push(&mut self, key: Key, data: Vec<f32>) {
        *self.push_rounds.entry(key).or_insert(0) += 1;
        self.server(key)
            .send(ServerMsg::Push { key, data })
            .expect("server gone");
    }

    /// ZPush of a codec-compressed payload (see
    /// [`crate::compress::Compressed::to_wire`]): counts toward the same
    /// per-key round as a dense push; the server decodes before
    /// aggregating.
    pub fn push_compressed(&mut self, key: Key, wire: Vec<f32>) {
        *self.push_rounds.entry(key).or_insert(0) += 1;
        self.server(key)
            .send(ServerMsg::PushCompressed { key, wire })
            .expect("server gone");
    }

    /// ZPull: fetch the value of `key`; in sync mode waits until the round
    /// containing this worker's last push has been applied.
    pub fn pull(&mut self, key: Key) -> Vec<f32> {
        let (reply, rx) = channel_named("ps.reply");
        let after_round = *self.push_rounds.get(&key).unwrap_or(&0);
        self.server(key)
            .send(ServerMsg::Pull { key, after_round, reply })
            .expect("server gone");
        rx.recv().expect("server dropped pull")
    }

    /// Ship an optimizer to every server (KVStore.set_optimizer).
    pub fn set_optimizer<F>(&self, factory: F)
    where
        F: Fn() -> Box<dyn Optimizer>,
    {
        for tx in &self.servers {
            tx.send(ServerMsg::SetOptimizer(factory())).expect("server gone");
        }
    }

    /// Retarget every server's sync quorum after a membership epoch.
    /// Rounds already satisfied by the new quorum complete immediately.
    pub fn set_expected_pushes(&self, n: usize) {
        for tx in &self.servers {
            tx.send(ServerMsg::SetExpectedPushes(n)).expect("server gone");
        }
    }

    /// Persist a checkpoint blob under `key` (sharded like every key).
    /// Blobs are a namespace apart from the optimizer store: no rounds, no
    /// aggregation, last write wins.
    pub fn save_blob(&self, key: Key, value: Vec<f32>) {
        self.server(key)
            .send(ServerMsg::SaveBlob { key, value })
            .expect("server gone");
    }

    /// Fetch a checkpoint blob; `None` if nothing was ever saved there.
    pub fn load_blob(&self, key: Key) -> Option<Vec<f32>> {
        let (reply, rx) = channel_named("ps.reply");
        self.server(key)
            .send(ServerMsg::LoadBlob { key, reply })
            .expect("server gone");
        rx.recv().expect("server dropped blob load")
    }
}

// ---------------------------------------------------------------------------
// Scheduler — the registration/rendezvous task (§4.1.2)
// ---------------------------------------------------------------------------

/// The MXNET scheduler task: launched first, listens for every worker and
/// server, assigns ranks in the PS namespace and releases the job once the
/// expected population is connected. In-process the "address broadcast" is
/// the `Arc` itself; the protocol (register -> barrier until complete) is
/// the paper's.
///
/// Beyond the launch barrier the scheduler is the job's **membership
/// authority** (the elasticity half of the PS task model, §1–§2): workers
/// [`deregister`](Scheduler::deregister) when they leave, late joiners are
/// [`admit`](Scheduler::admit)ted, and each change is sealed by
/// [`publish_view`](Scheduler::publish_view) into an epoch-numbered
/// [`MembershipView`] that the launcher turns into rebuilt per-client
/// worlds and a recomputed sync quorum.
pub struct Scheduler {
    inner: Arc<(Mutex<SchedState>, Condvar)>,
}

#[derive(Default)]
struct SchedState {
    workers: usize,
    servers: usize,
    expect_workers: usize,
    expect_servers: usize,
    /// Live worker ranks (membership epochs).
    live: BTreeSet<usize>,
    /// Completed membership epochs; 0 = the launch population.
    epoch: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Worker,
    Server,
}

/// An epoch-numbered snapshot of the live worker set, published by the
/// scheduler at each membership change.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipView {
    pub epoch: u64,
    /// Live worker ranks, ascending.
    pub workers: Vec<usize>,
}

impl Scheduler {
    pub fn new(expect_workers: usize, expect_servers: usize) -> Self {
        Self {
            inner: Arc::new((
                Mutex::named(
                    SchedState {
                        expect_workers,
                        expect_servers,
                        ..Default::default()
                    },
                    "ps.sched",
                ),
                Condvar::named("ps.sched_cv"),
            )),
        }
    }

    /// Register a task; returns its rank within its role's namespace.
    /// Blocks until the whole job population has registered (the paper's
    /// connection-establishment barrier).
    pub fn register(&self, role: Role) -> usize {
        let (lock, cv) = &*self.inner;
        let mut st = lock.lock().expect("scheduler state lock poisoned");
        let rank = match role {
            Role::Worker => {
                st.workers += 1;
                st.live.insert(st.workers - 1);
                st.workers - 1
            }
            Role::Server => {
                st.servers += 1;
                st.servers - 1
            }
        };
        cv.notify_all();
        while st.workers < st.expect_workers || st.servers < st.expect_servers {
            st = cv.wait(st).expect("scheduler state lock poisoned at barrier");
        }
        rank
    }

    /// Register a worker under a caller-assigned rank (the launcher's
    /// ps_rank, which is stable across thread scheduling); same barrier as
    /// [`Scheduler::register`].
    pub fn register_as(&self, rank: usize) {
        let (lock, cv) = &*self.inner;
        let mut st = lock.lock().expect("scheduler state lock poisoned");
        st.workers += 1;
        st.live.insert(rank);
        cv.notify_all();
        while st.workers < st.expect_workers || st.servers < st.expect_servers {
            st = cv.wait(st).expect("scheduler state lock poisoned at barrier");
        }
    }

    /// Remove a worker from the live set (fail-stop departure or
    /// cooperative preemption). Takes effect in the next published view.
    pub fn deregister(&self, rank: usize) {
        let (lock, _) = &*self.inner;
        lock.lock().expect("scheduler state lock poisoned").live.remove(&rank);
    }

    /// Admit a late joiner into the live set (no launch barrier: the job
    /// is already running). Takes effect in the next published view.
    pub fn admit(&self, rank: usize) {
        let (lock, _) = &*self.inner;
        lock.lock().expect("scheduler state lock poisoned").live.insert(rank);
    }

    /// Seal the current live set into a new epoch-numbered view.
    pub fn publish_view(&self) -> MembershipView {
        let (lock, cv) = &*self.inner;
        let mut st = lock.lock().expect("scheduler state lock poisoned");
        st.epoch += 1;
        cv.notify_all();
        MembershipView { epoch: st.epoch, workers: st.live.iter().copied().collect() }
    }

    /// The most recently published view (epoch 0 = launch population).
    pub fn view(&self) -> MembershipView {
        let (lock, _) = &*self.inner;
        let st = lock.lock().expect("scheduler state lock poisoned");
        MembershipView { epoch: st.epoch, workers: st.live.iter().copied().collect() }
    }

    pub fn handle(&self) -> Scheduler {
        Scheduler { inner: self.inner.clone() }
    }
}

// ---------------------------------------------------------------------------
// ClusterScheduler — one authority, many jobs (ISSUE 9)
// ---------------------------------------------------------------------------

/// Multi-job front end over [`Scheduler`]: the cluster authority's
/// registration service. Each admitted job gets its *own* [`Scheduler`]
/// (its own launch quorum, live set, and membership epochs) keyed by job
/// id, so one job's barrier can never block another's and per-job churn
/// stays per-job. This is the piece that promotes the paper's per-job
/// scheduler (§4.1.2) to a shared-cluster service: the launcher connects a
/// job's ranks to the quorum registered here instead of minting a private
/// scheduler per process.
#[derive(Clone)]
pub struct ClusterScheduler {
    jobs: Arc<Mutex<BTreeMap<u64, Scheduler>>>,
}

impl Default for ClusterScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl ClusterScheduler {
    pub fn new() -> Self {
        Self { jobs: Arc::new(Mutex::named(BTreeMap::new(), "cluster.jobs")) }
    }

    /// Register a job and mint its private quorum (`expect_workers` +
    /// `expect_servers` must connect before the job's launch barrier
    /// opens). Errors loudly on a duplicate id: a double-registered job
    /// would silently share (and corrupt) another job's live set.
    pub fn register_job(
        &self,
        job: u64,
        expect_workers: usize,
        expect_servers: usize,
    ) -> anyhow::Result<Scheduler> {
        let mut jobs = self.jobs.lock().expect("cluster scheduler lock poisoned");
        anyhow::ensure!(
            !jobs.contains_key(&job),
            "job {job} is already registered with the cluster scheduler"
        );
        let sched = Scheduler::new(expect_workers, expect_servers);
        jobs.insert(job, sched.handle());
        Ok(sched)
    }

    /// Retire a completed job; returns whether it was registered.
    pub fn finish_job(&self, job: u64) -> bool {
        self.jobs.lock().expect("cluster scheduler lock poisoned").remove(&job).is_some()
    }

    /// Registered job ids, ascending.
    pub fn job_ids(&self) -> Vec<u64> {
        self.jobs.lock().expect("cluster scheduler lock poisoned").keys().copied().collect()
    }

    /// A job's most recent membership view (None if not registered).
    pub fn view(&self, job: u64) -> Option<MembershipView> {
        self.jobs.lock().expect("cluster scheduler lock poisoned").get(&job).map(|s| s.view())
    }

    /// Live workers summed across every registered job — the authority's
    /// cluster-wide occupancy count.
    pub fn live_workers(&self) -> usize {
        self.jobs
            .lock()
            .expect("cluster scheduler lock poisoned")
            .values()
            .map(|s| s.view().workers.len())
            .sum()
    }
}

// ---------------------------------------------------------------------------
// FaultPlan — scripted churn (config/CLI: `--fault kill:3@200,join@300`)
// ---------------------------------------------------------------------------

/// What happens to the membership at a scripted point in training.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Worker `rank` leaves the job (fail-stop at the next membership
    /// epoch — the cloud-preemption model).
    Kill { rank: usize },
    /// Worker `rank` slows down by `factor` (>= 1.0) from here on.
    Straggle { rank: usize, factor: f64 },
    /// A new worker joins, assigned to `client` (None = the client with
    /// the fewest live members). It bootstraps from the PS checkpoint, or
    /// by peer broadcast when there are no servers.
    Join { client: Option<usize> },
}

/// One scripted churn event, effective at the first membership-epoch
/// boundary at or after `at_iter`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_iter: u64,
    pub kind: FaultKind,
}

/// A scripted churn schedule. Grammar (comma-separated events):
///
/// ```text
/// kill:R@N           worker rank R leaves at iteration N
/// straggle:R@NxF     worker rank R runs F x slower from iteration N
/// join@N             a worker joins at iteration N (auto-assigned client)
/// join:C@N           a worker joins client C at iteration N
/// ```
///
/// e.g. `kill:3@200,straggle:2@100x4,join@300`. Events are kept sorted by
/// iteration (stable for ties).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the `--fault` grammar; empty string = no churn.
    pub fn parse(s: &str) -> Result<Self> {
        let mut events = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            events.push(Self::parse_event(part).with_context(|| {
                format!(
                    "bad fault event {part:?} (grammar: kill:R@N | straggle:R@NxF | join@N | join:C@N)"
                )
            })?);
        }
        events.sort_by_key(|e| e.at_iter);
        Ok(Self { events })
    }

    fn parse_event(part: &str) -> Result<FaultEvent> {
        let (head, at) = part
            .split_once('@')
            .context("missing '@iter'")?;
        if let Some(rank) = head.strip_prefix("kill:") {
            let rank = rank.trim().parse::<usize>().context("kill rank")?;
            let at_iter = at.trim().parse::<u64>().context("iteration")?;
            return Ok(FaultEvent { at_iter, kind: FaultKind::Kill { rank } });
        }
        if let Some(rank) = head.strip_prefix("straggle:") {
            let rank = rank.trim().parse::<usize>().context("straggle rank")?;
            let (iter, factor) = at
                .split_once('x')
                .context("straggle needs '@NxF'")?;
            let at_iter = iter.trim().parse::<u64>().context("iteration")?;
            let factor = factor.trim().parse::<f64>().context("straggle factor")?;
            if !(factor >= 1.0 && factor.is_finite()) {
                bail!("straggle factor must be >= 1.0, got {factor}");
            }
            return Ok(FaultEvent { at_iter, kind: FaultKind::Straggle { rank, factor } });
        }
        if head == "join" || head.starts_with("join:") {
            let client = match head.strip_prefix("join:") {
                Some(c) if !c.trim().is_empty() => {
                    Some(c.trim().parse::<usize>().context("join client")?)
                }
                _ => None,
            };
            let at_iter = at.trim().parse::<u64>().context("iteration")?;
            return Ok(FaultEvent { at_iter, kind: FaultKind::Join { client } });
        }
        bail!("unknown event kind")
    }

    /// Number of `join` events (the launcher pre-spawns one worker each).
    pub fn n_joins(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Join { .. }))
            .count()
    }

    /// Largest event iteration (None when the plan is empty) — used to
    /// validate that every event fires within a run's iteration budget.
    pub fn last_iter(&self) -> Option<u64> {
        self.events.iter().map(|e| e.at_iter).max()
    }

    /// Render back to the grammar (config round-trip).
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Kill { rank } => format!("kill:{rank}@{}", e.at_iter),
                FaultKind::Straggle { rank, factor } => {
                    format!("straggle:{rank}@{}x{factor}", e.at_iter)
                }
                FaultKind::Join { client: Some(c) } => format!("join:{c}@{}", e.at_iter),
                FaultKind::Join { client: None } => format!("join@{}", e.at_iter),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::{Elastic1, Sgd, SgdHyper};
    use std::thread;

    #[test]
    fn sync_server_aggregates_before_update() {
        let group = ServerGroup::spawn(1, SyncMode::Sync, 3);
        let clients: Vec<PsClient> = (0..3).map(|_| group.client()).collect();
        clients[0].init(0, vec![1.0, 1.0]);
        // Plain SGD lr=0.1, rescale=1: w -= 0.1 * sum(grads).
        clients[0].set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(0.1, 1.0))));
        let hs: Vec<_> = clients
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    c.push(0, vec![1.0, 2.0]);
                    c.pull(0)
                })
            })
            .collect();
        for h in hs {
            let v = h.join().unwrap();
            // sum = [3, 6]; w = [1,1] - 0.1*[3,6] = [0.7, 0.4]
            assert!((v[0] - 0.7).abs() < 1e-6 && (v[1] - 0.4).abs() < 1e-6, "{v:?}");
        }
        group.shutdown();
    }

    #[test]
    fn sync_rounds_do_not_deadlock_with_fast_worker() {
        // Two workers race multiple rounds; round accounting must keep
        // every pull matched to its own round (no deadlock, exact result).
        let group = ServerGroup::spawn(1, SyncMode::Sync, 2);
        let c0 = group.client();
        c0.init(0, vec![0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let mut c = group.client();
                thread::spawn(move || {
                    let mut outs = Vec::new();
                    for _ in 0..3 {
                        c.push(0, vec![1.0]);
                        outs.push(c.pull(0)[0]);
                    }
                    outs
                })
            })
            .collect();
        for h in hs {
            let outs = h.join().unwrap();
            // Each round subtracts 2.0; values are monotone non-increasing
            // and the final round is exact.
            assert!(outs.windows(2).all(|w| w[1] <= w[0]), "{outs:?}");
            assert_eq!(outs[2], -6.0);
        }
        group.shutdown();
    }

    #[test]
    fn compressed_push_decodes_before_aggregation() {
        use crate::compress::{Compressor, Int8, TopK, INT8_BUCKET};
        // A sync round mixing one dense and one compressed push must
        // aggregate the *decoded* gradient (within codec tolerance).
        let group = ServerGroup::spawn(1, SyncMode::Sync, 2);
        let mut c = group.client();
        c.init(0, vec![0.0, 0.0, 0.0]);
        c.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let g = vec![1.0f32, -2.0, 0.5];
        c.push(0, g.clone());
        let mut c2 = group.client();
        let wire = Int8 { bucket: INT8_BUCKET }.compress(&g).to_wire();
        c2.push_compressed(0, wire);
        let v = c.pull(0);
        for (vi, gi) in v.iter().zip(&g) {
            // w = 0 - (g + decode(g)): decode error <= maxabs/254.
            let want = -2.0 * gi;
            assert!((vi - want).abs() < 0.02, "{v:?}");
        }
        // Compressed pushes racing ahead of init replay like dense ones.
        let mut c3 = group.client();
        let mut c4 = group.client();
        let wire = TopK { ratio: 1.0 }.compress(&[4.0, 0.0]).to_wire();
        c3.push_compressed(9, wire);
        c4.push(9, vec![1.0, 1.0]);
        c3.init(9, vec![0.0, 0.0]);
        assert_eq!(c4.pull(9), vec![-5.0, -1.0]);
        group.shutdown();
    }

    #[test]
    fn async_server_applies_immediately() {
        let group = ServerGroup::spawn(1, SyncMode::Async, 99);
        let mut c = group.client();
        c.init(0, vec![0.0]);
        c.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        c.push(0, vec![2.0]);
        assert_eq!(c.pull(0), vec![-2.0]);
        c.push(0, vec![1.0]);
        assert_eq!(c.pull(0), vec![-3.0]);
        group.shutdown();
    }

    #[test]
    fn keys_shard_across_servers() {
        let group = ServerGroup::spawn(2, SyncMode::Async, 1);
        let mut c = group.client();
        for k in 0..6 {
            c.init(k, vec![k as f32]);
        }
        for k in 0..6 {
            assert_eq!(c.pull(k), vec![k as f32]);
        }
        group.shutdown();
    }

    #[test]
    fn elastic1_on_server_moves_center() {
        let group = ServerGroup::spawn(1, SyncMode::Async, 1);
        let mut c = group.client();
        c.init(0, vec![0.0, 0.0]); // center
        c.set_optimizer(|| Box::new(Elastic1 { alpha: 0.5 }));
        c.push(0, vec![4.0, -2.0]); // client weights
        assert_eq!(c.pull(0), vec![2.0, -1.0]); // c + 0.5(w - c)
        group.shutdown();
    }

    #[test]
    fn initial_pull_without_push_answers_immediately() {
        let group = ServerGroup::spawn(1, SyncMode::Sync, 4);
        let mut c = group.client();
        c.init(3, vec![7.0]);
        assert_eq!(c.pull(3), vec![7.0]);
        group.shutdown();
    }

    #[test]
    fn shrinking_quorum_completes_waiting_round() {
        // 3 expected pushes, only 2 arrive (the third worker "died"); a
        // parked pull would wedge forever. SetExpectedPushes(2) after the
        // membership epoch must complete the round and release the pull.
        let group = ServerGroup::spawn(1, SyncMode::Sync, 3);
        let mut c = group.client();
        c.init(0, vec![0.0]);
        c.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        c.push(0, vec![1.0]);
        let mut c2 = group.client();
        c2.push(0, vec![1.0]);
        // Park a pull for round 1 on a helper thread.
        let h = thread::spawn(move || c.pull(0));
        thread::sleep(std::time::Duration::from_millis(20));
        c2.set_expected_pushes(2);
        assert_eq!(h.join().unwrap(), vec![-2.0]);
        group.shutdown();
    }

    #[test]
    fn growing_quorum_applies_to_next_round() {
        let group = ServerGroup::spawn(1, SyncMode::Sync, 1);
        let mut c = group.client();
        c.init(0, vec![0.0]);
        c.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        c.push(0, vec![1.0]);
        assert_eq!(c.pull(0), vec![-1.0]);
        c.set_expected_pushes(2);
        let mut c2 = group.client();
        c.push(0, vec![1.0]);
        c2.push(0, vec![1.0]);
        assert_eq!(c.pull(0), vec![-3.0]);
        group.shutdown();
    }

    #[test]
    fn checkpoint_blobs_round_trip_and_overwrite() {
        let group = ServerGroup::spawn(2, SyncMode::Sync, 1);
        let c = group.client();
        assert_eq!(c.load_blob(5), None);
        c.save_blob(5, vec![1.0, 2.0]);
        c.save_blob(6, vec![3.0]);
        assert_eq!(c.load_blob(5), Some(vec![1.0, 2.0]));
        assert_eq!(c.load_blob(6), Some(vec![3.0]));
        c.save_blob(5, vec![9.0]); // last write wins
        assert_eq!(c.load_blob(5), Some(vec![9.0]));
        // Blobs are a separate namespace: key 5 of the store is untouched.
        c.init(5, vec![0.5]);
        let mut c2 = group.client();
        assert_eq!(c2.pull(5), vec![0.5]);
        assert_eq!(c2.load_blob(5), Some(vec![9.0]));
        group.shutdown();
    }

    #[test]
    fn server_group_shutdown_is_idempotent_and_drop_safe() {
        // Dropping without shutdown must join the threads (no wedge)...
        {
            let group = ServerGroup::spawn(2, SyncMode::Async, 1);
            let mut c = group.client();
            c.init(0, vec![1.0]);
            assert_eq!(c.pull(0), vec![1.0]);
        } // ...Drop runs here.
          // And explicit shutdown followed by Drop must not double-join.
        let group = ServerGroup::spawn(1, SyncMode::Async, 1);
        group.shutdown();
    }

    #[test]
    fn scheduler_membership_views_track_churn() {
        let sched = Scheduler::new(0, 0);
        for r in 0..3 {
            sched.admit(r);
        }
        let v0 = sched.publish_view();
        assert_eq!(v0.workers, vec![0, 1, 2]);
        sched.deregister(1);
        sched.admit(7);
        let v1 = sched.publish_view();
        assert_eq!(v1.epoch, v0.epoch + 1);
        assert_eq!(v1.workers, vec![0, 2, 7]);
        assert_eq!(sched.view(), v1);
    }

    #[test]
    fn fault_plan_parses_and_round_trips() {
        let p = FaultPlan::parse("kill:3@200, straggle:2@100x4, join@300,join:1@50").unwrap();
        assert_eq!(p.events.len(), 4);
        // Sorted by iteration.
        assert_eq!(p.events[0].kind, FaultKind::Join { client: Some(1) });
        assert_eq!(p.events[1].kind, FaultKind::Straggle { rank: 2, factor: 4.0 });
        assert_eq!(p.events[2].kind, FaultKind::Kill { rank: 3 });
        assert_eq!(p.events[3].kind, FaultKind::Join { client: None });
        assert_eq!(p.n_joins(), 2);
        assert_eq!(p.last_iter(), Some(300));
        let rendered = p.render();
        assert_eq!(FaultPlan::parse(&rendered).unwrap(), p);
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_rejects_garbage() {
        for bad in [
            "kill:3",          // missing @iter
            "kill:x@5",        // bad rank
            "straggle:1@5",    // missing factor
            "straggle:1@5x0.5",// factor < 1
            "explode:1@5",     // unknown kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn scheduler_assigns_ranks_and_barriers() {
        let sched = Scheduler::new(3, 1);
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = sched.handle();
                thread::spawn(move || s.register(Role::Worker))
            })
            .chain(std::iter::once({
                let s = sched.handle();
                thread::spawn(move || 100 + s.register(Role::Server))
            }))
            .collect();
        let mut ranks: Vec<usize> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        ranks.sort();
        assert_eq!(ranks, vec![0, 1, 2, 100]);
    }

    #[test]
    fn cluster_scheduler_quorums_are_independent_per_job() {
        // Job 7's 2-worker barrier must open while job 9 (expecting 3) is
        // still short — one job's stragglers never block another job.
        let cluster = ClusterScheduler::new();
        let j7 = cluster.register_job(7, 2, 0).unwrap();
        let _j9 = cluster.register_job(9, 3, 0).unwrap();
        let hs: Vec<_> = (0..2)
            .map(|r| {
                let s = j7.handle();
                thread::spawn(move || s.register_as(r))
            })
            .collect();
        for h in hs {
            h.join().unwrap(); // returns => job 7's barrier opened
        }
        assert_eq!(cluster.view(7).unwrap().workers, vec![0, 1]);
        assert_eq!(cluster.view(9).unwrap().workers, Vec::<usize>::new());
        assert_eq!(cluster.live_workers(), 2);
        assert_eq!(cluster.job_ids(), vec![7, 9]);
        assert!(cluster.finish_job(9));
        assert!(!cluster.finish_job(9));
    }

    #[test]
    fn cluster_scheduler_rejects_duplicate_job_ids() {
        let cluster = ClusterScheduler::new();
        cluster.register_job(1, 2, 0).unwrap();
        let err = cluster.register_job(1, 4, 0).unwrap_err().to_string();
        assert!(err.contains("already registered"), "{err}");
    }
}
