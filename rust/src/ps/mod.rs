//! Parameter-server substrate: scheduler, servers, worker-side client.
//!
//! Mirrors MXNET's PS architecture (§3.2, §4.1): a *scheduler* task that
//! every worker/server registers with, N *server* tasks each owning a shard
//! of the KVStore (key -> server by modulo, like ps-lite key sharding), and
//! worker-side `ZPush`/`ZPull` primitives. Transport is in-process channels
//! (the LSF/TCP substitution, DESIGN.md §2); the protocol — registration,
//! per-key aggregation rounds, optimizer shipped to the server via
//! `set_optimizer` — follows the paper.
//!
//! Synchronous mode: a server aggregates `expected_pushes` gradients per
//! key per round, applies the shipped optimizer once, then answers the
//! round's pulls. Pulls carry the worker's push round so a fast worker
//! can never steal a slow worker's round (no deadlock, no silent
//! staleness) — see `ServerMsg::Pull::after_round`.
//!
//! Asynchronous mode: every push is applied immediately (the §2.3
//! staleness regime); pulls answer with whatever is current.

use crate::optimizer::Optimizer;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

pub type Key = usize;

/// Server aggregation discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Aggregate `expected_pushes` per key, update once, release pulls.
    Sync,
    /// Apply every push immediately.
    Async,
}

enum ServerMsg {
    /// Initialize a key (rank 0 in the PS namespace does this, §4.2.1).
    Init { key: Key, value: Vec<f32> },
    /// Push a gradient (or weights, for elastic averaging) for a key.
    Push { key: Key, data: Vec<f32> },
    /// Pull the value of a key once `after_round` rounds have completed
    /// (workers pass their own push count; async mode ignores it).
    Pull { key: Key, after_round: u64, reply: Sender<Vec<f32>> },
    /// Ship an optimizer to the server (KVStore.set_optimizer, §3.2).
    SetOptimizer(Box<dyn Optimizer>),
    Shutdown,
}

/// One PS server task: owns its key shard, runs on its own thread.
struct ServerState {
    mode: SyncMode,
    expected_pushes: usize,
    optimizer: Box<dyn Optimizer>,
    store: HashMap<Key, Vec<f32>>,
    /// Per-key gradient aggregation buffer + count (sync mode).
    agg: HashMap<Key, (Vec<f32>, usize)>,
    /// Completed aggregation rounds per key.
    rounds: HashMap<Key, u64>,
    /// Pulls parked until their round completes: key -> (round, reply).
    parked: HashMap<Key, Vec<(u64, Sender<Vec<f32>>)>>,
    /// Messages that raced ahead of their key's Init (workers may push as
    /// soon as the scheduler releases the job, §4.1.2); replayed on Init.
    pre_init: HashMap<Key, Vec<ServerMsg>>,
}

impl ServerState {
    fn on_push(&mut self, key: Key, data: Vec<f32>) {
        match self.mode {
            SyncMode::Async => {
                let w = self.store.get_mut(&key).expect("push before init");
                self.optimizer.update(key, w, &data);
                *self.rounds.entry(key).or_insert(0) += 1;
                self.release(key);
            }
            SyncMode::Sync => {
                let (buf, count) = self.agg.entry(key).or_insert_with(|| (Vec::new(), 0));
                if buf.is_empty() {
                    *buf = data;
                } else {
                    crate::tensor::add_assign(buf, &data);
                }
                *count += 1;
                if *count >= self.expected_pushes {
                    let (buf, _) = self.agg.remove(&key).unwrap();
                    let w = self.store.get_mut(&key).expect("push before init");
                    self.optimizer.update(key, w, &buf);
                    *self.rounds.entry(key).or_insert(0) += 1;
                    self.release(key);
                }
            }
        }
    }

    fn release(&mut self, key: Key) {
        let done = *self.rounds.get(&key).unwrap_or(&0);
        if let Some(parked) = self.parked.get_mut(&key) {
            let mut keep = Vec::new();
            for (round, reply) in parked.drain(..) {
                if round <= done {
                    let _ = reply.send(self.store[&key].clone());
                } else {
                    keep.push((round, reply));
                }
            }
            *parked = keep;
        }
    }

    fn on_pull(&mut self, key: Key, after_round: u64, reply: Sender<Vec<f32>>) {
        let done = *self.rounds.get(&key).unwrap_or(&0);
        let ready = match self.mode {
            SyncMode::Async => true,
            SyncMode::Sync => after_round <= done,
        };
        if ready {
            let _ = reply.send(self.store.get(&key).expect("pull before init").clone());
        } else {
            self.parked.entry(key).or_default().push((after_round, reply));
        }
    }

    fn handle(&mut self, msg: ServerMsg) -> bool {
        match msg {
            ServerMsg::Init { key, value } => {
                self.store.insert(key, value);
                // Replay anything that raced ahead of the init.
                if let Some(queued) = self.pre_init.remove(&key) {
                    for m in queued {
                        self.handle(m);
                    }
                }
            }
            ServerMsg::Push { key, data } => {
                if self.store.contains_key(&key) {
                    self.on_push(key, data);
                } else {
                    self.pre_init
                        .entry(key)
                        .or_default()
                        .push(ServerMsg::Push { key, data });
                }
            }
            ServerMsg::Pull { key, after_round, reply } => {
                if self.store.contains_key(&key) {
                    self.on_pull(key, after_round, reply);
                } else {
                    self.pre_init
                        .entry(key)
                        .or_default()
                        .push(ServerMsg::Pull { key, after_round, reply });
                }
            }
            ServerMsg::SetOptimizer(opt) => self.optimizer = opt,
            ServerMsg::Shutdown => return false,
        }
        true
    }

    fn run(mut self, rx: Receiver<ServerMsg>) {
        while let Ok(msg) = rx.recv() {
            if !self.handle(msg) {
                break;
            }
        }
    }
}

/// Handle to a running group of PS server threads.
pub struct ServerGroup {
    txs: Vec<Sender<ServerMsg>>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerGroup {
    /// Spawn `n_servers` server tasks. `expected_pushes` is the number of
    /// pushes per key per sync round (= #workers for dist modes, #clients
    /// for MPI modes — the §4 contention knob).
    pub fn spawn(n_servers: usize, mode: SyncMode, expected_pushes: usize) -> Self {
        let mut txs = Vec::new();
        let mut threads = Vec::new();
        for s in 0..n_servers {
            let (tx, rx) = channel();
            let state = ServerState {
                mode,
                expected_pushes: expected_pushes.max(1),
                optimizer: Box::new(crate::optimizer::Sgd::new(
                    crate::optimizer::SgdHyper::plain(0.1, 1.0),
                )),
                store: HashMap::new(),
                agg: HashMap::new(),
                rounds: HashMap::new(),
                parked: HashMap::new(),
                pre_init: HashMap::new(),
            };
            threads.push(
                std::thread::Builder::new()
                    .name(format!("ps-server-{s}"))
                    .spawn(move || state.run(rx))
                    .expect("spawn server"),
            );
            txs.push(tx);
        }
        Self { txs, threads }
    }

    pub fn n_servers(&self) -> usize {
        self.txs.len()
    }

    /// A worker-side client endpoint.
    pub fn client(&self) -> PsClient {
        PsClient { servers: self.txs.clone(), push_rounds: HashMap::new() }
    }

    /// Stop all server threads (remaining messages are processed first).
    pub fn shutdown(mut self) {
        for tx in &self.txs {
            let _ = tx.send(ServerMsg::Shutdown);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Worker-side PS endpoint: ZPush / ZPull over the sharded servers.
///
/// Keys are routed `key % n_servers` (ps-lite style). The client tracks its
/// own per-key push count so synchronous pulls wait for exactly the round
/// this worker contributed to.
#[derive(Clone)]
pub struct PsClient {
    servers: Vec<Sender<ServerMsg>>,
    push_rounds: HashMap<Key, u64>,
}

impl PsClient {
    fn server(&self, key: Key) -> &Sender<ServerMsg> {
        &self.servers[key % self.servers.len()]
    }

    /// Initialize a key on its server (call once, from PS rank 0).
    pub fn init(&self, key: Key, value: Vec<f32>) {
        self.server(key)
            .send(ServerMsg::Init { key, value })
            .expect("server gone");
    }

    /// ZPush: send a gradient/weight contribution for `key`.
    pub fn push(&mut self, key: Key, data: Vec<f32>) {
        *self.push_rounds.entry(key).or_insert(0) += 1;
        self.server(key)
            .send(ServerMsg::Push { key, data })
            .expect("server gone");
    }

    /// ZPull: fetch the value of `key`; in sync mode waits until the round
    /// containing this worker's last push has been applied.
    pub fn pull(&mut self, key: Key) -> Vec<f32> {
        let (reply, rx) = channel();
        let after_round = *self.push_rounds.get(&key).unwrap_or(&0);
        self.server(key)
            .send(ServerMsg::Pull { key, after_round, reply })
            .expect("server gone");
        rx.recv().expect("server dropped pull")
    }

    /// Ship an optimizer to every server (KVStore.set_optimizer).
    pub fn set_optimizer<F>(&self, factory: F)
    where
        F: Fn() -> Box<dyn Optimizer>,
    {
        for tx in &self.servers {
            tx.send(ServerMsg::SetOptimizer(factory())).expect("server gone");
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduler — the registration/rendezvous task (§4.1.2)
// ---------------------------------------------------------------------------

/// The MXNET scheduler task: launched first, listens for every worker and
/// server, assigns ranks in the PS namespace and releases the job once the
/// expected population is connected. In-process the "address broadcast" is
/// the `Arc` itself; the protocol (register -> barrier until complete) is
/// the paper's.
pub struct Scheduler {
    inner: Arc<(Mutex<SchedState>, std::sync::Condvar)>,
}

#[derive(Default)]
struct SchedState {
    workers: usize,
    servers: usize,
    expect_workers: usize,
    expect_servers: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Worker,
    Server,
}

impl Scheduler {
    pub fn new(expect_workers: usize, expect_servers: usize) -> Self {
        Self {
            inner: Arc::new((
                Mutex::new(SchedState {
                    expect_workers,
                    expect_servers,
                    ..Default::default()
                }),
                std::sync::Condvar::new(),
            )),
        }
    }

    /// Register a task; returns its rank within its role's namespace.
    /// Blocks until the whole job population has registered (the paper's
    /// connection-establishment barrier).
    pub fn register(&self, role: Role) -> usize {
        let (lock, cv) = &*self.inner;
        let mut st = lock.lock().unwrap();
        let rank = match role {
            Role::Worker => {
                st.workers += 1;
                st.workers - 1
            }
            Role::Server => {
                st.servers += 1;
                st.servers - 1
            }
        };
        cv.notify_all();
        while st.workers < st.expect_workers || st.servers < st.expect_servers {
            st = cv.wait(st).unwrap();
        }
        rank
    }

    pub fn handle(&self) -> Scheduler {
        Scheduler { inner: self.inner.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::{Elastic1, Sgd, SgdHyper};
    use std::thread;

    #[test]
    fn sync_server_aggregates_before_update() {
        let group = ServerGroup::spawn(1, SyncMode::Sync, 3);
        let clients: Vec<PsClient> = (0..3).map(|_| group.client()).collect();
        clients[0].init(0, vec![1.0, 1.0]);
        // Plain SGD lr=0.1, rescale=1: w -= 0.1 * sum(grads).
        clients[0].set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(0.1, 1.0))));
        let hs: Vec<_> = clients
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    c.push(0, vec![1.0, 2.0]);
                    c.pull(0)
                })
            })
            .collect();
        for h in hs {
            let v = h.join().unwrap();
            // sum = [3, 6]; w = [1,1] - 0.1*[3,6] = [0.7, 0.4]
            assert!((v[0] - 0.7).abs() < 1e-6 && (v[1] - 0.4).abs() < 1e-6, "{v:?}");
        }
        group.shutdown();
    }

    #[test]
    fn sync_rounds_do_not_deadlock_with_fast_worker() {
        // Two workers race multiple rounds; round accounting must keep
        // every pull matched to its own round (no deadlock, exact result).
        let group = ServerGroup::spawn(1, SyncMode::Sync, 2);
        let c0 = group.client();
        c0.init(0, vec![0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let mut c = group.client();
                thread::spawn(move || {
                    let mut outs = Vec::new();
                    for _ in 0..3 {
                        c.push(0, vec![1.0]);
                        outs.push(c.pull(0)[0]);
                    }
                    outs
                })
            })
            .collect();
        for h in hs {
            let outs = h.join().unwrap();
            // Each round subtracts 2.0; values are monotone non-increasing
            // and the final round is exact.
            assert!(outs.windows(2).all(|w| w[1] <= w[0]), "{outs:?}");
            assert_eq!(outs[2], -6.0);
        }
        group.shutdown();
    }

    #[test]
    fn async_server_applies_immediately() {
        let group = ServerGroup::spawn(1, SyncMode::Async, 99);
        let mut c = group.client();
        c.init(0, vec![0.0]);
        c.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        c.push(0, vec![2.0]);
        assert_eq!(c.pull(0), vec![-2.0]);
        c.push(0, vec![1.0]);
        assert_eq!(c.pull(0), vec![-3.0]);
        group.shutdown();
    }

    #[test]
    fn keys_shard_across_servers() {
        let group = ServerGroup::spawn(2, SyncMode::Async, 1);
        let mut c = group.client();
        for k in 0..6 {
            c.init(k, vec![k as f32]);
        }
        for k in 0..6 {
            assert_eq!(c.pull(k), vec![k as f32]);
        }
        group.shutdown();
    }

    #[test]
    fn elastic1_on_server_moves_center() {
        let group = ServerGroup::spawn(1, SyncMode::Async, 1);
        let mut c = group.client();
        c.init(0, vec![0.0, 0.0]); // center
        c.set_optimizer(|| Box::new(Elastic1 { alpha: 0.5 }));
        c.push(0, vec![4.0, -2.0]); // client weights
        assert_eq!(c.pull(0), vec![2.0, -1.0]); // c + 0.5(w - c)
        group.shutdown();
    }

    #[test]
    fn initial_pull_without_push_answers_immediately() {
        let group = ServerGroup::spawn(1, SyncMode::Sync, 4);
        let mut c = group.client();
        c.init(3, vec![7.0]);
        assert_eq!(c.pull(3), vec![7.0]);
        group.shutdown();
    }

    #[test]
    fn scheduler_assigns_ranks_and_barriers() {
        let sched = Scheduler::new(3, 1);
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = sched.handle();
                thread::spawn(move || s.register(Role::Worker))
            })
            .chain(std::iter::once({
                let s = sched.handle();
                thread::spawn(move || 100 + s.register(Role::Server))
            }))
            .collect();
        let mut ranks: Vec<usize> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        ranks.sort();
        assert_eq!(ranks, vec![0, 1, 2, 100]);
    }
}
