//! KVStore-MPI: the paper's hybrid API (§4.2).
//!
//! `KVStore.create("type")` supports the paper's five types:
//!
//! | type            | push                              | pull              |
//! |-----------------|-----------------------------------|-------------------|
//! | `local`         | in-process accumulate             | read              |
//! | `dist_sync`     | ZPush to PS (server aggregates)   | ZPull             |
//! | `dist_async`    | ZPush, applied immediately        | ZPull             |
//! | `sync_mpi`      | ring-allreduce in client, master ZPush | master ZPull + bcast |
//! | `async_mpi`     | same, but the PS side is async    | same              |
//!
//! With `#servers == 0` the fused [`KvWorker::pushpull`] degrades to a pure
//! MPI tensor allreduce (§4.2.4) — the `mpi-SGD` pure mode of Fig. 15/16.
//!
//! Faithful to Figs 4–5, every operation is a closure pushed into the
//! dataflow [`Engine`](crate::engine::Engine) with explicit dependencies:
//! per-key vars order operations on the same key, and a per-worker *comm
//! var* serializes all MPI/PS communication in program order — the paper's
//! "operations are enqueued in order to avoid deadlocks" (§4.2).
//!
//! Intra-client aggregation goes through the pluggable collective layer
//! ([`crate::collectives::AlgoKind`]): ring, halving-doubling,
//! hierarchical, or the per-message autotuner (`Auto`). Small per-key
//! gradients can be coalesced into fused buckets before dispatch
//! ([`KvWorker::pushpull_fused`], cap [`KvWorker::fusion_bytes`]).
//!
//! The gradient-compression plane ([`crate::compress`]) rides the same
//! paths: with a lossy codec configured
//! ([`KvWorker::configure_compression`]), intra-client exchanges run the
//! compressed allgather-reduce, masters push codec wire payloads the
//! servers decode before aggregating, and every lossy hop keeps an
//! error-feedback residual. The identity codec (default) is
//! regression-pinned to the bitwise pre-compression paths.
//!
//! Init discipline (matching the PS servers' pre_init replay): a `push`
//! that races ahead of its key's `init` is buffered and folded into the
//! init value; a `pull` of a never-initialized key is a programming error
//! and panics with a clear message.

use crate::collectives::{
    allreduce_with, compressed_allreduce, fused_allreduce_compressed_with_arena,
    tensor_allreduce_with, AlgoKind, FusionArena, HostReduce,
};
use crate::compress::{ef_compress, Codec, Compressor, EfState};
use crate::engine::{Engine, Var};
use crate::mpisim::Comm;
use crate::netsim::CostParams;
use crate::optimizer::Optimizer;
use crate::ps::{Key, PsClient};
use crate::tensor::NodeTensor;
use crate::util::sync::{channel, channel_named, Mutex, Receiver};
use std::collections::HashMap;
use std::sync::Arc;

/// KVStore flavor (KVStore.create("type"), §4.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvType {
    Local,
    DistSync,
    DistAsync,
    SyncMpi,
    AsyncMpi,
}

impl KvType {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "local" => KvType::Local,
            "dist_sync" => KvType::DistSync,
            "dist_async" => KvType::DistAsync,
            "sync_mpi" | "Synchronous-MPI" => KvType::SyncMpi,
            "async_mpi" | "Asynchronous-MPI" => KvType::AsyncMpi,
            _ => return None,
        })
    }

    pub fn is_mpi(&self) -> bool {
        matches!(self, KvType::SyncMpi | KvType::AsyncMpi)
    }
}

/// The engine's per-bucket *issue plan*: [`crate::collectives::fusion_buckets`]
/// over the key lengths, reversed into issue order. Backprop emits the last
/// layer's gradients first, so buckets are issued back to front; the comm
/// var then serializes the collectives in exactly this order (§4.2 deadlock
/// rule). A pure function of `(lens, fusion_bytes)` so every rank derives
/// the identical plan — the static verifier
/// ([`crate::analysis::check_engine_plans`]) proves coverage, disjointness
/// and issue-order over this function, and [`KvWorker::pushpull_buckets`]
/// issues engine ops from it.
pub fn bucket_issue_plan(lens: &[usize], fusion_bytes: usize) -> Vec<(usize, usize)> {
    let mut plan = crate::collectives::fusion_buckets(lens, fusion_bytes);
    plan.reverse();
    plan
}

/// A value still being produced by the engine; `wait()` blocks for it.
///
/// The primary backing is a **dependency-engine wait** (Figs 4–5 taken to
/// their conclusion): the producing op fills a shared slot and `wait()`
/// blocks on [`Engine::wait_var`] for the op's read/mutate vars — the
/// caller parks *inside the dependency engine*, not on a reply channel, so
/// completion is ordered exactly like any other DAG dependency. Fallback
/// composition paths (e.g. fused pushpull over a PS) still use a channel.
pub struct Pending<T>(PendingInner<T>);

enum PendingInner<T> {
    Engine {
        slot: Arc<Mutex<Option<T>>>,
        engine: Arc<Engine>,
        /// Vars whose quiescence signals the producing op completed.
        vars: Vec<Var>,
    },
    Channel(Receiver<T>),
}

impl<T> Pending<T> {
    /// Engine-backed pending: returns the handle plus the slot the
    /// producing op must fill. The op MUST be pushed with every var in
    /// `vars` among its read/mutate dependencies.
    fn engine_backed(engine: Arc<Engine>, vars: Vec<Var>) -> (Self, Arc<Mutex<Option<T>>>) {
        let slot = Arc::new(Mutex::named(None, "kv.pending_slot"));
        (Pending(PendingInner::Engine { slot: slot.clone(), engine, vars }), slot)
    }

    fn channel(rx: Receiver<T>) -> Self {
        Pending(PendingInner::Channel(rx))
    }

    pub fn wait(self) -> T {
        match self.0 {
            PendingInner::Engine { slot, engine, vars } => {
                for v in &vars {
                    engine.wait_var(*v);
                }
                slot.lock().expect("pending-result slot lock poisoned").take().unwrap_or_else(|| {
                    panic!(
                        "KVStore engine op completed without producing a result: \
                         the op panicked or was dropped before filling its slot"
                    )
                })
            }
            PendingInner::Channel(rx) => rx.recv().unwrap_or_else(|_| {
                panic!(
                    "KVStore reply channel disconnected before a value arrived: \
                     the worker/server thread or engine op backing this Pending \
                     died (server shutdown, worker panic, or dropped op)"
                )
            }),
        }
    }
}

/// One worker's KVStore endpoint.
pub struct KvWorker {
    pub ktype: KvType,
    engine: Arc<Engine>,
    /// This worker's MPI endpoint within its client (None for dist/local).
    comm: Option<Arc<Mutex<Comm>>>,
    /// PS endpoint (None for local or pure-MPI jobs).
    ps: Option<Arc<Mutex<PsClient>>>,
    /// Local store (Local type).
    local: Arc<Mutex<HashMap<Key, Vec<f32>>>>,
    /// Pushes that raced ahead of their key's `init` (mirrors the PS
    /// servers' pre_init replay, §4.1.2): buffered and folded in on init.
    /// Lock order is always `local` then `local_pre_init`.
    local_pre_init: Arc<Mutex<HashMap<Key, Vec<Vec<f32>>>>>,
    /// Checkpoint blobs kept in-worker when there is no PS to persist
    /// them (`#servers == 0` degradation of [`KvWorker::ckpt_save`]).
    ckpt_local: Mutex<HashMap<Key, Vec<f32>>>,
    /// Serializes all communication ops in program order (§4.2).
    comm_var: Var,
    /// Per-key dependency tags.
    key_vars: Mutex<HashMap<Key, Var>>,
    /// Rings for the multi-ring tensor allreduce (§6.3.2).
    pub n_rings: usize,
    /// Allreduce schedule for intra-client aggregation (`Auto` consults
    /// the α-β-γ autotuner per message).
    pub algo: AlgoKind,
    /// Group size for the hierarchical schedule (workers per node analog).
    pub group: usize,
    /// Gradient-fusion bucket cap in bytes for [`KvWorker::pushpull_fused`]
    /// (0 disables coalescing).
    pub fusion_bytes: usize,
    /// Cost-model constants the `Auto` schedule tunes against.
    pub cost: CostParams,
    /// Devices per worker (k): the local tier [`KvWorker::local_merge`]
    /// folds k per-device buffers before any wire hop. 1 = no device tier.
    pub devices: usize,
    /// Gradient codec (the compression plane). Identity (the default)
    /// keeps every path bitwise on the pre-compression implementation;
    /// lossy codecs shrink both hops — the intra-client exchange runs the
    /// compressed allgather-reduce, and masters push codec wire payloads
    /// the PS decodes before aggregating.
    codec: Arc<dyn Compressor>,
    /// Error-feedback residuals, one buffer per (namespace | key): what a
    /// lossy codec drops this round is carried into the next compression
    /// of the same buffer.
    ef: Arc<Mutex<EfState>>,
    /// Persistent gather arena for the fused bucket path: sized to the
    /// largest bucket ever pushed, then reused — zero allocations per
    /// push once warm ([`FusionArena::grows`] is the CI-asserted hook).
    arena: Arc<Mutex<FusionArena>>,
}

/// EF-residual namespaces (disjoint from plain KVStore keys): the master's
/// client→PS hop and the fused-bucket path each accumulate their own
/// residuals per key.
const EF_MASTER: u64 = 1 << 40;
const EF_FUSED: u64 = 1 << 41;
/// Whole-model intra-client allreduce ([`KvWorker::client_allreduce`]).
const EF_CLIENT: u64 = 1 << 42;
/// Per-device residuals of the intra-node local tier
/// ([`device_local_merge`]): device d of owner o keys its residual at
/// `EF_DEVICE | o << 8 | d`.
const EF_DEVICE: u64 = 1 << 43;

/// Base EF key for `owner`'s device residuals (device d uses base + d;
/// the 8-bit shift leaves room for 256 devices per owner).
pub fn device_ef_base(owner: u64) -> u64 {
    debug_assert!(owner < (1 << 35), "owner id overflows the EF_DEVICE namespace");
    EF_DEVICE | (owner << 8)
}

/// The local tier of the two-tier kvstore (MXNet's `local` store folded
/// under the `dist` tier, §2.3 topology): merge the k per-device gradient
/// buffers of one worker into the single leader-side buffer that crosses
/// the inter-node hop. Buffers are row-mean gradients over b/k-row device
/// shards, so the merge averages them (fold in device order, then one
/// scale) — the result is the same estimator as a full-b-row step.
///
/// With a lossy codec each device's buffer goes through its own EF
/// round-trip first (residual key `base_key + d`), mirroring real MXNet's
/// 2-bit compression applied at local-kvstore merge time with per-device
/// residual state. A single buffer (k = 1) is returned untouched — bitwise
/// the pre-device-tier path, codec or not: the device tier does not exist,
/// so no device residual may be minted.
pub fn device_local_merge(
    mut bufs: Vec<Vec<f32>>,
    codec: &dyn Compressor,
    ef: &mut EfState,
    base_key: u64,
) -> Vec<f32> {
    assert!(!bufs.is_empty(), "device_local_merge needs at least one device buffer");
    if bufs.len() == 1 {
        return bufs.pop().expect("len checked above");
    }
    let k = bufs.len();
    let mut acc: Option<Vec<f32>> = None;
    for (d, buf) in bufs.into_iter().enumerate() {
        let contrib = if codec.is_identity() {
            buf
        } else {
            crate::compress::ef_roundtrip(codec, base_key + d as u64, &buf, ef)
        };
        match &mut acc {
            None => acc = Some(contrib),
            Some(a) => crate::tensor::add_assign(a, &contrib),
        }
    }
    let mut out = acc.expect("k >= 2 buffers folded");
    let inv = 1.0f32 / k as f32;
    for v in out.iter_mut() {
        *v *= inv;
    }
    out
}

impl KvWorker {
    /// Create a worker endpoint. `comm` is its communicator inside its MPI
    /// client (required for MPI types), `ps` its PS client (required for
    /// dist types; optional for MPI types — None means pure MPI).
    pub fn create(
        ktype: KvType,
        engine: Arc<Engine>,
        comm: Option<Comm>,
        ps: Option<PsClient>,
    ) -> Self {
        assert!(
            !ktype.is_mpi() || comm.is_some(),
            "MPI kvstore types need a communicator"
        );
        assert!(
            !matches!(ktype, KvType::DistSync | KvType::DistAsync) || ps.is_some(),
            "dist kvstore types need a PS client"
        );
        let comm_var = engine.new_var();
        Self {
            ktype,
            engine,
            comm: comm.map(|c| Arc::new(Mutex::named(c, "kv.comm"))),
            ps: ps.map(|p| Arc::new(Mutex::named(p, "kv.ps"))),
            local: Arc::new(Mutex::named(HashMap::new(), "kv.local")),
            local_pre_init: Arc::new(Mutex::named(HashMap::new(), "kv.pre_init")),
            ckpt_local: Mutex::named(HashMap::new(), "kv.ckpt"),
            comm_var,
            key_vars: Mutex::named(HashMap::new(), "kv.key_vars"),
            n_rings: 2,
            algo: AlgoKind::Ring,
            group: 2,
            fusion_bytes: 0,
            cost: CostParams::testbed1(),
            devices: 1,
            codec: Arc::from(Codec::identity().build(0.0)),
            ef: Arc::new(Mutex::named(EfState::new(), "kv.ef")),
            arena: Arc::new(Mutex::named(FusionArena::new(), "kv.arena")),
        }
    }

    /// Growth count of the fused-path gather arena (the per-push
    /// allocation regression hook: constant once warmed up).
    pub fn fusion_arena_grows(&self) -> usize {
        self.arena
            .lock()
            .expect("fusion arena lock poisoned")
            .grows()
    }

    /// Configure the gradient codec (`topk_ratio` is ignored by non-topk
    /// codecs). Identity restores the bitwise pre-compression paths.
    pub fn configure_compression(&mut self, codec: Codec, topk_ratio: f64) {
        self.codec = Arc::from(codec.build(topk_ratio));
    }

    /// Name of the active codec (bench/diagnostics).
    pub fn codec_name(&self) -> &'static str {
        self.codec.name()
    }

    /// Configure the collective layer in one call (used by the launcher).
    ///
    /// The worker communicator spans node *leaders* — one rank per worker
    /// — so the device tier never runs on the wire here: `two_tier`'s
    /// intra leg is the in-process [`KvWorker::local_merge`] and its inter
    /// leg IS the flat ring over this comm. The wire schedule is mapped
    /// accordingly and priced as the leader tier (`devices = 1`): k-way
    /// NIC contention models k device *ranks* behind one NIC, which this
    /// comm by construction cannot have. `cost.devices` carries k into
    /// [`KvWorker::devices`] for the local tier before the reset.
    pub fn configure_collective(
        &mut self,
        algo: AlgoKind,
        rings: usize,
        group: usize,
        fusion_bytes: usize,
        cost: CostParams,
    ) {
        self.algo = if algo == AlgoKind::TwoTier { AlgoKind::Ring } else { algo };
        self.n_rings = rings.max(1);
        self.group = group.max(1);
        self.fusion_bytes = fusion_bytes;
        self.devices = cost.devices.max(1);
        let mut cost = cost;
        cost.devices = 1;
        self.cost = cost;
    }

    /// The local tier: average this worker's k per-device gradient buffers
    /// into the one leader buffer that enters the wire schedules, through
    /// the worker's codec with per-device EF residuals (see
    /// [`device_local_merge`]). `owner` scopes the residual keys — pass a
    /// stable per-(worker, buffer) id such as the KVStore key.
    pub fn local_merge(&self, bufs: Vec<Vec<f32>>, owner: u64) -> Vec<f32> {
        device_local_merge(
            bufs,
            &*self.codec,
            &mut self.ef.lock().expect("EF-residual state lock poisoned"),
            device_ef_base(owner),
        )
    }

    /// Capture the collective parameters for use inside an engine op.
    fn algo_params(&self) -> (AlgoKind, usize, usize, CostParams) {
        (self.algo, self.n_rings, self.group, self.cost.clone())
    }

    /// Capture the compression plane for use inside an engine op.
    fn codec_params(&self) -> (Arc<dyn Compressor>, Arc<Mutex<EfState>>) {
        (self.codec.clone(), self.ef.clone())
    }

    fn key_var(&self, key: Key) -> Var {
        *self
            .key_vars
            .lock()
            .unwrap_or_else(|_| panic!("key-var table lock poisoned (key {key})"))
            .entry(key)
            .or_insert_with(|| self.engine.new_var())
    }

    pub fn rank(&self) -> usize {
        self.comm.as_ref().map(|c| c.lock().expect("client communicator lock poisoned").rank()).unwrap_or(0)
    }

    pub fn client_size(&self) -> usize {
        self.comm.as_ref().map(|c| c.lock().expect("client communicator lock poisoned").size()).unwrap_or(1)
    }

    /// Insert an initialized value into the local store, folding in any
    /// pushes that raced ahead of the init (the PS servers' pre_init
    /// replay discipline, kept consistent here).
    fn local_init_insert(&self, key: Key, value: Vec<f32>) {
        let mut store = self.local.lock().expect("local store lock poisoned");
        let mut pre = self.local_pre_init.lock().expect("pre-init buffer lock poisoned");
        let mut v = value;
        if let Some(pushes) = pre.remove(&key) {
            for pdata in pushes {
                crate::tensor::add_assign(&mut v, &pdata);
            }
        }
        store.insert(key, v);
    }

    /// Initialize a key. PS rank 0 initializes the servers (§4.2.1); with
    /// no servers the value is broadcast inside the MPI client instead.
    /// `is_root` = this worker is rank 0 in the PS namespace.
    pub fn init(&self, key: Key, value: Vec<f32>, is_root: bool) {
        match self.ktype {
            KvType::Local => {
                self.local_init_insert(key, value);
            }
            KvType::DistSync | KvType::DistAsync => {
                if is_root {
                    self.ps
                        .as_ref()
                        .expect("dist kvstore requires a PS client")
                        .lock()
                        .unwrap_or_else(|_| panic!("PS client lock poisoned initializing key {key}"))
                        .init(key, value);
                }
            }
            KvType::SyncMpi | KvType::AsyncMpi => {
                if let Some(ps) = &self.ps {
                    if is_root {
                        ps.lock().expect("PS client lock poisoned").init(key, value);
                    }
                } else {
                    // Pure MPI: MPI_Bcast from rank 0 of the client.
                    let comm = self.comm.as_ref().expect("MPI kvstore requires a communicator");
                    let mut c = comm.lock().expect("client communicator lock poisoned");
                    let mut v = value;
                    c.bcast(0, &mut v);
                    drop(c);
                    self.local_init_insert(key, v);
                }
            }
        }
    }

    /// The PS hop shared by the dist push and the MPI master push: dense
    /// ZPush, or — on a codec-carrying *gradient* push — EF-compress under
    /// `ef_key` and ship the wire payload for the server to decode before
    /// aggregation.
    fn ps_push(
        ps: &Arc<Mutex<PsClient>>,
        codec: &dyn Compressor,
        ef: &Mutex<EfState>,
        use_codec: bool,
        ef_key: u64,
        key: Key,
        data: Vec<f32>,
    ) {
        if !use_codec || codec.is_identity() {
            ps.lock()
                .unwrap_or_else(|_| panic!("PS client lock poisoned pushing key {key}"))
                .push(key, data);
        } else {
            let wire = ef_compress(
                codec,
                ef_key,
                &data,
                &mut ef.lock().unwrap_or_else(|_| {
                    panic!("EF-residual state lock poisoned (ef_key {ef_key:#x}, key {key})")
                }),
            )
            .to_wire();
            ps.lock()
                .unwrap_or_else(|_| {
                    panic!("PS client lock poisoned pushing compressed key {key}")
                })
                .push_compressed(key, wire);
        }
    }

    /// KVStore.push (Fig. 4): enqueue the client-side aggregation +
    /// master ZPush as an engine op reading the key var and mutating the
    /// comm var. Payloads are treated as *gradients*: a lossy codec
    /// compresses both hops (with error feedback).
    pub fn push(&self, key: Key, data: Vec<f32>) {
        self.push_impl(key, data, true);
    }

    /// [`KvWorker::push`] for *model-snapshot* payloads (the
    /// model-averaging family's sync points: ESGD / Local SGD / BMUF push
    /// replicas the server merges and workers adopt wholesale). Always
    /// dense: error feedback is an unbiased-over-time *gradient*
    /// mechanism — sparsifying a snapshot that is adopted outright is
    /// simply mass loss — so lossy codecs never touch these pushes.
    pub fn push_model(&self, key: Key, data: Vec<f32>) {
        self.push_impl(key, data, false);
    }

    fn push_impl(&self, key: Key, data: Vec<f32>, use_codec: bool) {
        let kv = self.key_var(key);
        match self.ktype {
            KvType::Local => {
                let store = self.local.clone();
                let pre = self.local_pre_init.clone();
                self.engine.push(
                    move || {
                        let mut s = store
                            .lock()
                            .unwrap_or_else(|_| panic!("local store lock poisoned pushing key {key}"));
                        match s.get_mut(&key) {
                            Some(v) => crate::tensor::add_assign(v, &data),
                            None => {
                                // Same discipline as the PS servers
                                // (§4.1.2): a push racing ahead of init is
                                // buffered and replayed onto the init value.
                                pre.lock()
                                    .expect("pre-init buffer lock poisoned")
                                    .entry(key)
                                    .or_default()
                                    .push(data);
                            }
                        }
                    },
                    &[],
                    &[kv],
                );
            }
            KvType::DistSync | KvType::DistAsync => {
                let ps = self.ps.clone().expect("dist kvstore requires a PS client");
                let (codec, ef) = self.codec_params();
                self.engine.push(
                    move || {
                        Self::ps_push(&ps, &*codec, &ef, use_codec, key as u64, key, data);
                    },
                    &[kv],
                    &[self.comm_var],
                );
            }
            KvType::SyncMpi | KvType::AsyncMpi => {
                let comm = self.comm.clone().expect("MPI kvstore requires a communicator");
                let ps = self.ps.clone();
                let (kind, rings, group, cost) = self.algo_params();
                let (codec, ef) = self.codec_params();
                self.engine.push(
                    move || {
                        let mut c = comm.lock().expect("client communicator lock poisoned");
                        let mut buf = data;
                        // Aggregate across the MPI client first (§4.2.2);
                        // a codec-carrying gradient push moves compressed
                        // payloads (identity delegates to the plain
                        // schedules inside, bitwise), a model push stays
                        // on the dense schedules...
                        if use_codec {
                            compressed_allreduce(
                                kind,
                                &mut *c,
                                &mut buf,
                                &*codec,
                                key as u64,
                                &mut ef.lock().expect("EF-residual state lock poisoned"),
                                rings,
                                group,
                                &cost,
                            );
                        } else {
                            allreduce_with(kind, &mut *c, &mut buf, rings, group, &cost);
                        }
                        // ...then only the master talks to the servers,
                        // re-compressing the client aggregate for the PS
                        // hop (its own EF residual: the master's dropped
                        // mass returns on *its* next push of this key).
                        if c.rank() == 0 {
                            if let Some(ps) = &ps {
                                Self::ps_push(
                                    ps,
                                    &*codec,
                                    &ef,
                                    use_codec,
                                    EF_MASTER | key as u64,
                                    key,
                                    buf,
                                );
                            }
                        }
                    },
                    &[kv],
                    &[self.comm_var],
                );
            }
        }
    }

    /// KVStore.pull (Fig. 5): master ZPulls and broadcasts inside the
    /// client; everyone else receives the broadcast. The returned
    /// [`Pending`] is backed by the key's dependency var: `wait()` blocks
    /// in the engine, not on a channel.
    pub fn pull(&self, key: Key) -> Pending<Vec<f32>> {
        let kv = self.key_var(key);
        let (pending, slot) = Pending::engine_backed(self.engine.clone(), vec![kv]);
        match self.ktype {
            KvType::Local => {
                let store = self.local.clone();
                self.engine.push(
                    move || {
                        let v = store
                            .lock()
                            .unwrap_or_else(|_| {
                                panic!("local store lock poisoned pulling key {key}")
                            })
                            .get(&key)
                            .unwrap_or_else(|| {
                                panic!(
                                    "KVStore pull on uninitialized key {key}: \
                                     call init() before pull() (pushes before \
                                     init are buffered, not implicit inits)"
                                )
                            })
                            .clone();
                        *slot.lock().expect("pending-result slot lock poisoned") = Some(v);
                    },
                    &[kv],
                    &[],
                );
            }
            KvType::DistSync | KvType::DistAsync => {
                let ps = self.ps.clone().expect("dist kvstore requires a PS client");
                self.engine.push(
                    move || {
                        *slot.lock().expect("pending-result slot lock poisoned") = Some(ps.lock().expect("PS client lock poisoned").pull(key));
                    },
                    &[],
                    &[self.comm_var, kv],
                );
            }
            KvType::SyncMpi | KvType::AsyncMpi => {
                let comm = self.comm.clone().expect("MPI kvstore requires a communicator");
                let ps = self.ps.clone();
                let local = self.local.clone();
                self.engine.push(
                    move || {
                        let mut c = comm.lock().expect("client communicator lock poisoned");
                        let mut buf = Vec::new();
                        if c.rank() == 0 {
                            buf = match &ps {
                                Some(ps) => ps.lock().expect("PS client lock poisoned").pull(key),
                                // Pure MPI: the "value" lives locally
                                // (pushpull is the natural API there).
                                None => local
                                    .lock()
                                    .unwrap_or_else(|_| {
                                        panic!("local store lock poisoned pulling key {key}")
                                    })
                                    .get(&key)
                                    .unwrap_or_else(|| {
                                        panic!(
                                            "KVStore pull on uninitialized key \
                                             {key} (pure MPI): call init() first"
                                        )
                                    })
                                    .clone(),
                            };
                        }
                        c.bcast(0, &mut buf);
                        *slot.lock().expect("pending-result slot lock poisoned") = Some(buf);
                    },
                    &[],
                    &[self.comm_var, kv],
                );
            }
        }
        pending
    }

    /// KVStore.pushpull (§4.2.4, added to MXNET for MPI acceleration):
    /// fuses push+pull into one tensor allreduce — no PS round-trip when
    /// there are no servers.
    pub fn pushpull(&self, key: Key, data: Vec<f32>) -> Pending<Vec<f32>> {
        match self.ktype {
            KvType::SyncMpi | KvType::AsyncMpi if self.ps.is_none() => {
                let kv = self.key_var(key);
                let (pending, slot) = Pending::engine_backed(self.engine.clone(), vec![kv]);
                let comm = self.comm.clone().expect("MPI kvstore requires a communicator");
                let (kind, rings, group, cost) = self.algo_params();
                let (codec, ef) = self.codec_params();
                self.engine.push(
                    move || {
                        let mut c = comm.lock().expect("client communicator lock poisoned");
                        let mut buf = data;
                        compressed_allreduce(
                            kind,
                            &mut *c,
                            &mut buf,
                            &*codec,
                            key as u64,
                            &mut ef.lock().expect("EF-residual state lock poisoned"),
                            rings,
                            group,
                            &cost,
                        );
                        *slot.lock().expect("pending-result slot lock poisoned") = Some(buf);
                    },
                    &[],
                    &[self.comm_var, kv],
                );
                pending
            }
            _ => {
                // Fallback composition: push then pull.
                self.push(key, data);
                self.pull(key)
            }
        }
    }

    /// Fused pushpull (§2.1 gradient bucketing): allreduce a whole batch
    /// of per-key gradients in one engine op, coalescing consecutive small
    /// keys into buckets of at most `fusion_bytes` bytes so each bucket
    /// pays the per-message latency once. Results come back in input
    /// order. On non-pure-MPI stores this degrades to per-key pushpull
    /// composition.
    pub fn pushpull_fused(&self, keyed: Vec<(Key, Vec<f32>)>) -> Pending<Vec<Vec<f32>>> {
        if keyed.is_empty() {
            // Nothing to reduce: resolve immediately (an engine-backed
            // Pending with no vars would otherwise race the op).
            let (pending, slot) = Pending::engine_backed(self.engine.clone(), Vec::new());
            *slot.lock().expect("pending-result slot lock poisoned") = Some(Vec::new());
            return pending;
        }
        match self.ktype {
            KvType::SyncMpi | KvType::AsyncMpi if self.ps.is_none() => {
                let key_vars: Vec<Var> = keyed.iter().map(|(k, _)| self.key_var(*k)).collect();
                let mut mutates = vec![self.comm_var];
                mutates.extend(key_vars.iter().copied());
                let (pending, slot) = Pending::engine_backed(self.engine.clone(), key_vars);
                let comm = self.comm.clone().expect("MPI kvstore requires a communicator");
                let (kind, rings, group, cost) = self.algo_params();
                let (codec, ef) = self.codec_params();
                let arena = self.arena.clone();
                let fusion_bytes = self.fusion_bytes;
                self.engine.push(
                    move || {
                        let mut c = comm.lock().expect("client communicator lock poisoned");
                        // Per-bucket EF residuals keyed by the bucket's
                        // first KVStore key: the bucket layout is a pure
                        // function of the key lens, so the same bucket
                        // accumulates the same residual every iteration.
                        let ef_keys: Vec<u64> =
                            keyed.iter().map(|(k, _)| EF_FUSED | *k as u64).collect();
                        let mut bufs: Vec<Vec<f32>> =
                            keyed.into_iter().map(|(_, v)| v).collect();
                        fused_allreduce_compressed_with_arena(
                            kind,
                            &mut *c,
                            &mut bufs,
                            &ef_keys,
                            fusion_bytes,
                            &*codec,
                            &mut ef.lock().expect("EF-residual state lock poisoned"),
                            rings,
                            group,
                            &cost,
                            &mut arena.lock().expect("fusion arena lock poisoned"),
                        );
                        *slot.lock().expect("pending-result slot lock poisoned") = Some(bufs);
                    },
                    &[],
                    &mutates,
                );
                pending
            }
            _ => {
                let (reply, rx) = channel_named("kv.reply");
                let pends: Vec<Pending<Vec<f32>>> = keyed
                    .into_iter()
                    .map(|(k, v)| self.pushpull(k, v))
                    .collect();
                crate::util::sync::Builder::new()
                    .name("kv-fused-reply".to_string())
                    .spawn(move || {
                        let out: Vec<Vec<f32>> = pends.into_iter().map(|p| p.wait()).collect();
                        let _ = reply.send(out);
                    })
                    .expect("spawn fused-reply thread");
                Pending::channel(rx)
            }
        }
    }

    /// Per-bucket nonblocking pushpull (the DAG-embedded collective path,
    /// arXiv:1802.06949): splits `keyed` into fusion buckets (same layout
    /// as [`crate::collectives::fusion_buckets`]) and issues **one engine
    /// op per bucket**, returning each bucket's input-index range and
    /// [`Pending`], in issue order. Buckets are issued in *reverse* key
    /// order — backprop emits the last layer's gradients first, so that is
    /// the order in which buckets become ready — and the comm var
    /// serializes the collectives in that same order (§4.2 deadlock rule);
    /// a trainer draining the returned list front to back therefore
    /// overlaps bucket i+1's allreduce with bucket i's wait/update.
    pub fn pushpull_buckets(
        &self,
        keyed: Vec<(Key, Vec<f32>)>,
    ) -> Vec<((usize, usize), Pending<Vec<Vec<f32>>>)> {
        let lens: Vec<usize> = keyed.iter().map(|(_, v)| v.len()).collect();
        let plan = bucket_issue_plan(&lens, self.fusion_bytes);
        let mut keyed: Vec<Option<(Key, Vec<f32>)>> = keyed.into_iter().map(Some).collect();
        plan.into_iter()
            .map(|(i, j)| {
                let bucket: Vec<(Key, Vec<f32>)> = keyed[i..j]
                    .iter_mut()
                    .map(|s| s.take().expect("bucket_issue_plan ranges must be disjoint"))
                    .collect();
                ((i, j), self.pushpull_fused(bucket))
            })
            .collect()
    }

    // -- elasticity: epoch-scoped communicators + checkpoint/restore -------

    /// Swap in a rebuilt communicator at a membership-epoch boundary and
    /// return the old one (the epoch-scoped world story: the client's
    /// world shrinks or grows without restarting the worker).
    ///
    /// Callers must quiesce first (`wait_all`): every engine op captures
    /// the same `Arc<Mutex<Comm>>`, so ops enqueued after this call run on
    /// the new world, and an op still in flight would race the swap.
    pub fn replace_comm(&self, new: Comm) -> Comm {
        let comm = self
            .comm
            .as_ref()
            .expect("replace_comm on a communicator-less kvstore");
        std::mem::replace(&mut *comm.lock().expect("client communicator lock poisoned"), new)
    }

    /// Persist a checkpoint blob through the PS (the master-replica path
    /// joiners and restarted ranks bootstrap from). With `#servers == 0`
    /// the blob is kept in this worker's local store instead — a restarted
    /// rank can reload in place, and a *new* rank bootstraps by peer
    /// broadcast ([`KvWorker::client_bcast`]) since there is no PS to pull
    /// from.
    ///
    /// Blob keys are a namespace apart from training keys (no rounds, no
    /// aggregation, last write wins). Called at membership-epoch
    /// boundaries where the trainer has already quiesced the engine, so it
    /// talks to the PS directly rather than through the comm var.
    pub fn ckpt_save(&self, key: Key, data: Vec<f32>) {
        match &self.ps {
            Some(ps) => ps.lock().expect("PS client lock poisoned").save_blob(key, data),
            None => {
                self.ckpt_local.lock().expect("checkpoint store lock poisoned").insert(key, data);
            }
        }
    }

    /// Fetch a checkpoint blob saved by [`KvWorker::ckpt_save`]; `None` if
    /// nothing was saved under `key`.
    pub fn ckpt_load(&self, key: Key) -> Option<Vec<f32>> {
        match &self.ps {
            Some(ps) => ps.lock().expect("PS client lock poisoned").load_blob(key),
            None => self.ckpt_local.lock().expect("checkpoint store lock poisoned").get(&key).cloned(),
        }
    }

    /// Broadcast `data` from the client member with MPI rank `root` to the
    /// whole client — the peer-bootstrap path a joiner takes when
    /// `#servers == 0` leaves no PS checkpoint to pull. Every member of
    /// the client must call it (survivors pass their replica, joiners pass
    /// anything); runs through the engine comm var like every collective.
    pub fn client_bcast(&self, root: usize, data: Vec<f32>) -> Pending<Vec<f32>> {
        let (pending, slot) = Pending::engine_backed(self.engine.clone(), vec![self.comm_var]);
        let comm = self.comm.clone().expect("client_bcast needs MPI");
        self.engine.push(
            move || {
                let mut c = comm.lock().expect("client communicator lock poisoned");
                let mut buf = data;
                c.bcast(root, &mut buf);
                *slot.lock().expect("pending-result slot lock poisoned") = Some(buf);
            },
            &[],
            &[self.comm_var],
        );
        pending
    }

    /// Intra-client gradient aggregation (sync SGD *within* the
    /// communicator, §5 ESGD): a plain multi-ring allreduce across the MPI
    /// client, never touching the PS.
    pub fn client_allreduce(&self, data: Vec<f32>) -> Pending<Vec<f32>> {
        // Backed by the comm var: comm ops are serialized in program order
        // (§4.2), so its quiescence covers this op.
        let (pending, slot) = Pending::engine_backed(self.engine.clone(), vec![self.comm_var]);
        let comm = self.comm.clone().expect("client_allreduce needs MPI");
        let (kind, rings, group, cost) = self.algo_params();
        let (codec, ef) = self.codec_params();
        self.engine.push(
            move || {
                let mut c = comm.lock().expect("client communicator lock poisoned");
                let mut buf = data;
                // Whole-model buffer: one EF residual slot of its own.
                compressed_allreduce(
                    kind,
                    &mut *c,
                    &mut buf,
                    &*codec,
                    EF_CLIENT,
                    &mut ef.lock().expect("EF-residual state lock poisoned"),
                    rings,
                    group,
                    &cost,
                );
                *slot.lock().expect("pending-result slot lock poisoned") = Some(buf);
            },
            &[],
            &[self.comm_var],
        );
        pending
    }

    /// Tensor-variant pushpull: allreduce a whole [`NodeTensor`] (the group
    /// of per-device vectors, §6.1) with the multi-ring schedule.
    pub fn pushpull_tensor(&self, key: Key, tensor: NodeTensor) -> Pending<NodeTensor> {
        let kv = self.key_var(key);
        let (pending, slot) = Pending::engine_backed(self.engine.clone(), vec![kv]);
        let comm = self.comm.clone().expect("tensor pushpull needs MPI");
        let (kind, rings, group, cost) = self.algo_params();
        self.engine.push(
            move || {
                let mut c = comm.lock().expect("client communicator lock poisoned");
                let mut t = tensor;
                tensor_allreduce_with(kind, &mut *c, &mut t, rings, group, &cost, HostReduce::Host);
                *slot.lock().expect("pending-result slot lock poisoned") = Some(t);
            },
            &[],
            &[self.comm_var, kv],
        );
        pending
    }

    /// Ship an optimizer to the PS (KVStore.set_optimizer, §3.2). Only the
    /// PS root should call this once.
    pub fn set_optimizer<F>(&self, factory: F)
    where
        F: Fn() -> Box<dyn Optimizer>,
    {
        if let Some(ps) = &self.ps {
            ps.lock().expect("PS client lock poisoned").set_optimizer(factory);
        }
    }

    /// Block until every enqueued op of this worker's engine completed.
    pub fn wait_all(&self) {
        self.engine.wait_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::World;
    use crate::optimizer::{Sgd, SgdHyper};
    use crate::ps::{ServerGroup, SyncMode};
    use std::thread;

    #[test]
    fn local_push_accumulates_pull_reads() {
        let engine = Arc::new(Engine::new(2));
        let kv = KvWorker::create(KvType::Local, engine, None, None);
        kv.init(0, vec![1.0, 1.0], true);
        kv.push(0, vec![2.0, 3.0]);
        kv.push(0, vec![1.0, 1.0]);
        assert_eq!(kv.pull(0).wait(), vec![4.0, 5.0]);
    }

    #[test]
    fn dist_sync_via_engine_matches_ps_semantics() {
        let group = ServerGroup::spawn(2, SyncMode::Sync, 3);
        let c0 = group.client();
        c0.init(0, vec![0.0]);
        c0.init(1, vec![10.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let hs: Vec<_> = (0..3)
            .map(|w| {
                let ps = group.client();
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(1));
                    let kv = KvWorker::create(KvType::DistSync, engine, None, Some(ps));
                    kv.push(0, vec![1.0]);
                    kv.push(1, vec![2.0]);
                    let a = kv.pull(0).wait();
                    let b = kv.pull(1).wait();
                    (w, a[0], b[0])
                })
            })
            .collect();
        for h in hs {
            let (_, a, b) = h.join().unwrap();
            assert_eq!(a, -3.0); // 0 - 1*sum(1,1,1)
            assert_eq!(b, 4.0); // 10 - 1*sum(2,2,2)
        }
        group.shutdown();
    }

    #[test]
    fn sync_mpi_aggregates_in_client_then_master_pushes() {
        // 1 client of 4 workers; server expects exactly 1 push per round.
        let group = ServerGroup::spawn(1, SyncMode::Sync, 1);
        let c0 = group.client();
        c0.init(0, vec![0.0, 0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let comms = World::create(4);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let ps = group.client();
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(1));
                    let kv =
                        KvWorker::create(KvType::SyncMpi, engine, Some(comm), Some(ps));
                    kv.push(0, vec![1.0, 2.0]);
                    kv.pull(0).wait()
                })
            })
            .collect();
        for h in hs {
            // Client aggregate = [4, 8]; server: 0 - [4,8] = [-4,-8].
            assert_eq!(h.join().unwrap(), vec![-4.0, -8.0]);
        }
        group.shutdown();
    }

    #[test]
    fn pure_mpi_pushpull_is_allreduce() {
        let comms = World::create(3);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(1));
                    let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    kv.pushpull(7, vec![1.0, 10.0]).wait()
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), vec![3.0, 30.0]);
        }
    }

    #[test]
    fn pure_mpi_init_broadcasts_from_rank0() {
        let comms = World::create(3);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                thread::spawn(move || {
                    let rank = comm.rank();
                    let engine = Arc::new(Engine::new(1));
                    let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    let value = if rank == 0 { vec![5.0, 6.0] } else { Vec::new() };
                    kv.init(0, value, rank == 0);
                    kv.pull(0).wait()
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), vec![5.0, 6.0]);
        }
    }

    #[test]
    fn tensor_pushpull_sums_device_groups() {
        let comms = World::create(2);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                thread::spawn(move || {
                    let r = comm.rank() as f32;
                    let engine = Arc::new(Engine::new(1));
                    let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    let t = NodeTensor::from_vecs(vec![
                        vec![r + 1.0; 4],
                        vec![10.0 * (r + 1.0); 4],
                    ]);
                    kv.pushpull_tensor(0, t).wait()
                })
            })
            .collect();
        for h in hs {
            let t = h.join().unwrap();
            // (1 + 10) + (2 + 20) = 33 on every device vector.
            assert!(t.vecs.iter().all(|v| v.iter().all(|&x| x == 33.0)));
        }
    }

    #[test]
    fn local_push_before_init_replays_on_init() {
        // Same discipline as the PS pre_init queue: the racing push is
        // folded into the init value, not treated as an implicit init.
        let engine = Arc::new(Engine::new(1));
        let kv = KvWorker::create(KvType::Local, engine, None, None);
        kv.push(0, vec![2.0, 3.0]);
        kv.wait_all();
        kv.init(0, vec![10.0, 10.0], true);
        assert_eq!(kv.pull(0).wait(), vec![12.0, 13.0]);
    }

    #[test]
    fn pushpull_fused_pure_mpi_matches_per_key() {
        for (algo, fusion) in [
            (AlgoKind::Ring, 0usize),
            (AlgoKind::Ring, 1 << 20),
            (AlgoKind::HalvingDoubling, 1 << 20),
            (AlgoKind::Hierarchical, 256),
            (AlgoKind::Auto, 1 << 20),
        ] {
            let comms = World::create(3);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    thread::spawn(move || {
                        let engine = Arc::new(Engine::new(1));
                        let mut kv =
                            KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                        kv.algo = algo;
                        kv.fusion_bytes = fusion;
                        let keyed: Vec<(usize, Vec<f32>)> = (0..4)
                            .map(|k| (k, vec![(k + 1) as f32; 5 + k]))
                            .collect();
                        kv.pushpull_fused(keyed).wait()
                    })
                })
                .collect();
            for h in hs {
                let out = h.join().unwrap();
                assert_eq!(out.len(), 4);
                for (k, buf) in out.iter().enumerate() {
                    assert_eq!(buf.len(), 5 + k);
                    assert!(
                        buf.iter().all(|&x| x == 3.0 * (k + 1) as f32),
                        "algo {algo:?} fusion {fusion} key {k}: {buf:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn pushpull_fused_falls_back_without_mpi() {
        let engine = Arc::new(Engine::new(1));
        let kv = KvWorker::create(KvType::Local, engine, None, None);
        kv.init(0, vec![0.0; 2], true);
        kv.init(1, vec![1.0; 3], true);
        let out = kv
            .pushpull_fused(vec![(0, vec![2.0; 2]), (1, vec![2.0; 3])])
            .wait();
        assert_eq!(out[0], vec![2.0; 2]);
        assert_eq!(out[1], vec![3.0; 3]);
    }

    #[test]
    #[should_panic(expected = "reply channel disconnected")]
    fn channel_pending_panics_clearly_when_backing_dies() {
        // A channel-backed Pending whose producer died must panic with a
        // diagnosis, not a bare RecvError unwrap.
        let (tx, rx) = channel::<Vec<f32>>();
        drop(tx);
        Pending::channel(rx).wait();
    }

    #[test]
    fn pending_is_engine_backed_for_pure_mpi_pushpull() {
        // wait() must return after the engine vars quiesce even when the
        // worker thread never parks on a channel: issue many nonblocking
        // pushpulls, then wait them all out of order.
        let comms = World::create(2);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(2));
                    let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    let pends: Vec<_> =
                        (0..8).map(|k| kv.pushpull(k, vec![k as f32 + 1.0; 3])).collect();
                    let mut out: Vec<Vec<f32>> = pends.into_iter().map(|p| p.wait()).collect();
                    out.reverse();
                    out
                })
            })
            .collect();
        for h in hs {
            let out = h.join().unwrap();
            for (i, buf) in out.iter().enumerate() {
                let k = 7 - i;
                assert_eq!(buf[..], [2.0 * (k as f32 + 1.0); 3][..]);
            }
        }
    }

    #[test]
    fn pushpull_buckets_matches_fused_and_overlaps_issue() {
        // Per-bucket issue (reverse key order) must produce the same sums
        // as one fused call, with bucket ranges tiling the key space.
        let comms = World::create(3);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(1));
                    let mut kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    kv.fusion_bytes = 64; // several buckets over 6 keys
                    let keyed: Vec<(usize, Vec<f32>)> =
                        (0..6).map(|k| (k, vec![(k + 1) as f32; 4 + k])).collect();
                    let buckets = kv.pushpull_buckets(keyed);
                    let mut seen = vec![false; 6];
                    let mut prev_start = usize::MAX;
                    for ((i, j), pending) in buckets {
                        assert!(i < j && j <= 6);
                        // Reverse issue order: ranges descend.
                        assert!(i < prev_start);
                        prev_start = i;
                        let bufs = pending.wait();
                        assert_eq!(bufs.len(), j - i);
                        for (k, buf) in (i..j).zip(bufs) {
                            assert!(!seen[k]);
                            seen[k] = true;
                            assert_eq!(buf[..], vec![3.0 * (k + 1) as f32; 4 + k][..]);
                        }
                    }
                    assert!(seen.iter().all(|&s| s));
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn replace_comm_shrinks_the_allreduce_world() {
        // 3 ranks allreduce; rank 2 "dies" at the epoch boundary; the two
        // survivors swap in a rebuilt 2-rank world and keep reducing —
        // no deadlock, and the sums now span the survivors only.
        let comms = World::create(3);
        let new_world = Arc::new(Mutex::new(World::create(2)));
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let new_world = new_world.clone();
                thread::spawn(move || {
                    let rank = comm.rank();
                    let engine = Arc::new(Engine::new(1));
                    let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    let a = kv.pushpull(0, vec![rank as f32 + 1.0]).wait();
                    assert_eq!(a, vec![6.0]);
                    kv.wait_all(); // quiesce before the epoch boundary
                    if rank == 2 {
                        return vec![-1.0]; // fail-stop departure
                    }
                    let fresh = new_world.lock().unwrap().pop().unwrap();
                    // New worlds are handed out highest-rank-first by pop:
                    // old rank 1 -> new rank 1, old rank 0 -> new rank 0
                    // is irrelevant for a sum, so any assignment works.
                    drop(kv.replace_comm(fresh));
                    kv.pushpull(1, vec![10.0]).wait()
                })
            })
            .collect();
        let out: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(out[0], vec![20.0]);
        assert_eq!(out[1], vec![20.0]);
        assert_eq!(out[2], vec![-1.0]);
    }

    #[test]
    fn checkpoint_blobs_persist_through_ps() {
        let group = ServerGroup::spawn(2, SyncMode::Sync, 1);
        let engine = Arc::new(Engine::new(1));
        let kv = KvWorker::create(KvType::DistSync, engine, None, Some(group.client()));
        assert_eq!(kv.ckpt_load(0), None);
        kv.ckpt_save(0, vec![1.0, 2.0]);
        kv.ckpt_save(1, vec![3.0]);
        // A different worker endpoint sees the same blobs (PS-backed).
        let engine2 = Arc::new(Engine::new(1));
        let kv2 = KvWorker::create(KvType::DistSync, engine2, None, Some(group.client()));
        assert_eq!(kv2.ckpt_load(0), Some(vec![1.0, 2.0]));
        assert_eq!(kv2.ckpt_load(1), Some(vec![3.0]));
        group.shutdown();
    }

    #[test]
    fn checkpoint_degrades_to_local_without_servers() {
        let comms = World::create(1);
        let engine = Arc::new(Engine::new(1));
        let kv = KvWorker::create(
            KvType::SyncMpi,
            engine,
            Some(comms.into_iter().next().unwrap()),
            None,
        );
        kv.ckpt_save(7, vec![4.0]);
        assert_eq!(kv.ckpt_load(7), Some(vec![4.0]));
        assert_eq!(kv.ckpt_load(8), None);
    }

    #[test]
    fn client_bcast_bootstraps_joiner_replica() {
        // Rank 1 plays a joiner with no state; the bcast hands it rank 0's
        // replica bitwise.
        let comms = World::create(3);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                thread::spawn(move || {
                    let rank = comm.rank();
                    let engine = Arc::new(Engine::new(1));
                    let kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                    let mine = if rank == 1 {
                        Vec::new() // joiner: nothing yet
                    } else {
                        vec![0.25, -1.5, 3.0]
                    };
                    kv.client_bcast(0, mine).wait()
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), vec![0.25, -1.5, 3.0]);
        }
    }

    #[test]
    fn identity_codec_pushpull_bitwise_matches_default() {
        // configure_compression(identity) must leave the pure-MPI pushpull
        // on the exact pre-compression path: bitwise-equal results.
        let run = |configure: bool| -> Vec<Vec<f32>> {
            let comms = World::create(3);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    thread::spawn(move || {
                        let engine = Arc::new(Engine::new(1));
                        let mut kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                        if configure {
                            kv.configure_compression(crate::compress::Codec::identity(), 0.01);
                        }
                        kv.pushpull(0, vec![0.1 + kv.rank() as f32, -2.5]).wait()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn compressed_pushpull_consistent_and_accurate() {
        for codec in ["int8", "topk"] {
            let comms = World::create(3);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    thread::spawn(move || {
                        let engine = Arc::new(Engine::new(1));
                        let mut kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), None);
                        kv.configure_compression(crate::compress::Codec::named(codec), 1.0);
                        // topk ratio 1.0 keeps everything; int8 quantizes.
                        kv.pushpull(3, vec![1.0, -2.0, 0.5, 4.0]).wait()
                    })
                })
                .collect();
            let out: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
            for o in &out[1..] {
                assert_eq!(*o, out[0], "{codec}: ranks disagree");
            }
            for (a, want) in out[0].iter().zip([3.0f32, -6.0, 1.5, 12.0]) {
                assert!((a - want).abs() < 0.1, "{codec}: {a} vs {want}");
            }
        }
    }

    #[test]
    fn compressed_mpi_push_reaches_ps_decoded() {
        // 1 client of 2 workers with int8: the client aggregate crosses
        // the PS hop as a codec payload; the server decodes then applies.
        let group = ServerGroup::spawn(1, SyncMode::Sync, 1);
        let c0 = group.client();
        c0.init(0, vec![0.0, 0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let comms = World::create(2);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let ps = group.client();
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(1));
                    let mut kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), Some(ps));
                    kv.configure_compression(crate::compress::Codec::named("int8"), 0.01);
                    kv.push(0, vec![1.0, 2.0]);
                    kv.pull(0).wait()
                })
            })
            .collect();
        for h in hs {
            let v = h.join().unwrap();
            // Client aggregate [2, 4]; server: 0 - [2, 4] (within int8
            // tolerance across the two lossy hops).
            assert!((v[0] + 2.0).abs() < 0.1 && (v[1] + 4.0).abs() < 0.1, "{v:?}");
        }
        group.shutdown();
    }

    #[test]
    fn push_model_bypasses_the_codec() {
        // Model-snapshot pushes stay dense even with a lossy codec
        // configured: the pulled merge must be bit-exact, not sparsified
        // (topk at this ratio would zero two of the three elements).
        let group = ServerGroup::spawn(1, SyncMode::Sync, 1);
        let c0 = group.client();
        c0.init(0, vec![0.0, 0.0, 0.0]);
        c0.set_optimizer(|| Box::new(Sgd::new(SgdHyper::plain(1.0, 1.0))));
        let comms = World::create(2);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let ps = group.client();
                thread::spawn(move || {
                    let engine = Arc::new(Engine::new(1));
                    let mut kv = KvWorker::create(KvType::SyncMpi, engine, Some(comm), Some(ps));
                    kv.configure_compression(crate::compress::Codec::named("topk"), 0.34);
                    kv.push_model(0, vec![1.0, -2.0, 0.25]);
                    kv.pull(0).wait()
                })
            })
            .collect();
        for h in hs {
            // Client ring sums two replicas exactly; server applies the
            // dense aggregate: w = 0 - [2, -4, 0.5].
            assert_eq!(h.join().unwrap(), vec![-2.0, 4.0, -0.5]);
        }
        group.shutdown();
    }

    #[test]
    fn device_local_merge_averages_and_single_buffer_is_untouched() {
        let codec = Codec::identity().build(0.0);
        let mut ef = EfState::new();
        // k = 1: bitwise identity, no residual minted.
        let solo = vec![vec![0.1f32, -2.5, 3.75]];
        let out = device_local_merge(solo.clone(), &*codec, &mut ef, device_ef_base(0));
        assert_eq!(out, solo[0]);
        // k = 3 identity: exact mean (payloads chosen exact in f32).
        let bufs = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let out = device_local_merge(bufs, &*codec, &mut ef, device_ef_base(0));
        assert_eq!(out, vec![3.0, 4.0]);
        assert!(ef.residual(device_ef_base(0)).is_none(), "identity mints no residuals");
    }

    #[test]
    fn device_local_merge_keeps_per_device_residuals() {
        // A lossy codec must accumulate residual state per device key:
        // merging twice with int8 leaves k residual buffers, one per
        // device, under the owner's EF_DEVICE base.
        let codec = Codec::named("int8").build(0.0);
        let mut ef = EfState::new();
        let base = device_ef_base(7);
        for _ in 0..2 {
            let bufs = vec![vec![0.3f32, -1.7, 0.01, 2.0], vec![1.1, 0.0, -0.5, 0.25]];
            let out = device_local_merge(bufs, &*codec, &mut ef, base);
            assert_eq!(out.len(), 4);
        }
        assert!(ef.residual(base).is_some(), "device 0 residual");
        assert!(ef.residual(base + 1).is_some(), "device 1 residual");
        assert!(ef.residual(base + 2).is_none(), "no phantom third device");
    }

    #[test]
    fn two_tier_wire_schedule_maps_to_leader_ring() {
        // The worker comm is already the leader tier: configuring
        // two_tier must put the flat ring on the wire, record k for the
        // local tier, and price the wire at devices = 1.
        let engine = Arc::new(Engine::new(1));
        let comms = World::create(1);
        let mut kv = KvWorker::create(
            KvType::SyncMpi,
            engine,
            Some(comms.into_iter().next().unwrap()),
            None,
        );
        let mut cost = CostParams::testbed1();
        cost.devices = 4;
        kv.configure_collective(AlgoKind::TwoTier, 2, 2, 0, cost);
        assert_eq!(kv.algo, AlgoKind::Ring);
        assert_eq!(kv.devices, 4);
        assert_eq!(kv.cost.devices, 1);
        // And the local tier averages through the worker's codec state.
        let merged = kv.local_merge(vec![vec![2.0f32, 4.0], vec![6.0, 8.0]], 0);
        assert_eq!(merged, vec![4.0, 6.0]);
    }

    #[test]
    fn engine_pipelines_independent_keys() {
        // Pushing many keys enqueues without blocking; wait_all drains.
        let engine = Arc::new(Engine::new(2));
        let kv = KvWorker::create(KvType::Local, engine, None, None);
        for k in 0..32 {
            kv.init(k, vec![0.0; 8], true);
            kv.push(k, vec![1.0; 8]);
        }
        kv.wait_all();
        for k in 0..32 {
            assert_eq!(kv.pull(k).wait(), vec![1.0; 8]);
        }
    }
}
