//! α-β-γ timing models of the §6/§7.3 allreduce designs.
//!
//! These regenerate Figures 15 and 17–20. Costs follow the paper's own
//! formalism (§6.2: ring allreduce = (p-1)α + 2·(p-1)/p·nβ + (p-1)/p·nγ),
//! extended with the intra-node tensor phases of §6.3 and the multi-ring
//! overlap of Fig. 9. Absolute seconds come from the [`CostParams`]
//! bandwidth constants (taken from the paper where stated); what must hold
//! is the *shape*: who wins, by what factor, where crossovers fall.

use super::{pow2_floor, AlgoKind};
use crate::compress::Compressor;
use crate::netsim::CostParams;

// ---------------------------------------------------------------------------
// Per-algorithm α-β-γ models + the select_best autotuner
// ---------------------------------------------------------------------------

/// One pipelined collective step: a transfer of `bytes` at `(a, b)` split
/// into `k` sub-chunks whose reduction (`g` per byte) overlaps the
/// remaining sub-transfers — the pipeline-fill + steady-state formula
/// `t + (k-1)·max(t, r) + r` with `t = α + nβ/k`, `r = nγ/k`. `k == 1`
/// degenerates to the blocking `α + nβ + nγ`.
fn pipelined_step(bytes: f64, k: f64, a: f64, b: f64, g: f64) -> f64 {
    let t = a + bytes * b / k;
    let r = bytes * g / k;
    t + (k - 1.0) * t.max(r) + r
}

/// Network-level cost of one host-memory allreduce of `bytes` across `p`
/// ranks under the given schedule at pipeline depth
/// `params.pipeline_chunks` — the depth the data path actually runs
/// ([`crate::collectives::allreduce_with`]).
pub fn network_allreduce_seconds(
    kind: AlgoKind,
    p: usize,
    bytes: usize,
    params: &CostParams,
) -> f64 {
    network_allreduce_seconds_chunked(kind, p, bytes, params.pipeline_chunks, params)
}

/// Network-level cost of one host-memory allreduce of `bytes` across `p`
/// ranks under the given schedule, composed per step from
/// [`pipelined_step`] (the §6.2 formalism extended with k-way chunk
/// pipelining; `chunks == 1` reproduces the blocking closed forms):
///
/// * ring — `2(p-1)` steps of chunk `n/p`; blocking total
///   `2(p-1)α + 2·(p-1)/p·nβ + (p-1)/p·nγ` (bandwidth-optimal);
/// * halving-doubling — `lg q` halving exchanges of `n/2^{s+1}` each way;
///   blocking total `2·lg q·α + 2·(q-1)/q·nβ·(1+δ) + (q-1)/q·nγ` plus a
///   `2(α + nβ) + nγ` fold-in when `p` is not a power of two.
///   `δ = hd_contention` models the fabric congestion of the distance-2^k
///   exchanges (ring traffic stays on neighbor links; halving-doubling
///   does not — Shi et al., arXiv:1711.05979, §IV);
/// * hierarchical — intra-group gather+bcast over host memory, plus the
///   ring over `⌈p/g⌉` leaders with `g = gpus_per_worker`;
/// * two_tier — the device tier: blocks of `params.devices` device ranks
///   reduce onto their node leader over the intra-node fabric
///   (`alpha_dev`/`beta_dev`, device-kernel reduction), then the leaders
///   run the ring over an *uncontended* NIC (they own it exclusively).
///
/// **NIC contention**: with `params.devices = k > 1` every node's NIC
/// carries `k` device ranks' traffic, so the flat schedules (ring,
/// halving-doubling, hierarchical) pay `k · beta_net` per byte — the same
/// shared-NIC mechanism as [`Design::BaiduRing`]'s non-topology-aware
/// ring. The two-tier schedule is precisely the escape: only one leader
/// per node touches the network, over a 1/k-sized effective payload per
/// node. At `devices == 1` the contention factor is exactly 1.0 and the
/// two-tier price is bitwise the ring price (zero intra term, leaders =
/// everyone), so [`select_best`]'s first-minimum tie-break keeps picking
/// the flat schedule — `devices == 1` pricing is unchanged from the
/// pre-device-tier model.
///
/// `Auto` returns the minimum over the data-path schedules at the same
/// pipeline depth.
pub fn network_allreduce_seconds_chunked(
    kind: AlgoKind,
    p: usize,
    bytes: usize,
    chunks: usize,
    params: &CostParams,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let n = bytes as f64;
    let k = chunks.max(1) as f64;
    let a = params.alpha_net;
    // k-device NIC sharing: flat schedules put every device rank's
    // traffic through its node's single NIC.
    let b = params.beta_net * params.devices.max(1) as f64;
    let gh = params.gamma_omp;
    match kind {
        AlgoKind::Ring => {
            let pf = p as f64;
            let chunk = n / pf;
            (pf - 1.0) * pipelined_step(chunk, k, a, b, gh)
                + (pf - 1.0) * pipelined_step(chunk, k, a, b, 0.0)
        }
        AlgoKind::HalvingDoubling => {
            let q = pow2_floor(p);
            let bc = b * (1.0 + params.hd_contention);
            let mut t = 0.0;
            let mut win = n / 2.0;
            let mut m = q;
            while m > 1 {
                t += pipelined_step(win, k, a, bc, gh); // halving exchange
                t += pipelined_step(win, k, a, bc, 0.0); // doubling exchange
                win /= 2.0;
                m /= 2;
            }
            if p > q {
                t += 2.0 * (a + n * b) + n * gh; // non-power-of-two fold-in
            }
            t
        }
        AlgoKind::Hierarchical => {
            let g = params.gpus_per_worker.clamp(1, p);
            let leaders = (p + g - 1) / g;
            let gf = g as f64;
            let intra = (gf - 1.0)
                * (pipelined_step(n, k, a, params.beta_hostmem, params.gamma_host)
                    + pipelined_step(n, k, a, params.beta_hostmem, 0.0));
            intra + network_allreduce_seconds_chunked(AlgoKind::Ring, leaders, bytes, chunks, params)
        }
        AlgoKind::TwoTier => {
            let d = params.devices.clamp(1, p);
            let df = d as f64;
            let leaders = (p + d - 1) / d;
            // Device tier: each non-leader device streams its buffer to
            // the node leader over the intra-node fabric; the leader's
            // device-kernel reduction overlaps the remaining sub-chunks.
            let intra = (df - 1.0)
                * (pipelined_step(n, k, params.alpha_dev, params.beta_dev, params.gamma_gpu_ibm)
                    + pipelined_step(n, k, params.alpha_dev, params.beta_dev, 0.0));
            // Leader ring: one rank per node on the wire — the NIC is
            // theirs alone, so the leader phase prices at devices = 1.
            let mut leader_params = params.clone();
            leader_params.devices = 1;
            intra
                + network_allreduce_seconds_chunked(
                    AlgoKind::Ring,
                    leaders,
                    bytes,
                    chunks,
                    &leader_params,
                )
        }
        AlgoKind::Auto => select_best_chunked(bytes, p, chunks, params).1,
    }
}

/// Wire bytes one allreduce of `bytes` moves per node per iteration on
/// each tier, at the bandwidth-optimal asymptote (ring reduce-scatter +
/// allgather moves ~`2·n` per participant per tier). Returned as
/// `(intra_node, inter_node)`:
///
/// * flat (`two_tier == false`): every one of the node's `devices` ranks
///   pushes ~`2n` through the NIC — `(0, 2·n·devices)`;
/// * two-tier: the `devices - 1` non-leaders move `2n` each on the
///   device fabric (gather + broadcast), and only the leader's `2n`
///   crosses the NIC — `(2·n·(devices-1), 2·n)`.
///
/// Exact integer accounting, so `inter(two_tier) * devices ==
/// inter(flat)` holds with no rounding — the ISSUE-8 CI gate in
/// `examples/check_bench.rs`.
pub fn tier_wire_bytes(two_tier: bool, devices: usize, bytes: usize) -> (u64, u64) {
    let k = devices.max(1) as u64;
    let n = bytes as u64;
    if two_tier {
        (2 * n * (k - 1), 2 * n)
    } else {
        (0, 2 * n * k)
    }
}

/// Autotuner: the cheapest data-path schedule for `(bytes, p)` under the
/// α-β-γ model at the data path's pipeline depth. Below the α/β crossover
/// the latency-optimal halving-doubling wins; past it the
/// bandwidth-optimal ring does.
pub fn select_best(bytes: usize, p: usize, params: &CostParams) -> (AlgoKind, f64) {
    select_best_chunked(bytes, p, params.pipeline_chunks, params)
}

/// [`select_best`] at an explicit pipeline depth.
pub fn select_best_chunked(
    bytes: usize,
    p: usize,
    chunks: usize,
    params: &CostParams,
) -> (AlgoKind, f64) {
    if p <= 1 {
        return (AlgoKind::Ring, 0.0);
    }
    AlgoKind::DATA_PATH
        .into_iter()
        .map(|k| (k, network_allreduce_seconds_chunked(k, p, bytes, chunks, params)))
        .min_by(|x, y| x.1.total_cmp(&y.1))
        .expect("non-empty algorithm set")
}

/// [`network_allreduce_seconds`] for a job co-located with `tenants`
/// running jobs on one cluster (the authority's contention pricing,
/// ISSUE 9): the allreduce is modeled on the `tenants`-way partitioned
/// fabric of [`CostParams::contended`] — bandwidth terms inflate with
/// tenancy, per-message latency does not. `tenants <= 1` is exactly the
/// uncontended model.
pub fn contended_allreduce_seconds(
    kind: AlgoKind,
    p: usize,
    bytes: usize,
    tenants: usize,
    params: &CostParams,
) -> f64 {
    network_allreduce_seconds(kind, p, bytes, &params.contended(tenants))
}

// ---------------------------------------------------------------------------
// Compute/communication overlap (DAG-embedded collectives)
// ---------------------------------------------------------------------------

/// Fraction of a training step spent in the backward pass — the window
/// over which per-bucket gradients become ready for DAG-embedded
/// collectives (fwd:bwd ≈ 1:2 for the paper's workloads).
pub const BWD_FRAC: f64 = 0.66;

/// Modeled seconds for one training iteration when each of `buckets`
/// fusion buckets is issued as a dependency-tracked engine op the moment
/// its gradients are ready (arXiv:1802.06949), instead of one blocking
/// allreduce after the full backward pass.
///
/// Bucket i's communication hides under the remaining backward compute
/// (gradients are emitted over the last [`BWD_FRAC`] of `compute_s`) and
/// under later buckets' update window; only the tail bucket — ready when
/// backward ends — is necessarily exposed, plus whatever communication
/// exceeds the overlap window. Never worse than the blocking
/// `compute_s + comm_s`; with one bucket there is nothing to overlap.
pub fn overlapped_step_seconds(compute_s: f64, comm_s: f64, buckets: usize) -> f64 {
    let b = buckets.max(1) as f64;
    let window = compute_s * BWD_FRAC * (b - 1.0) / b;
    let tail = comm_s / b;
    (compute_s + tail + (comm_s - tail - window).max(0.0)).min(compute_s + comm_s)
}

/// Full tensor-allreduce seconds for a schedule: the ring reproduces the
/// [`Design::RingIbm`] model (multi-ring overlap and all) exactly, so a
/// `collective = "ring"` run is bit-identical to the pre-autotuner
/// trainer; the other schedules pay the same intra-node phases (tensor
/// reduce to host, broadcast back, one GpuStart/GpuWait pair each way)
/// around their own network phase.
pub fn tensor_allreduce_seconds(
    kind: AlgoKind,
    p: usize,
    bytes: usize,
    rings: usize,
    params: &CostParams,
) -> f64 {
    match kind {
        AlgoKind::Ring => simulate(Design::RingIbm { rings }, p, bytes, params).seconds,
        AlgoKind::Auto => {
            let (k, _) = select_best(bytes, p, params);
            tensor_allreduce_seconds(k, p, bytes, rings, params)
        }
        k => {
            let n = bytes as f64;
            n * params.gamma_gpu_ibm
                + network_allreduce_seconds(k, p, bytes, params)
                + n * params.beta_gpu_bcast
                + 2.0 * params.gpu_sync
        }
    }
}

/// Modeled seconds for one *compressed* tensor allreduce of `dense_bytes`
/// across `p` ranks — the α-β-γ mirror of
/// [`crate::collectives::compressed_allreduce`]: intra-node reduce +
/// broadcast around a (p−1)-step allgather of the codec's **wire bytes**
/// ([`crate::compress::Compressor::wire_bytes`], exactly what mpisim
/// moves), a decompress-reduce of all `p` decoded payloads at host-reduce
/// speed, and the codec's own γ (one encode, `p` decodes). Identity
/// delegates to [`tensor_allreduce_seconds`] — bitwise the pre-compression
/// pricing, so default-config figures regenerate unchanged.
pub fn compressed_tensor_allreduce_seconds(
    kind: AlgoKind,
    p: usize,
    dense_bytes: usize,
    rings: usize,
    codec: &dyn Compressor,
    params: &CostParams,
) -> f64 {
    if codec.is_identity() {
        return tensor_allreduce_seconds(kind, p, dense_bytes, rings, params);
    }
    let n = dense_bytes as f64;
    // Intra-node phases as in the non-ring arm of tensor_allreduce_seconds.
    let intra = n * params.gamma_gpu_ibm + n * params.beta_gpu_bcast + 2.0 * params.gpu_sync;
    // Encode streams the dense buffer once; decode+fold of a peer payload
    // is payload-proportional (a sparse payload scatter-adds only its k
    // elements; a quantized payload streams its byte count) plus one dense
    // pass to seat our own decoded contribution.
    let wire = codec.wire_bytes(dense_bytes / 4) as f64;
    let encode = n * params.gamma_codec;
    let seat = n * params.gamma_omp + wire * params.gamma_codec;
    if p <= 1 {
        return intra + encode + seat;
    }
    let pf = p as f64;
    let net = (pf - 1.0) * (params.alpha_net + wire * params.beta_net);
    let fold = (pf - 1.0) * wire * (params.gamma_codec + params.gamma_omp);
    intra + encode + seat + net + fold
}

/// The §7.3 design space, one variant per curve in Figs 17–20.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Design {
    /// a) ring-IBMGpu: tensor rings from host memory, IBMGpu reduction
    /// kernels, `rings` logical rings overlapping NVLink math with network.
    RingIbm { rings: usize },
    /// b) ring-NCCL: one ring (NCCL ops are blocking), NCCL reduction BW.
    RingNccl,
    /// c) omp_ring-IBMGpu: reduce whole buffer to host, host bucket ring
    /// with 8 OMP threads for the per-step reductions, copy back.
    OmpRing,
    /// d) reg-IBMGpu: host reduce + default MPI_Allreduce + bcast,
    /// pipelined across the three stages.
    Reg,
    /// Baidu's ring over *every GPU* (Fig. 20 baseline): no node-tensor
    /// grouping, host-staging copies on every hop, non-topology-aware rank
    /// order so every hop crosses the node NIC.
    BaiduRing,
}

impl Design {
    pub fn label(&self) -> String {
        match self {
            Design::RingIbm { rings } => format!("ring-IBMGpu({rings})"),
            Design::RingNccl => "ring-NCCL".into(),
            Design::OmpRing => "omp_ring-IBMGpu".into(),
            Design::Reg => "reg-IBMGpu".into(),
            Design::BaiduRing => "Baidu-ring".into(),
        }
    }
}

/// Result of one simulated allreduce.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub design_label: String,
    /// Workers (ring participants; 2 per Minsky node).
    pub p: usize,
    /// Message bytes per device vector.
    pub bytes: usize,
    /// Virtual seconds for the full tensor allreduce.
    pub seconds: f64,
    /// Effective bandwidth: bytes / seconds (the Figs 17–19 y-axis).
    pub gbps: f64,
}

/// Ring phase cost on host memories: 2(p-1) steps of (α + chunk·β) plus the
/// per-step reduction γ over the reduce-scatter half; `overlap` subtracts
/// whatever reduction time hides under the network transfer (multi-ring).
fn ring_phase(p: usize, n: f64, alpha: f64, beta: f64, gamma: f64, overlap: bool) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let pf = p as f64;
    let steps = 2.0 * (pf - 1.0);
    let net = steps * alpha + 2.0 * (pf - 1.0) / pf * n * beta;
    let red = (pf - 1.0) / pf * n * gamma;
    if overlap {
        // Reduction of ring i hides under transfer of ring i+1 (Fig. 9);
        // only whatever exceeds the network time is exposed.
        net + (red - net).max(0.0)
    } else {
        net + red
    }
}

/// Simulate one tensor allreduce of `bytes` per device vector across `p`
/// workers (each grouping `params.gpus_per_worker` device vectors).
pub fn simulate(design: Design, p: usize, bytes: usize, params: &CostParams) -> SimResult {
    let n = bytes as f64;
    let a = params.alpha_net;
    let seconds = match design {
        Design::RingIbm { rings } => {
            let r = rings.max(1) as f64;
            // Startup: the first ring's slice must be tensor-reduced into
            // host memory before its network phase can start; subsequent
            // slices overlap with the previous ring's transfer.
            let startup = n / r * params.gamma_gpu_ibm;
            // Per-ring latency terms multiply; bytes are shared by the NIC.
            let net = 2.0 * (p as f64 - 1.0) * r * a
                + if p > 1 {
                    2.0 * (p as f64 - 1.0) / p as f64 * n * params.beta_net
                } else {
                    0.0
                };
            // Per-step NVLink reductions overlap with network when r >= 2.
            let red = if p > 1 {
                (p as f64 - 1.0) / p as f64 * n * params.gamma_gpu_ibm
            } else {
                0.0
            };
            let exposed_red = if rings >= 2 { (red - net).max(0.0) } else { red };
            // Final intra-node broadcast back to the device vectors.
            let bcast = n * params.beta_gpu_bcast;
            // GpuStart/GpuWait pipelining (Fig. 9): one launch+sync pair per
            // ring, not per step.
            let sync = 2.0 * r * params.gpu_sync;
            startup + net + exposed_red + bcast + sync
        }
        Design::RingNccl => {
            // Blocking NCCL ops: no overlap anywhere, NCCL reduce BW, and a
            // kernel launch + sync on every ring step (§7.3).
            let reduce = n * params.gamma_gpu_nccl + params.gpu_sync;
            let ring = ring_phase(p, n, a, params.beta_net, params.gamma_gpu_nccl, false)
                + 2.0 * (p.saturating_sub(1)) as f64 * params.gpu_sync;
            let bcast = n * params.beta_gpu_bcast + params.gpu_sync;
            reduce + ring + bcast
        }
        Design::OmpRing => {
            // Whole buffer reduced into host first (IBMGpu kernels), then a
            // host bucket ring whose per-step math runs on 8 OMP threads
            // (an OMP fork/join barrier per step).
            let omp_barrier = 5e-6;
            let reduce = n * params.gamma_gpu_ibm + params.gpu_sync;
            let ring = ring_phase(p, n, a, params.beta_net, params.gamma_omp, false)
                + (p.saturating_sub(1)) as f64 * omp_barrier;
            let copy_back = n * params.beta_gpu_bcast + params.gpu_sync;
            reduce + ring + copy_back
        }
        Design::Reg => {
            // Three stages pipelined over CHUNKS chunks: steady state is
            // bounded by the slowest stage, plus pipeline fill.
            const CHUNKS: f64 = 4.0;
            let s1 = n * params.gamma_gpu_ibm;
            // "default MPI_Allreduce": recursive doubling — log2(p) rounds
            // each moving the FULL buffer and reducing it at host speed
            // (not bandwidth-optimal; this is exactly what the paper's
            // bucket rings replace, §6.2).
            let rounds = (p.max(2) as f64).log2().ceil();
            let s2 = if p > 1 {
                rounds * (a + n * params.beta_net + n * params.gamma_host)
            } else {
                0.0
            };
            let s3 = n * params.beta_gpu_bcast;
            let max = s1.max(s2).max(s3);
            // Per-chunk stage handoffs are blocking syncs.
            let sync = 3.0 * CHUNKS * params.gpu_sync;
            (s1 + s2 + s3) / CHUNKS + max * (CHUNKS - 1.0) / CHUNKS + sync
        }
        Design::BaiduRing => {
            // Ring over every GPU: pg participants, each hop staged through
            // host memory (2 extra copies, §6.3) and — with non-topology-
            // aware ordering — crossing the node NIC, which therefore
            // carries g concurrent chunk flows per step.
            let g = params.gpus_per_worker as f64;
            let pg = p as f64 * g;
            if pg <= 1.0 {
                0.0
            } else {
                let chunk = n / pg;
                let steps = 2.0 * (pg - 1.0);
                let per_step = a
                    + params.gpu_sync
                    + chunk * (g * params.beta_net + 2.0 * params.beta_h2d);
                // Per-step GPU math (no IBMGpu kernels: NCCL-class BW),
                // blocking within each step.
                let red_steps = pg - 1.0;
                steps * per_step + red_steps * chunk * params.gamma_gpu_nccl
            }
        }
    };
    SimResult {
        design_label: design.label(),
        p,
        bytes,
        seconds,
        gbps: bytes as f64 / seconds.max(1e-12) / 1e9,
    }
}

/// Sweep helper: all designs at one (p, bytes) point.
pub fn compare_designs(p: usize, bytes: usize, params: &CostParams) -> Vec<SimResult> {
    [
        Design::RingIbm { rings: 2 },
        Design::RingNccl,
        Design::OmpRing,
        Design::Reg,
    ]
    .into_iter()
    .map(|d| simulate(d, p, bytes, params))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minsky() -> CostParams {
        CostParams::minsky()
    }

    #[test]
    fn ring_ibm_beats_all_at_mid_sizes() {
        // Figs 17-19: the IBMGpu multi-ring is best at 4/16/64 MB.
        for bytes in [4 << 20, 16 << 20, 64 << 20] {
            let res = compare_designs(16, bytes, &minsky());
            let best = res
                .iter()
                .min_by(|a, b| a.seconds.total_cmp(&b.seconds))
                .unwrap();
            assert_eq!(best.design_label, "ring-IBMGpu(2)", "at {bytes}: {res:?}");
        }
    }

    #[test]
    fn contended_allreduce_prices_tenancy_monotonically() {
        let m = minsky();
        let (p, bytes) = (8, 16 << 20);
        let solo = contended_allreduce_seconds(AlgoKind::Ring, p, bytes, 1, &m);
        assert_eq!(solo, network_allreduce_seconds(AlgoKind::Ring, p, bytes, &m));
        let two = contended_allreduce_seconds(AlgoKind::Ring, p, bytes, 2, &m);
        let four = contended_allreduce_seconds(AlgoKind::Ring, p, bytes, 4, &m);
        assert!(solo < two && two < four, "{solo} {two} {four}");
        // Single-rank jobs never touch the shared fabric: free at any tenancy.
        assert_eq!(contended_allreduce_seconds(AlgoKind::Ring, 1, bytes, 4, &m), 0.0);
    }

    #[test]
    fn gap_narrows_at_large_messages() {
        // §7.3: "for very large messages, the performance gap diminishes
        // across the three" (the three ring designs a/b/c), as fixed
        // per-step costs amortize and all hit the bandwidth wall.
        let m = minsky();
        let ratio = |bytes: usize| {
            let ibm = simulate(Design::RingIbm { rings: 2 }, 16, bytes, &m).seconds;
            let nccl = simulate(Design::RingNccl, 16, bytes, &m).seconds;
            let omp = simulate(Design::OmpRing, 16, bytes, &m).seconds;
            nccl.max(omp) / ibm
        };
        assert!(ratio(256 << 20) < ratio(4 << 20));
    }

    #[test]
    fn ibm_vs_baidu_factor_is_paper_scale() {
        // Fig 20: ~6x for the same number of GPUs.
        let p = 16; // 32 GPUs
        let bytes = 16 << 20;
        let ibm = simulate(Design::RingIbm { rings: 2 }, p, bytes, &minsky());
        let baidu = simulate(Design::BaiduRing, p, bytes, &minsky());
        let factor = baidu.seconds / ibm.seconds;
        assert!(factor > 3.0 && factor < 10.0, "factor {factor}");
    }

    #[test]
    fn cost_monotone_in_bytes_and_p() {
        let m = minsky();
        for d in [
            Design::RingIbm { rings: 2 },
            Design::RingNccl,
            Design::OmpRing,
            Design::Reg,
            Design::BaiduRing,
        ] {
            let t1 = simulate(d, 8, 1 << 20, &m).seconds;
            let t2 = simulate(d, 8, 4 << 20, &m).seconds;
            assert!(t2 > t1, "{d:?} not monotone in bytes");
            let t3 = simulate(d, 16, 4 << 20, &m).seconds;
            assert!(t3 > t1, "{d:?} not monotone in p");
        }
    }

    #[test]
    fn single_worker_has_no_network_cost() {
        let m = minsky();
        let r = simulate(Design::RingIbm { rings: 2 }, 1, 16 << 20, &m);
        // Only intra-node reduce + bcast (+ per-ring syncs) remain.
        let n = (16 << 20) as f64;
        let expect = n / 2.0 * m.gamma_gpu_ibm + n * m.beta_gpu_bcast + 4.0 * m.gpu_sync;
        assert!((r.seconds - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn multi_ring_overlap_helps() {
        let m = minsky();
        let one = simulate(Design::RingIbm { rings: 1 }, 16, 64 << 20, &m);
        let two = simulate(Design::RingIbm { rings: 2 }, 16, 64 << 20, &m);
        assert!(two.seconds < one.seconds, "{} !< {}", two.seconds, one.seconds);
    }

    #[test]
    fn select_best_picks_hd_small_ring_large() {
        // Below the α/β crossover the latency-optimal halving-doubling
        // wins; above it the bandwidth-optimal ring does (the acceptance
        // shape of the autotuner).
        let m = minsky();
        let p = 16;
        assert_eq!(select_best(4 << 10, p, &m).0, AlgoKind::HalvingDoubling);
        assert_eq!(select_best(64 << 20, p, &m).0, AlgoKind::Ring);
        // The winner changes at least once over the sweep, and the two
        // regimes are contiguous (no flip-flopping back to HD at the top).
        let mut last_hd = 0usize;
        let mut first_ring_after = usize::MAX;
        for shift in 10..28 {
            let bytes = 1usize << shift;
            match select_best(bytes, p, &m).0 {
                AlgoKind::HalvingDoubling => last_hd = bytes,
                AlgoKind::Ring if first_ring_after == usize::MAX => first_ring_after = bytes,
                _ => {}
            }
        }
        assert!(last_hd > 0 && first_ring_after < usize::MAX);
        assert!(last_hd < 64 << 20, "hd still winning at huge messages");
    }

    #[test]
    fn network_costs_monotone_and_positive() {
        let m = minsky();
        for k in AlgoKind::DATA_PATH {
            let t1 = network_allreduce_seconds(k, 8, 1 << 16, &m);
            let t2 = network_allreduce_seconds(k, 8, 1 << 22, &m);
            assert!(t1 > 0.0 && t2 > t1, "{k:?}");
            assert_eq!(network_allreduce_seconds(k, 1, 1 << 20, &m), 0.0);
        }
    }

    #[test]
    fn two_tier_prices_bitwise_as_ring_at_one_device() {
        // With devices = 1 the intra term is exactly 0.0 and the leader
        // ring spans every rank, so the two-tier price must be *bitwise*
        // the flat ring price — the satellite-4 degeneracy requirement.
        let m = minsky();
        for p in [2usize, 3, 8, 16] {
            for bytes in [1usize << 10, 1 << 16, 64 << 20] {
                let tt = network_allreduce_seconds(AlgoKind::TwoTier, p, bytes, &m);
                let ring = network_allreduce_seconds(AlgoKind::Ring, p, bytes, &m);
                assert_eq!(tt, ring, "p={p} bytes={bytes}");
            }
        }
    }

    #[test]
    fn select_best_never_two_tier_at_one_device() {
        // Equal price + first-minimum tie-break: the flat schedule wins
        // every tie, so the autotuner must never surface TwoTier when
        // there is no device tier to exploit.
        let m = minsky();
        for p in [2usize, 4, 16, 17] {
            for shift in 8..28 {
                let (k, _) = select_best(1usize << shift, p, &m);
                assert_ne!(k, AlgoKind::TwoTier, "p={p} bytes=2^{shift}");
            }
        }
    }

    #[test]
    fn two_tier_wins_large_messages_with_devices() {
        // p = 16 device ranks, 4 per node: the flat schedules pay 4-way
        // NIC contention while two-tier reduces on NVLink first — at
        // bandwidth-bound sizes two-tier must beat every flat schedule
        // and the autotuner must pick it.
        let mut m = minsky();
        m.devices = 4;
        let p = 16;
        let bytes = 64 << 20;
        let tt = network_allreduce_seconds(AlgoKind::TwoTier, p, bytes, &m);
        for flat in [AlgoKind::Ring, AlgoKind::HalvingDoubling, AlgoKind::Hierarchical] {
            let t = network_allreduce_seconds(flat, p, bytes, &m);
            assert!(tt < t, "{:?}: two_tier {tt} !< {t}", flat);
        }
        assert_eq!(select_best(bytes, p, &m).0, AlgoKind::TwoTier);
    }

    #[test]
    fn flat_pricing_unchanged_by_device_knob_at_one() {
        // The presets carry devices = 1; multiplying beta_net by 1.0 is
        // exact, so every pre-device-tier number regenerates bitwise.
        let m = minsky();
        assert_eq!(m.devices, 1);
        assert_eq!(CostParams::testbed1().devices, 1);
        let contended = {
            let mut c = m.clone();
            c.devices = 2;
            c
        };
        for k in [AlgoKind::Ring, AlgoKind::HalvingDoubling, AlgoKind::Hierarchical] {
            let base = network_allreduce_seconds(k, 8, 1 << 20, &m);
            let shared = network_allreduce_seconds(k, 8, 1 << 20, &contended);
            assert!(shared > base, "{k:?}: contention must cost");
        }
    }

    #[test]
    fn tier_wire_bytes_inter_is_exactly_one_kth() {
        for devices in 1..=8usize {
            for bytes in [1usize, 4096, 102 << 20] {
                let (flat_intra, flat_inter) = tier_wire_bytes(false, devices, bytes);
                let (tt_intra, tt_inter) = tier_wire_bytes(true, devices, bytes);
                assert_eq!(flat_intra, 0);
                // The acceptance gate: exact integer 1/k, no rounding.
                assert_eq!(tt_inter * devices as u64, flat_inter, "k={devices}");
                assert_eq!(tt_intra, 2 * bytes as u64 * (devices as u64 - 1));
            }
        }
    }

    #[test]
    fn hd_pays_fold_in_for_non_power_of_two() {
        let m = minsky();
        let t8 = network_allreduce_seconds(AlgoKind::HalvingDoubling, 8, 1 << 20, &m);
        let t9 = network_allreduce_seconds(AlgoKind::HalvingDoubling, 9, 1 << 20, &m);
        assert!(t9 > t8);
    }

    #[test]
    fn tensor_seconds_ring_matches_design_ring_ibm() {
        // collective = "ring" must keep the exact pre-autotuner numbers.
        let m = minsky();
        for (p, bytes, rings) in [(6, 102 << 20, 2), (16, 4 << 20, 1), (2, 1 << 16, 4)] {
            let a = tensor_allreduce_seconds(AlgoKind::Ring, p, bytes, rings, &m);
            let b = simulate(Design::RingIbm { rings }, p, bytes, &m).seconds;
            assert_eq!(a, b);
        }
    }

    #[test]
    fn auto_never_beats_its_own_components() {
        let m = minsky();
        for bytes in [1 << 12, 1 << 18, 1 << 24] {
            let auto = network_allreduce_seconds(AlgoKind::Auto, 12, bytes, &m);
            for k in AlgoKind::DATA_PATH {
                assert!(auto <= network_allreduce_seconds(k, 12, bytes, &m) + 1e-15);
            }
        }
    }

    #[test]
    fn compressed_model_identity_bitwise_and_sane_shape() {
        use crate::compress::{Codec, Identity};
        let m = minsky();
        // Identity pricing is bitwise the dense pricing (default-config
        // figures regenerate unchanged).
        for (p, bytes) in [(6usize, 102usize << 20), (16, 4 << 20), (1, 1 << 16)] {
            let a = compressed_tensor_allreduce_seconds(AlgoKind::Ring, p, bytes, 2, &Identity, &m);
            let b = tensor_allreduce_seconds(AlgoKind::Ring, p, bytes, 2, &m);
            assert_eq!(a, b);
        }
        // Lossy codecs: positive, monotone in bytes and p, and the sparser
        // codec moves less wire so it models cheaper than int8.
        let int8 = Codec::named("int8").build(0.01);
        let topk = Codec::named("topk").build(0.01);
        for codec in [&*int8, &*topk] {
            let t1 = compressed_tensor_allreduce_seconds(AlgoKind::Ring, 6, 4 << 20, 2, codec, &m);
            let t2 = compressed_tensor_allreduce_seconds(AlgoKind::Ring, 6, 64 << 20, 2, codec, &m);
            let t3 = compressed_tensor_allreduce_seconds(AlgoKind::Ring, 12, 4 << 20, 2, codec, &m);
            assert!(t1 > 0.0 && t2 > t1 && t3 > t1, "{}", codec.name());
        }
        let bytes = 102 << 20;
        let ti = compressed_tensor_allreduce_seconds(AlgoKind::Ring, 6, bytes, 2, &*int8, &m);
        let tt = compressed_tensor_allreduce_seconds(AlgoKind::Ring, 6, bytes, 2, &*topk, &m);
        assert!(tt < ti, "{tt} !< {ti}");
        // On the *fast* MPI fabric the dense bandwidth-optimal ring is
        // already near the wire bound, so the codec γ keeps compression
        // from a clean win there; its network term alone must still be a
        // fraction of the dense schedule's. The end-to-end payoff is on
        // the TCP-class PS path (PsFabric moves the codec's wire bytes) —
        // exactly the paper's §2.3 bottleneck story.
        let wire = topk.wire_bytes(bytes / 4);
        assert!(wire * 20 < bytes, "topk wire {wire} not << {bytes}");
        let ps_dense = bytes as f64 * m.beta_ps;
        let ps_topk = wire as f64 * m.beta_ps
            + crate::compress::codec_seconds(&*topk, bytes, &m);
        assert!(ps_topk < ps_dense / 2.0, "{ps_topk} !< {ps_dense}/2");
    }

    #[test]
    fn reg_allreduce_degrades_with_scale() {
        // The recursive-doubling "default MPI_Allreduce" moves the full
        // buffer log2(p) times, so its gap to the bandwidth-optimal ring
        // widens with p (Fig. 15's end-to-end "nearly twice as fast" —
        // with compute in the denominator — is asserted in figures.rs).
        let m = minsky();
        let f_at = |p: usize| {
            let ring = simulate(Design::RingIbm { rings: 2 }, p, 100 << 20, &m);
            let reg = simulate(Design::Reg, p, 100 << 20, &m);
            reg.seconds / ring.seconds
        };
        assert!(f_at(8) > 1.5, "{}", f_at(8));
        assert!(f_at(32) > f_at(8));
    }
}
