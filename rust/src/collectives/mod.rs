//! Tensor collectives (paper §6): bucket ring algorithms over node tensors.
//!
//! Two halves:
//!
//! * **Real data movement** (this file) — ring reduce-scatter / allgather /
//!   allreduce built on [`crate::mpisim`] point-to-point sends, plus the
//!   tensor variants that pre-reduce the per-device vector group into host
//!   memory and broadcast the result back (§6.3). These run on the actual
//!   training path of the threaded framework and are the correctness-
//!   critical code.
//! * **Timing simulation** ([`sim`]) — the α-β-γ cost models that regenerate
//!   the paper's bandwidth/scaling figures (Figs 15, 17–20) on the
//!   [`crate::netsim`] substrate.

pub mod sim;

use crate::mpisim::Comm;
use crate::tensor::{add_assign, NodeTensor};

/// Tag base for ring steps; mpisim collectives use the high bit, rings use
/// plain user tags namespaced per call via an internal counter.
const RING_TAG: u64 = 0x5247; // "RG"

/// Partition `len` into `p` near-equal chunks; returns (start, end) of `i`.
pub fn chunk_bounds(len: usize, p: usize, i: usize) -> (usize, usize) {
    let base = len / p;
    let rem = len % p;
    let start = i * base + i.min(rem);
    let end = start + base + usize::from(i < rem);
    (start, end)
}

/// Bucket ring reduce-scatter (§6.2): after the call, rank `r` holds the
/// fully reduced chunk `(r + 1) % p` of `data`; other chunks are garbage
/// (partial sums). Returns the owned chunk index.
pub fn ring_reduce_scatter(comm: &mut Comm, data: &mut [f32]) -> usize {
    let p = comm.size();
    let r = comm.rank();
    if p == 1 {
        return 0;
    }
    let right = (r + 1) % p;
    let left = (r + p - 1) % p;
    for step in 0..p - 1 {
        let send_idx = (r + p - step) % p;
        let recv_idx = (r + p - step - 1) % p;
        let (ss, se) = chunk_bounds(data.len(), p, send_idx);
        let (rs, re) = chunk_bounds(data.len(), p, recv_idx);
        let incoming = comm.sendrecv(
            right,
            RING_TAG + step as u64,
            data[ss..se].to_vec(),
            left,
            RING_TAG + step as u64,
        );
        add_assign(&mut data[rs..re], &incoming);
    }
    (r + 1) % p
}

/// Bucket ring allgather (§6.3.1): rank `r` enters owning chunk
/// `(r + 1) % p` (the reduce-scatter output) and exits with every chunk.
pub fn ring_allgather(comm: &mut Comm, data: &mut [f32]) {
    let p = comm.size();
    let r = comm.rank();
    if p == 1 {
        return;
    }
    let right = (r + 1) % p;
    let left = (r + p - 1) % p;
    for step in 0..p - 1 {
        let send_idx = (r + 1 + p - step) % p;
        let recv_idx = (r + p - step) % p;
        let (ss, se) = chunk_bounds(data.len(), p, send_idx);
        let (rs, re) = chunk_bounds(data.len(), p, recv_idx);
        let incoming = comm.sendrecv(
            right,
            RING_TAG + 100 + step as u64,
            data[ss..se].to_vec(),
            left,
            RING_TAG + 100 + step as u64,
        );
        data[rs..re].copy_from_slice(&incoming);
    }
}

/// Bandwidth-optimal ring allreduce = reduce-scatter + allgather (§6.2).
/// Cost: (p-1)α·2 + 2·(p-1)/p·nβ + (p-1)/p·nγ — the §6.2 lower bound.
pub fn ring_allreduce(comm: &mut Comm, data: &mut [f32]) {
    ring_reduce_scatter(comm, data);
    ring_allgather(comm, data);
}

/// Multi-ring allreduce (§6.3.2, Fig. 9): the buffer is split equally among
/// `rings` logical rings, each running the bucket algorithm on its slice.
///
/// In the paper the rings exist to *overlap* the NVLink reduction of ring i
/// with the network transfer of ring i+1; data-wise the result is identical
/// to a single ring, which is exactly what this implementation (and its
/// tests) asserts. The timing benefit is modelled in [`sim`].
pub fn multi_ring_allreduce(comm: &mut Comm, data: &mut [f32], rings: usize) {
    let rings = rings.max(1).min(data.len().max(1));
    let len = data.len();
    for ring in 0..rings {
        let (s, e) = chunk_bounds(len, rings, ring);
        ring_allreduce(comm, &mut data[s..e]);
    }
}

/// Strategy for the intra-node (device group -> host) reduction of a
/// tensor collective. On the paper's hardware this is the IBMGpu or NCCL
/// kernel; on the training path it can be the AOT-compiled `tensor_reduce`
/// Pallas kernel via a caller-supplied closure.
pub enum HostReduce<'a> {
    /// Plain Rust f32 summation (host memory, the omp_ring analog).
    Host,
    /// Caller-supplied reducer, e.g. the compiled HLO `tensor_reduce`.
    Custom(&'a dyn Fn(&NodeTensor) -> Vec<f32>),
}

/// Tensor allreduce (§6.3): intra-node reduce of the vector group into host
/// memory, host-memory multi-ring bucket allreduce across workers, then
/// intra-node broadcast back to every device vector.
///
/// This is the paper's headline collective: rings run over *host* memories
/// (GPU memory is unreachable from the NIC on Minsky), and grouping the
/// per-socket GPUs under one worker halves the ring hop count.
pub fn tensor_allreduce(
    comm: &mut Comm,
    tensor: &mut NodeTensor,
    rings: usize,
    reduce: HostReduce<'_>,
) {
    let mut host = match reduce {
        HostReduce::Host => tensor.reduce_to_host(),
        HostReduce::Custom(f) => f(tensor),
    };
    multi_ring_allreduce(comm, &mut host, rings);
    tensor.broadcast_from_host(&host);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::World;
    use std::thread;

    fn run_world<F, R>(size: usize, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Clone + Send + 'static,
        R: Send + 'static,
    {
        let comms = World::create(size);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn payload(rank: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| (rank * 1000 + i) as f32 * 0.25)
            .collect()
    }

    fn expected_sum(p: usize, len: usize) -> Vec<f32> {
        let mut out = vec![0.0; len];
        for r in 0..p {
            add_assign(&mut out, &payload(r, len));
        }
        out
    }

    #[test]
    fn chunk_bounds_cover_exactly() {
        for len in [0, 1, 7, 64, 65] {
            for p in [1, 2, 3, 8] {
                let mut total = 0;
                let mut prev_end = 0;
                for i in 0..p {
                    let (s, e) = chunk_bounds(len, p, i);
                    assert_eq!(s, prev_end);
                    total += e - s;
                    prev_end = e;
                }
                assert_eq!(total, len);
            }
        }
    }

    #[test]
    fn ring_allreduce_matches_sum() {
        for p in [1, 2, 3, 4, 6] {
            for len in [1, 5, 64, 257] {
                let out = run_world(p, move |mut c| {
                    let mut d = payload(c.rank(), len);
                    ring_allreduce(&mut c, &mut d);
                    d
                });
                let want = expected_sum(p, len);
                for d in out {
                    assert_eq!(d, want, "p={p} len={len}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_owned_chunk_is_reduced() {
        let p = 4;
        let len = 64;
        let out = run_world(p, move |mut c| {
            let mut d = payload(c.rank(), len);
            let owned = ring_reduce_scatter(&mut c, &mut d);
            let (s, e) = chunk_bounds(len, p, owned);
            (owned, d[s..e].to_vec())
        });
        let want = expected_sum(p, len);
        for (r, (owned, chunk)) in out.iter().enumerate() {
            assert_eq!(*owned, (r + 1) % p);
            let (s, e) = chunk_bounds(len, p, *owned);
            assert_eq!(chunk[..], want[s..e], "rank {r}");
        }
    }

    #[test]
    fn multi_ring_equals_single_ring() {
        let p = 3;
        let len = 100;
        for rings in [1, 2, 4, 7] {
            let out = run_world(p, move |mut c| {
                let mut d = payload(c.rank(), len);
                multi_ring_allreduce(&mut c, &mut d, rings);
                d
            });
            let want = expected_sum(p, len);
            for d in out {
                assert_eq!(d, want, "rings={rings}");
            }
        }
    }

    #[test]
    fn tensor_allreduce_sums_all_devices_all_workers() {
        let p = 3;
        let g = 2;
        let len = 50;
        let out = run_world(p, move |mut c| {
            let vecs: Vec<Vec<f32>> = (0..g)
                .map(|d| payload(c.rank() * g + d, len))
                .collect();
            let mut t = NodeTensor::from_vecs(vecs);
            tensor_allreduce(&mut c, &mut t, 2, HostReduce::Host);
            t
        });
        let mut want = vec![0.0; len];
        for v in 0..p * g {
            add_assign(&mut want, &payload(v, len));
        }
        for t in out {
            for v in &t.vecs {
                assert_eq!(*v, want);
            }
        }
    }

    #[test]
    fn tensor_allreduce_custom_reducer_used() {
        let p = 2;
        let out = run_world(p, move |mut c| {
            let mut t = NodeTensor::from_vecs(vec![vec![1.0; 8], vec![2.0; 8]]);
            let reducer = |t: &NodeTensor| t.reduce_to_host();
            tensor_allreduce(&mut c, &mut t, 1, HostReduce::Custom(&reducer));
            t.vecs[0][0]
        });
        // 2 workers x (1+2) = 6.
        assert!(out.iter().all(|&x| x == 6.0));
    }

    #[test]
    fn ring_allreduce_len_smaller_than_ranks() {
        let p = 5;
        let out = run_world(p, move |mut c| {
            let mut d = vec![c.rank() as f32 + 1.0; 2]; // len < p
            ring_allreduce(&mut c, &mut d);
            d
        });
        for d in out {
            assert_eq!(d, vec![15.0, 15.0]);
        }
    }
}
