//! Tensor collectives (paper §6): pluggable allreduce algorithms over node
//! tensors.
//!
//! Two halves:
//!
//! * **Real data movement** (this file) — the [`CollectiveAlgo`] strategy
//!   layer with three algorithms built on the **nonblocking request
//!   primitives** of [`crate::mpisim`] (`isend`/`irecv`/`wait_any`): the
//!   bucket **ring** (bandwidth-optimal, §6.2), recursive
//!   **halving-doubling** (latency-optimal for small tensors; the MPICH
//!   reduce-scatter + allgather schedule with non-power-of-two fold-in),
//!   and a **two-level hierarchical** allreduce (intra-group reduce →
//!   leader ring → intra-group broadcast, the §6.3 node-grouping idea
//!   applied inside a client). Every schedule is a **k-way chunk-pipelined
//!   state machine**: each step's message is split into `k` sub-chunks and
//!   folded in via `wait_any` as each arrives, so step s+1's send overlaps
//!   step s's remaining receives and reduction (arXiv:1802.06949's
//!   DAG-embedded collectives; `chunks == 1` is exactly the blocking
//!   schedule, which stays the correctness baseline). Plus the tensor
//!   variants that pre-reduce the per-device vector group into host memory
//!   and broadcast back (§6.3), and gradient **fusion**
//!   ([`fused_allreduce`] / [`fusion_buckets`]) that coalesces small keys
//!   into one message before dispatch. These run on the actual training
//!   path of the threaded framework and are the correctness-critical code.
//! * **Timing simulation** ([`sim`]) — the α-β-γ cost models that regenerate
//!   the paper's bandwidth/scaling figures (Figs 15, 17–20) on the
//!   [`crate::netsim`] substrate, one per algorithm (with the chunk
//!   pipeline's latency/overlap terms), [`sim::select_best`] auto-tuning
//!   the choice per message size (cf. Shi et al., arXiv:1711.05979), and
//!   [`sim::overlapped_step_seconds`] pricing compute/communication
//!   overlap for the virtual-clock trainers.

pub mod sim;

use crate::compress::{ef_compress_in_place, Compressed, Compressor, EfState};
use crate::mpisim::{Comm, CommOps};
use crate::netsim::CostParams;
use crate::tensor::{add_assign, NodeTensor};

/// Tag bases for the algorithm families; mpisim collectives use the high
/// bit, these use plain user tags. Pipelined schedules consume
/// `steps * chunks` consecutive tags per phase, so the bases are spaced
/// [`TAG_SPACING`] apart (debug-asserted); across consecutive calls the
/// per-pair FIFO of [`crate::mpisim`] plus posting-order matching
/// preserves correctness.
pub(crate) const TAG_SPACING: u64 = 1 << 20;
pub(crate) const RING_RS_TAG: u64 = TAG_SPACING;
pub(crate) const RING_AG_TAG: u64 = 2 * TAG_SPACING;
pub(crate) const SUBSET_RS_TAG: u64 = 3 * TAG_SPACING;
pub(crate) const SUBSET_AG_TAG: u64 = 4 * TAG_SPACING;
pub(crate) const HD_RS_TAG: u64 = 5 * TAG_SPACING;
pub(crate) const HD_AG_TAG: u64 = 6 * TAG_SPACING;
pub(crate) const HD_FOLD_TAG: u64 = 7 * TAG_SPACING;
pub(crate) const HIER_GATHER_TAG: u64 = 8 * TAG_SPACING;
pub(crate) const HIER_BCAST_TAG: u64 = 9 * TAG_SPACING;
pub(crate) const COMPRESS_TAG: u64 = 10 * TAG_SPACING;
pub(crate) const DEV_GATHER_TAG: u64 = 11 * TAG_SPACING;
pub(crate) const DEV_BCAST_TAG: u64 = 12 * TAG_SPACING;

/// Default sub-chunks per pipelined step when no [`CostParams`] is in
/// scope (the presets carry their own tuned value).
pub const DEFAULT_PIPELINE_CHUNKS: usize = 4;

/// Largest power of two <= `p` — the halving-doubling survivor count. The
/// data path and the cost model ([`sim`]) must agree on this for the
/// fold-in accounting to match reality, so it exists exactly once.
pub(crate) fn pow2_floor(p: usize) -> usize {
    let mut q = 1usize;
    while q * 2 <= p {
        q *= 2;
    }
    q
}

/// Partition `len` into `p` near-equal chunks; returns (start, end) of `i`.
pub fn chunk_bounds(len: usize, p: usize, i: usize) -> (usize, usize) {
    let base = len / p;
    let rem = len % p;
    let start = i * base + i.min(rem);
    let end = start + base + usize::from(i < rem);
    (start, end)
}

/// Sub-range `sub` (of `k`) within the half-open range `[lo, hi)`.
fn sub_bounds(lo: usize, hi: usize, k: usize, sub: usize) -> (usize, usize) {
    let (s, e) = chunk_bounds(hi - lo, k, sub);
    (lo + s, lo + e)
}

/// Clamp the pipeline depth so a `steps`-step schedule never emits a tag
/// outside its [`TAG_SPACING`] family window, and *prove* it: the fit is a
/// checked assertion on every build (promoted from a debug-only assert),
/// and a clamp below the requested depth is reported once per
/// (schedule, requested, limit) instead of shrinking the pipeline
/// invisibly. Identical on every rank: derived only from `steps` and
/// `requested`.
pub(crate) fn clamp_pipeline_chunks(schedule: &'static str, requested: usize, steps: usize) -> usize {
    let limit = (TAG_SPACING as usize / steps.max(1)).max(1);
    let k = requested.max(1).min(limit);
    assert!(
        (steps.max(1) as u64).saturating_mul(k as u64) <= TAG_SPACING,
        "{schedule}: pipeline tags escape the family window: \
         {steps} steps x {k} chunks > {TAG_SPACING}"
    );
    if k < requested {
        warn_clamp_once(schedule, requested, k);
    }
    k
}

/// Log a pipeline-depth clamp exactly once per distinct triple, so a long
/// training run reports the silent degradation without spamming stderr.
fn warn_clamp_once(schedule: &'static str, requested: usize, got: usize) {
    use std::collections::HashSet;
    use std::sync::{Mutex, OnceLock};
    static SEEN: OnceLock<Mutex<HashSet<(&'static str, usize, usize)>>> = OnceLock::new();
    let seen = SEEN.get_or_init(|| Mutex::new(HashSet::new()));
    let fresh = seen
        .lock()
        .expect("clamp-warning registry poisoned")
        .insert((schedule, requested, got));
    if fresh {
        eprintln!(
            "[collectives] {schedule}: requested pipeline depth {requested} \
             clamped to {got} (tag-window limit)"
        );
    }
}

/// One bucket-ring phase over an arbitrary rank list: the reduce-scatter
/// schedule (`gather == false`, incoming chunks are summed) or the
/// allgather schedule (`gather == true`, incoming chunks are copied).
/// `idx` is this rank's position in the logical ring of `l` members whose
/// physical neighbors are `right`/`left`. Shared by the full-communicator
/// ring and the subset ring so the correctness-critical step/chunk/tag
/// logic exists exactly once.
///
/// The phase runs as a k-way chunk-pipelined state machine over
/// nonblocking requests: every step's receives are posted up front, each
/// step's chunk is split into `chunks` sub-chunks, and — because the chunk
/// received at step s is exactly the chunk sent at step s+1 — each
/// sub-chunk is forwarded the moment it is folded in, so step s+1's send
/// overlaps step s's remaining receives and reduction. `chunks == 1`
/// reproduces the blocking schedule message-for-message (same tags, same
/// sizes, same per-element reduction order), which keeps every pipelined
/// variant bitwise sum-equivalent to the baseline.
#[allow(clippy::too_many_arguments)]
fn ring_steps<C: CommOps>(
    comm: &mut C,
    right: usize,
    left: usize,
    idx: usize,
    l: usize,
    data: &mut [f32],
    tag_base: u64,
    gather: bool,
    chunks: usize,
) {
    if l <= 1 {
        return;
    }
    let n = data.len();
    let steps = l - 1;
    let k = clamp_pipeline_chunks("ring", chunks, steps);
    let sub_range = |ci: usize, sub: usize| {
        let (cs, ce) = chunk_bounds(n, l, ci);
        sub_bounds(cs, ce, k, sub)
    };
    let send_chunk = |step: usize| {
        if gather {
            (idx + 1 + l - step) % l
        } else {
            (idx + l - step) % l
        }
    };
    let recv_chunk = |step: usize| {
        if gather {
            (idx + l - step) % l
        } else {
            (idx + l - step - 1) % l
        }
    };
    // Post every step's sub-chunk receives up front — tags are unique per
    // (step, sub), so nothing can mismatch — then kick off step 0.
    let mut reqs: Vec<C::Req> = Vec::with_capacity(steps * k);
    let mut meta: Vec<(usize, usize)> = Vec::with_capacity(steps * k);
    for step in 0..steps {
        for sub in 0..k {
            reqs.push(comm.irecv(left, tag_base + (step * k + sub) as u64));
            meta.push((step, sub));
        }
    }
    for sub in 0..k {
        let (s, e) = sub_range(send_chunk(0), sub);
        comm.send(right, tag_base + sub as u64, data[s..e].to_vec());
    }
    // Drain: fold each arriving sub-chunk in and forward it immediately.
    while !reqs.is_empty() {
        let (i, incoming) = comm.wait_any(&mut reqs);
        let (step, sub) = meta.remove(i);
        let (s, e) = sub_range(recv_chunk(step), sub);
        if gather {
            data[s..e].copy_from_slice(&incoming);
        } else {
            add_assign(&mut data[s..e], &incoming);
        }
        if step + 1 < steps {
            comm.send(right, tag_base + ((step + 1) * k + sub) as u64, data[s..e].to_vec());
        }
    }
}

/// Bucket ring reduce-scatter (§6.2): after the call, rank `r` holds the
/// fully reduced chunk `(r + 1) % p` of `data`; other chunks are garbage
/// (partial sums). Returns the owned chunk index.
pub fn ring_reduce_scatter<C: CommOps>(comm: &mut C, data: &mut [f32]) -> usize {
    let p = comm.size();
    let r = comm.rank();
    ring_steps(comm, (r + 1) % p, (r + p - 1) % p, r, p, data, RING_RS_TAG, false, 1);
    (r + 1) % p
}

/// Bucket ring allgather (§6.3.1): rank `r` enters owning chunk
/// `(r + 1) % p` (the reduce-scatter output) and exits with every chunk.
pub fn ring_allgather<C: CommOps>(comm: &mut C, data: &mut [f32]) {
    let p = comm.size();
    let r = comm.rank();
    ring_steps(comm, (r + 1) % p, (r + p - 1) % p, r, p, data, RING_AG_TAG, true, 1);
}

/// Bandwidth-optimal ring allreduce = reduce-scatter + allgather (§6.2).
/// Cost: (p-1)α·2 + 2·(p-1)/p·nβ + (p-1)/p·nγ — the §6.2 lower bound.
/// This (`chunks == 1`) is the correctness baseline every pipelined
/// schedule is tested against.
pub fn ring_allreduce<C: CommOps>(comm: &mut C, data: &mut [f32]) {
    ring_allreduce_pipelined(comm, data, 1);
}

/// [`ring_allreduce`] with k-way chunk pipelining: each step's chunk moves
/// as `chunks` sub-chunks so step s+1's send overlaps step s's reduce.
pub fn ring_allreduce_pipelined<C: CommOps>(comm: &mut C, data: &mut [f32], chunks: usize) {
    let p = comm.size();
    let r = comm.rank();
    ring_steps(comm, (r + 1) % p, (r + p - 1) % p, r, p, data, RING_RS_TAG, false, chunks);
    ring_steps(comm, (r + 1) % p, (r + p - 1) % p, r, p, data, RING_AG_TAG, true, chunks);
}

/// Multi-ring allreduce (§6.3.2, Fig. 9): the buffer is split equally among
/// `rings` logical rings, each running the bucket algorithm on its slice.
///
/// In the paper the rings exist to *overlap* the NVLink reduction of ring i
/// with the network transfer of ring i+1; data-wise the result is identical
/// to a single ring, which is exactly what this implementation (and its
/// tests) asserts. The timing benefit is modelled in [`sim`].
pub fn multi_ring_allreduce<C: CommOps>(comm: &mut C, data: &mut [f32], rings: usize) {
    multi_ring_allreduce_pipelined(comm, data, rings, 1);
}

/// [`multi_ring_allreduce`] with k-way chunk pipelining per ring.
pub fn multi_ring_allreduce_pipelined<C: CommOps>(
    comm: &mut C,
    data: &mut [f32],
    rings: usize,
    chunks: usize,
) {
    let rings = rings.max(1).min(data.len().max(1));
    let len = data.len();
    for ring in 0..rings {
        let (s, e) = chunk_bounds(len, rings, ring);
        ring_allreduce_pipelined(comm, &mut data[s..e], chunks);
    }
}

// ---------------------------------------------------------------------------
// Pluggable allreduce algorithms
// ---------------------------------------------------------------------------

/// Bucket ring allreduce over an explicit subset of ranks (used as the
/// leader phase of [`hierarchical_allreduce`]). Every rank in `ranks` must
/// call this with the same list; ranks outside the subset must not call it.
pub fn ring_allreduce_subset<C: CommOps>(comm: &mut C, ranks: &[usize], data: &mut [f32]) {
    ring_allreduce_subset_pipelined(comm, ranks, data, 1);
}

/// [`ring_allreduce_subset`] with k-way chunk pipelining.
pub fn ring_allreduce_subset_pipelined<C: CommOps>(
    comm: &mut C,
    ranks: &[usize],
    data: &mut [f32],
    chunks: usize,
) {
    let l = ranks.len();
    if l <= 1 {
        return;
    }
    let idx = ranks
        .iter()
        .position(|&r| r == comm.rank())
        .expect("rank not in subset");
    let right = ranks[(idx + 1) % l];
    let left = ranks[(idx + l - 1) % l];
    ring_steps(comm, right, left, idx, l, data, SUBSET_RS_TAG, false, chunks);
    ring_steps(comm, right, left, idx, l, data, SUBSET_AG_TAG, true, chunks);
}

/// Recursive vector halving-doubling allreduce (Thakur/Rabenseifner): a
/// vector-halving reduce-scatter followed by a vector-doubling allgather —
/// 2·⌈lg p⌉ latency terms against the ring's 2(p-1), which makes it the
/// small-tensor algorithm of choice (see [`sim::select_best`]).
///
/// Non-power-of-two rank counts fold the `p - 2^⌊lg p⌋` extra ranks into
/// their partners up front and replay the result to them at the end
/// (the MPICH scheme).
pub fn halving_doubling_allreduce<C: CommOps>(comm: &mut C, data: &mut [f32]) {
    halving_doubling_allreduce_pipelined(comm, data, 1);
}

/// [`halving_doubling_allreduce`] with k-way chunk pipelining: each step's
/// window moves as `chunks` sub-chunks folded in via `wait_any`, so the
/// pair's reduction overlaps the remaining sub-transfers.
pub fn halving_doubling_allreduce_pipelined<C: CommOps>(
    comm: &mut C,
    data: &mut [f32],
    chunks: usize,
) {
    let p = comm.size();
    let r = comm.rank();
    if p == 1 {
        return;
    }
    let n = data.len();
    let q = pow2_floor(p);
    // RS+AG tags (up to 2·lg q steps × k subs) must stay inside one tag
    // family; identical on every rank.
    let lgq = (q.trailing_zeros() as usize).max(1);
    let k = clamp_pipeline_chunks("halving_doubling", chunks, 2 * lgq);
    let extras = p - q;
    if r >= q {
        // Extra rank: contribute the vector, receive the final result.
        comm.send(r - q, HD_FOLD_TAG, data.to_vec());
        let result = comm.recv(r - q, HD_FOLD_TAG + 1);
        data.copy_from_slice(&result);
        return;
    }
    if r < extras {
        let incoming = comm.recv(r + q, HD_FOLD_TAG);
        add_assign(data, &incoming);
    }
    // Vector-halving reduce-scatter among the power-of-two survivors: at
    // each step the pair splits the live window, keeps one half and sends
    // the other; both sides compute the same split from the shared window.
    let (mut lo, mut hi) = (0usize, n);
    let mut windows: Vec<(usize, usize)> = Vec::new();
    let mut mask = q >> 1;
    let mut step = 0usize;
    while mask > 0 {
        let partner = r ^ mask;
        let mid = lo + (hi - lo) / 2;
        let (keep, send) = if r & mask == 0 {
            ((lo, mid), (mid, hi))
        } else {
            ((mid, hi), (lo, mid))
        };
        // Exchange the halves sub-chunk by sub-chunk; reduce on arrival.
        let mut reqs: Vec<C::Req> = Vec::with_capacity(k);
        let mut meta: Vec<usize> = Vec::with_capacity(k);
        for sub in 0..k {
            let tag = HD_RS_TAG + (step * k + sub) as u64;
            let (ss, se) = sub_bounds(send.0, send.1, k, sub);
            comm.send(partner, tag, data[ss..se].to_vec());
            reqs.push(comm.irecv(partner, tag));
            meta.push(sub);
        }
        while !reqs.is_empty() {
            let (i, incoming) = comm.wait_any(&mut reqs);
            let sub = meta.remove(i);
            let (ks, ke) = sub_bounds(keep.0, keep.1, k, sub);
            add_assign(&mut data[ks..ke], &incoming);
        }
        windows.push((lo, hi));
        lo = keep.0;
        hi = keep.1;
        mask >>= 1;
        step += 1;
    }
    // Vector-doubling allgather: replay the window splits in reverse, each
    // pair exchanging its owned window to reassemble the parent window.
    let mut mask = 1usize;
    while mask < q {
        let partner = r ^ mask;
        let (plo, phi) = windows.pop().expect("window stack underflow");
        // The partner owns exactly the other half of the parent window.
        let (dlo, dhi) = if lo == plo { (hi, phi) } else { (plo, lo) };
        let mut reqs: Vec<C::Req> = Vec::with_capacity(k);
        let mut meta: Vec<usize> = Vec::with_capacity(k);
        for sub in 0..k {
            let tag = HD_AG_TAG + (step * k + sub) as u64;
            let (ss, se) = sub_bounds(lo, hi, k, sub);
            comm.send(partner, tag, data[ss..se].to_vec());
            reqs.push(comm.irecv(partner, tag));
            meta.push(sub);
        }
        while !reqs.is_empty() {
            let (i, incoming) = comm.wait_any(&mut reqs);
            let sub = meta.remove(i);
            let (ds, de) = sub_bounds(dlo, dhi, k, sub);
            data[ds..de].copy_from_slice(&incoming);
        }
        lo = plo;
        hi = phi;
        mask <<= 1;
        step += 1;
    }
    if r < extras {
        comm.send(r + q, HD_FOLD_TAG + 1, data.to_vec());
    }
}

/// Two-level hierarchical allreduce: ranks are grouped into blocks of
/// `group` consecutive ranks (the intra-client analog of §6.3's node
/// grouping); each group reduces onto its leader, the leaders run a bucket
/// ring among themselves, and the result is broadcast back into the groups.
pub fn hierarchical_allreduce<C: CommOps>(comm: &mut C, data: &mut [f32], group: usize) {
    hierarchical_allreduce_pipelined(comm, data, group, 1);
}

/// [`hierarchical_allreduce`] with k-way chunk pipelining: members stream
/// their buffer to the leader in sub-chunks (so the leader's reduction of
/// member m overlaps member m+1's transfer), the leader phase runs the
/// pipelined subset ring, and the broadcast back streams the same way.
/// Members are folded in strictly in rank order, keeping the per-element
/// float reduction order identical to the blocking schedule.
pub fn hierarchical_allreduce_pipelined<C: CommOps>(
    comm: &mut C,
    data: &mut [f32],
    group: usize,
    chunks: usize,
) {
    gather_ring_bcast(comm, data, group, chunks, "hierarchical", HIER_GATHER_TAG, HIER_BCAST_TAG);
}

/// Two-tier device allreduce (the MXNet `local` → `dist` kvstore topology,
/// SNIPPETS.md `multi_node.md`): the communicator's ranks are *device
/// ranks*, `devices` per node. Each node's devices reduce onto their node
/// leader over the intra-node fabric (NVLink/shared-host-memory class in
/// the cost model), the node leaders run the bucket ring across the
/// network — every inter-node message now carries the payload once per
/// *node* instead of once per device, the 1/k wire-byte win of
/// Shi et al. (arXiv:1711.05979) — and leaders broadcast the result back
/// down the fast fabric. Structurally this is [`hierarchical_allreduce`]
/// with the group reinterpreted as a device clique, but it is a distinct
/// [`AlgoKind`] because the two tiers price on different fabrics
/// ([`sim`]: `alpha_dev`/`beta_dev` intra, uncontended `beta_net` for the
/// leader ring) and trace as their own schedule family in `commcheck`
/// ([`DEV_GATHER_TAG`]/[`DEV_BCAST_TAG`]).
pub fn two_tier_allreduce<C: CommOps>(comm: &mut C, data: &mut [f32], devices: usize) {
    two_tier_allreduce_pipelined(comm, data, devices, 1);
}

/// [`two_tier_allreduce`] with k-way chunk pipelining (same streaming
/// scheme as [`hierarchical_allreduce_pipelined`]; `devices == 1`
/// degenerates to every rank being its own leader, i.e. the plain subset
/// ring over the whole communicator — data-wise the flat ring).
pub fn two_tier_allreduce_pipelined<C: CommOps>(
    comm: &mut C,
    data: &mut [f32],
    devices: usize,
    chunks: usize,
) {
    gather_ring_bcast(comm, data, devices, chunks, "two_tier", DEV_GATHER_TAG, DEV_BCAST_TAG);
}

/// The shared gather → leader-ring → broadcast state machine behind
/// [`hierarchical_allreduce_pipelined`] (host groups, HIER tags) and
/// [`two_tier_allreduce_pipelined`] (device cliques, DEV tags): blocks of
/// `group` consecutive ranks reduce onto their leader in sub-chunk
/// streams, leaders run the pipelined subset ring, leaders broadcast
/// back. One implementation so the correctness-critical step/chunk/fold
/// logic exists exactly once; the tag bases keep the two schedules in
/// separate `commcheck` families.
fn gather_ring_bcast<C: CommOps>(
    comm: &mut C,
    data: &mut [f32],
    group: usize,
    chunks: usize,
    schedule: &'static str,
    gather_tag: u64,
    bcast_tag: u64,
) {
    let p = comm.size();
    let r = comm.rank();
    if p == 1 {
        return;
    }
    // The benign data-length clamp (no point in empty sub-chunks) happens
    // first; only a tag-window clamp below that is worth reporting.
    let k = clamp_pipeline_chunks(schedule, chunks.max(1).min(data.len().max(1)), 1);
    let n = data.len();
    let g = group.clamp(1, p);
    let leader = r - r % g;
    let last = (leader + g).min(p);
    if r != leader {
        for sub in 0..k {
            let (s, e) = sub_bounds(0, n, k, sub);
            comm.send(leader, gather_tag + sub as u64, data[s..e].to_vec());
        }
        let mut reqs: Vec<C::Req> =
            (0..k).map(|sub| comm.irecv(leader, bcast_tag + sub as u64)).collect();
        let mut meta: Vec<usize> = (0..k).collect();
        while !reqs.is_empty() {
            let (i, incoming) = comm.wait_any(&mut reqs);
            let sub = meta.remove(i);
            let (s, e) = sub_bounds(0, n, k, sub);
            data[s..e].copy_from_slice(&incoming);
        }
        return;
    }
    for m in leader + 1..last {
        let mut reqs: Vec<C::Req> =
            (0..k).map(|sub| comm.irecv(m, gather_tag + sub as u64)).collect();
        let mut meta: Vec<usize> = (0..k).collect();
        while !reqs.is_empty() {
            let (i, incoming) = comm.wait_any(&mut reqs);
            let sub = meta.remove(i);
            let (s, e) = sub_bounds(0, n, k, sub);
            add_assign(&mut data[s..e], &incoming);
        }
    }
    let leaders: Vec<usize> = (0..p).step_by(g).collect();
    ring_allreduce_subset_pipelined(comm, &leaders, data, chunks);
    for m in leader + 1..last {
        for sub in 0..k {
            let (s, e) = sub_bounds(0, n, k, sub);
            comm.send(m, bcast_tag + sub as u64, data[s..e].to_vec());
        }
    }
}

/// Which allreduce schedule a job uses (the `collective` config knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoKind {
    /// Bucket multi-ring (§6.2/§6.3.2) — bandwidth-optimal.
    Ring,
    /// Recursive vector halving-doubling — latency-optimal small tensors.
    HalvingDoubling,
    /// Two-level: intra-group reduce → leader ring → intra-group bcast.
    Hierarchical,
    /// Two-tier device schedule: intra-node device reduce on the fast
    /// fabric → node-leader ring over the NIC (payload crosses the
    /// network once per node, not once per device) → device broadcast.
    /// Device count comes from [`CostParams::devices`].
    TwoTier,
    /// Pick per message with the α-β-γ model ([`sim::select_best`]).
    Auto,
}

impl AlgoKind {
    /// The real-data schedules (everything but `Auto`). `TwoTier` is
    /// deliberately *last*: [`sim::select_best`] keeps the first minimum
    /// under `min_by(total_cmp)`, so at `devices == 1` — where the
    /// two-tier price is bitwise the ring price — the tie breaks to the
    /// flat schedule deterministically.
    pub const DATA_PATH: [AlgoKind; 4] = [
        AlgoKind::Ring,
        AlgoKind::HalvingDoubling,
        AlgoKind::Hierarchical,
        AlgoKind::TwoTier,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ring" => AlgoKind::Ring,
            "hd" | "halving_doubling" | "halving-doubling" => AlgoKind::HalvingDoubling,
            "hierarchical" | "two_level" | "two-level" => AlgoKind::Hierarchical,
            "two_tier" | "two-tier" | "twotier" => AlgoKind::TwoTier,
            "auto" => AlgoKind::Auto,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Ring => "ring",
            AlgoKind::HalvingDoubling => "halving_doubling",
            AlgoKind::Hierarchical => "hierarchical",
            AlgoKind::TwoTier => "two_tier",
            AlgoKind::Auto => "auto",
        }
    }
}

/// Object-safe strategy interface over the three schedules, for callers
/// that want to hold a boxed algorithm rather than dispatch on
/// [`AlgoKind`] (the KVStore uses the enum; benches use this).
pub trait CollectiveAlgo: Send + Sync {
    fn name(&self) -> &'static str;
    fn allreduce(&self, comm: &mut Comm, data: &mut [f32]);
}

/// The §6.2 bucket multi-ring (`chunks`-way pipelined per ring).
pub struct BucketRing {
    pub rings: usize,
    pub chunks: usize,
}

impl CollectiveAlgo for BucketRing {
    fn name(&self) -> &'static str {
        "ring"
    }
    fn allreduce(&self, comm: &mut Comm, data: &mut [f32]) {
        multi_ring_allreduce_pipelined(comm, data, self.rings, self.chunks);
    }
}

/// Recursive vector halving-doubling (`chunks`-way pipelined per step).
pub struct HalvingDoubling {
    pub chunks: usize,
}

impl CollectiveAlgo for HalvingDoubling {
    fn name(&self) -> &'static str {
        "halving_doubling"
    }
    fn allreduce(&self, comm: &mut Comm, data: &mut [f32]) {
        halving_doubling_allreduce_pipelined(comm, data, self.chunks);
    }
}

/// Two-level hierarchical allreduce with a fixed group size.
pub struct Hierarchical {
    pub group: usize,
    pub chunks: usize,
}

impl CollectiveAlgo for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }
    fn allreduce(&self, comm: &mut Comm, data: &mut [f32]) {
        hierarchical_allreduce_pipelined(comm, data, self.group, self.chunks);
    }
}

/// Two-tier device allreduce with a fixed per-node device count.
pub struct TwoTier {
    pub devices: usize,
    pub chunks: usize,
}

impl CollectiveAlgo for TwoTier {
    fn name(&self) -> &'static str {
        "two_tier"
    }
    fn allreduce(&self, comm: &mut Comm, data: &mut [f32]) {
        two_tier_allreduce_pipelined(comm, data, self.devices, self.chunks);
    }
}

/// Resolve `Auto` for a message of `bytes` across `p` ranks. Returns the
/// concrete schedule plus the hierarchical group size to run it with: an
/// autotuned choice uses `params.gpus_per_worker` — the grouping the cost
/// model priced — while an explicit choice keeps the caller's `group`.
fn resolve_kind(
    kind: AlgoKind,
    bytes: usize,
    p: usize,
    group: usize,
    params: &CostParams,
) -> (AlgoKind, usize) {
    match kind {
        AlgoKind::Auto => (
            sim::select_best(bytes, p, params).0,
            params.gpus_per_worker.max(1),
        ),
        k => (k, group),
    }
}

/// Instantiate a boxed schedule; `Auto` resolves against `bytes_hint`.
/// The chunk-pipeline depth comes from `params.pipeline_chunks`.
pub fn build_algo(
    kind: AlgoKind,
    rings: usize,
    group: usize,
    bytes_hint: usize,
    p: usize,
    params: &CostParams,
) -> Box<dyn CollectiveAlgo> {
    let (kind, group) = resolve_kind(kind, bytes_hint, p, group, params);
    let chunks = params.pipeline_chunks.max(1);
    match kind {
        AlgoKind::Ring => Box::new(BucketRing { rings, chunks }),
        AlgoKind::HalvingDoubling => Box::new(HalvingDoubling { chunks }),
        AlgoKind::Hierarchical => Box::new(Hierarchical { group, chunks }),
        AlgoKind::TwoTier => Box::new(TwoTier { devices: params.devices.max(1), chunks }),
        AlgoKind::Auto => unreachable!("select_best never returns Auto"),
    }
}

/// Run one allreduce with the given schedule. `Auto` consults the α-β-γ
/// autotuner per message: every rank sees the same (bytes, p, params), so
/// the choice is identical across the communicator. All schedules run
/// `params.pipeline_chunks`-way chunk-pipelined (1 = blocking).
pub fn allreduce_with<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    data: &mut [f32],
    rings: usize,
    group: usize,
    params: &CostParams,
) {
    let (kind, group) = resolve_kind(kind, data.len() * 4, comm.size(), group, params);
    let chunks = params.pipeline_chunks.max(1);
    match kind {
        AlgoKind::Ring => multi_ring_allreduce_pipelined(comm, data, rings, chunks),
        AlgoKind::HalvingDoubling => halving_doubling_allreduce_pipelined(comm, data, chunks),
        AlgoKind::Hierarchical => hierarchical_allreduce_pipelined(comm, data, group, chunks),
        AlgoKind::TwoTier => {
            two_tier_allreduce_pipelined(comm, data, params.devices.max(1), chunks)
        }
        AlgoKind::Auto => unreachable!("select_best never returns Auto"),
    }
}

/// Compressed allreduce (the gradient-compression plane): error-feedback
/// compress the local buffer, allgather every rank's *compressed* payload
/// (that is what moves on the wire — fewer f32 words through mpisim), and
/// decompress-reduce all `p` payloads locally in rank order, so every rank
/// computes the bitwise-identical sum of the decoded contributions.
///
/// Identity codecs delegate to [`allreduce_with`] — the pre-compression
/// schedule, bitwise (regression-tested) — so `compression = "identity"`
/// costs nothing and changes nothing. Lossy codecs use the allgather
/// exchange because quantized/sparse codes cannot be summed mid-schedule
/// without recompounding the quantization error at every hop; the EF
/// residual (`ef_key`-scoped in `ef`) carries what the codec dropped into
/// the next call.
#[allow(clippy::too_many_arguments)]
pub fn compressed_allreduce<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    data: &mut [f32],
    codec: &dyn Compressor,
    ef_key: u64,
    ef: &mut EfState,
    rings: usize,
    group: usize,
    params: &CostParams,
) {
    if codec.is_identity() {
        allreduce_with(kind, comm, data, rings, group, params);
        return;
    }
    let p = comm.size();
    if p <= 1 {
        // A 1-rank "allreduce" moves zero wire bytes, so there is nothing
        // to compress: leave the buffer untouched (exactly what the dense
        // schedules do at p == 1, and the sim plane's wireless-local-step
        // rule). Any PS hop that follows compresses separately.
        return;
    }
    let r = comm.rank();
    // In-place EF encode: no defensive copy of `data` — the fused path
    // hands an arena slice straight to the codec. `data` briefly holds
    // input + residual, then the decompress-reduce below overwrites it.
    let wire = ef_compress_in_place(codec, ef_key, data, ef).to_wire();
    // Post every receive first, then fan the payload out; (source, tag)
    // matching keeps back-to-back compressed calls on one comm ordered via
    // the per-pair FIFO.
    let mut reqs: Vec<C::Req> = Vec::with_capacity(p.saturating_sub(1));
    let mut srcs: Vec<usize> = Vec::with_capacity(p.saturating_sub(1));
    for s in 0..p {
        if s != r {
            reqs.push(comm.irecv(s, COMPRESS_TAG));
            srcs.push(s);
        }
    }
    for s in 0..p {
        if s != r {
            comm.send(s, COMPRESS_TAG, wire.clone());
        }
    }
    let mut payloads: Vec<Option<Vec<f32>>> = (0..p).map(|_| None).collect();
    payloads[r] = Some(wire);
    while !reqs.is_empty() {
        let (i, msg) = comm.wait_any(&mut reqs);
        payloads[srcs.remove(i)] = Some(msg);
    }
    // Decompress-reduce in rank order: deterministic and identical on
    // every rank (same payloads, same fold order).
    for (s, payload) in payloads.into_iter().enumerate() {
        let dec = Compressed::from_wire(&payload.expect("payload from every rank"))
            .expect("malformed compressed allreduce payload")
            .decompress();
        debug_assert_eq!(dec.len(), data.len());
        if s == 0 {
            data.copy_from_slice(&dec);
        } else {
            add_assign(data, &dec);
        }
    }
}

/// Persistent gather buffer for the fused bucket paths.
///
/// Ownership rules: one arena per fused call site (`KvWorker` owns one
/// behind its mutex), borrowed mutably for the duration of one fused
/// call; the buckets of a call reuse it sequentially, and the backing
/// buffer only grows when a bucket exceeds every bucket seen before —
/// one allocation per bucket-size high-water mark, zero per push once
/// warm. [`FusionArena::grows`] is the allocation-counting hook the CI
/// bench-smoke gate asserts on (it tracks arena growth, not wire-side
/// message buffers).
#[derive(Debug, Default)]
pub struct FusionArena {
    buf: Vec<f32>,
    grows: usize,
}

impl FusionArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutable view of the first `n` arena elements, growing the backing
    /// buffer only when `n` exceeds every previous request.
    pub fn slot(&mut self, n: usize) -> &mut [f32] {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
            self.grows += 1;
        }
        &mut self.buf[..n]
    }

    /// How many times the backing buffer has grown since construction.
    pub fn grows(&self) -> usize {
        self.grows
    }

    /// Current capacity in elements.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
}

/// [`fused_allreduce`] with a codec: the compressed bucket path. Buckets
/// form exactly like the dense path ([`fusion_buckets`]); each bucket is
/// compressed/exchanged/decompress-reduced as one message, with its EF
/// residual keyed by `ef_keys[bucket start]` so a bucket's dropped mass
/// returns to the *same* bucket next iteration. Identity codecs delegate
/// to the dense [`fused_allreduce`], bitwise.
///
/// Allocates a fresh single-call arena; steady-state callers should hold
/// a [`FusionArena`] and use [`fused_allreduce_compressed_with_arena`].
#[allow(clippy::too_many_arguments)]
pub fn fused_allreduce_compressed<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    bufs: &mut [Vec<f32>],
    ef_keys: &[u64],
    fusion_bytes: usize,
    codec: &dyn Compressor,
    ef: &mut EfState,
    rings: usize,
    group: usize,
    params: &CostParams,
) {
    let arena = &mut FusionArena::new();
    fused_allreduce_compressed_with_arena(
        kind,
        comm,
        bufs,
        ef_keys,
        fusion_bytes,
        codec,
        ef,
        rings,
        group,
        params,
        arena,
    );
}

/// [`fused_allreduce_compressed`] against a caller-owned persistent
/// [`FusionArena`]: buckets gather into arena slices instead of per-push
/// vectors, and the codec (via the in-place EF encode inside
/// [`compressed_allreduce`]) reads straight out of the arena.
#[allow(clippy::too_many_arguments)]
pub fn fused_allreduce_compressed_with_arena<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    bufs: &mut [Vec<f32>],
    ef_keys: &[u64],
    fusion_bytes: usize,
    codec: &dyn Compressor,
    ef: &mut EfState,
    rings: usize,
    group: usize,
    params: &CostParams,
    arena: &mut FusionArena,
) {
    if codec.is_identity() {
        fused_allreduce_with_arena(kind, comm, bufs, fusion_bytes, rings, group, params, arena);
        return;
    }
    debug_assert_eq!(bufs.len(), ef_keys.len());
    let lens: Vec<usize> = bufs.iter().map(|b| b.len()).collect();
    for (i, j) in fusion_buckets(&lens, fusion_bytes) {
        let ef_key = ef_keys[i];
        if j == i + 1 {
            compressed_allreduce(
                kind, comm, &mut bufs[i], codec, ef_key, ef, rings, group, params,
            );
        } else {
            let fused = arena.slot(lens[i..j].iter().sum());
            let mut off = 0;
            for b in &bufs[i..j] {
                fused[off..off + b.len()].copy_from_slice(b);
                off += b.len();
            }
            compressed_allreduce(kind, comm, fused, codec, ef_key, ef, rings, group, params);
            let mut off = 0;
            for b in bufs[i..j].iter_mut() {
                b.copy_from_slice(&fused[off..off + b.len()]);
                off += b.len();
            }
        }
    }
}

/// Gradient fusion (§2.1's per-layer bucketing, Horovod-style): coalesce
/// consecutive buffers into buckets of at most `fusion_bytes` bytes (a
/// buffer larger than the cap forms its own bucket; `fusion_bytes == 0`
/// disables coalescing), allreduce each bucket as one message, and scatter
/// the results back in place. Small per-layer keys thus pay the
/// per-message α once per bucket instead of once per key.
///
/// Allocates a fresh single-call arena; steady-state callers should hold
/// a [`FusionArena`] and use [`fused_allreduce_with_arena`].
pub fn fused_allreduce<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    bufs: &mut [Vec<f32>],
    fusion_bytes: usize,
    rings: usize,
    group: usize,
    params: &CostParams,
) {
    let arena = &mut FusionArena::new();
    fused_allreduce_with_arena(kind, comm, bufs, fusion_bytes, rings, group, params, arena);
}

/// [`fused_allreduce`] against a caller-owned persistent [`FusionArena`]:
/// bucket gather/scatter goes through arena slices, so a warmed-up call
/// site does zero allocations per push.
#[allow(clippy::too_many_arguments)]
pub fn fused_allreduce_with_arena<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    bufs: &mut [Vec<f32>],
    fusion_bytes: usize,
    rings: usize,
    group: usize,
    params: &CostParams,
    arena: &mut FusionArena,
) {
    let lens: Vec<usize> = bufs.iter().map(|b| b.len()).collect();
    for (i, j) in fusion_buckets(&lens, fusion_bytes) {
        if j == i + 1 {
            allreduce_with(kind, comm, &mut bufs[i], rings, group, params);
        } else {
            let fused = arena.slot(lens[i..j].iter().sum());
            let mut off = 0;
            for b in &bufs[i..j] {
                fused[off..off + b.len()].copy_from_slice(b);
                off += b.len();
            }
            allreduce_with(kind, comm, fused, rings, group, params);
            let mut off = 0;
            for b in bufs[i..j].iter_mut() {
                b.copy_from_slice(&fused[off..off + b.len()]);
                off += b.len();
            }
        }
    }
}

/// Bucket layout under the fusion cap: `[start, end)` buffer-index ranges
/// of consecutive buffers coalesced per bucket. A buffer larger than the
/// cap forms its own bucket; `fusion_bytes == 0` disables coalescing.
/// Shared by [`fused_allreduce`] and the trainers' per-bucket issue so
/// data path and issue order agree on the bucketing exactly.
pub fn fusion_buckets(lens: &[usize], fusion_bytes: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < lens.len() {
        let mut bytes = lens[i] * 4;
        let mut j = i + 1;
        while j < lens.len() && fusion_bytes > 0 && bytes + lens[j] * 4 <= fusion_bytes {
            bytes += lens[j] * 4;
            j += 1;
        }
        out.push((i, j));
        i = j;
    }
    out
}

/// Strategy for the intra-node (device group -> host) reduction of a
/// tensor collective. On the paper's hardware this is the IBMGpu or NCCL
/// kernel; on the training path it can be the AOT-compiled `tensor_reduce`
/// Pallas kernel via a caller-supplied closure.
pub enum HostReduce<'a> {
    /// Plain Rust f32 summation (host memory, the omp_ring analog).
    Host,
    /// Caller-supplied reducer, e.g. the compiled HLO `tensor_reduce`.
    Custom(&'a dyn Fn(&NodeTensor) -> Vec<f32>),
}

/// Tensor allreduce (§6.3): intra-node reduce of the vector group into host
/// memory, host-memory multi-ring bucket allreduce across workers, then
/// intra-node broadcast back to every device vector.
///
/// This is the paper's headline collective: rings run over *host* memories
/// (GPU memory is unreachable from the NIC on Minsky), and grouping the
/// per-socket GPUs under one worker halves the ring hop count.
pub fn tensor_allreduce<C: CommOps>(
    comm: &mut C,
    tensor: &mut NodeTensor,
    rings: usize,
    reduce: HostReduce<'_>,
) {
    let mut host = match reduce {
        HostReduce::Host => tensor.reduce_to_host(),
        HostReduce::Custom(f) => f(tensor),
    };
    multi_ring_allreduce(comm, &mut host, rings);
    tensor.broadcast_from_host(&host);
}

/// [`tensor_allreduce`] with a pluggable inter-node schedule: intra-node
/// reduce into host memory, any [`AlgoKind`] across workers, intra-node
/// broadcast back.
pub fn tensor_allreduce_with<C: CommOps>(
    kind: AlgoKind,
    comm: &mut C,
    tensor: &mut NodeTensor,
    rings: usize,
    group: usize,
    params: &CostParams,
    reduce: HostReduce<'_>,
) {
    let mut host = match reduce {
        HostReduce::Host => tensor.reduce_to_host(),
        HostReduce::Custom(f) => f(tensor),
    };
    allreduce_with(kind, comm, &mut host, rings, group, params);
    tensor.broadcast_from_host(&host);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::World;
    use std::thread;

    fn run_world<F, R>(size: usize, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Clone + Send + 'static,
        R: Send + 'static,
    {
        let comms = World::create(size);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn payload(rank: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| (rank * 1000 + i) as f32 * 0.25)
            .collect()
    }

    fn expected_sum(p: usize, len: usize) -> Vec<f32> {
        let mut out = vec![0.0; len];
        for r in 0..p {
            add_assign(&mut out, &payload(r, len));
        }
        out
    }

    #[test]
    fn chunk_bounds_cover_exactly() {
        for len in [0, 1, 7, 64, 65] {
            for p in [1, 2, 3, 8] {
                let mut total = 0;
                let mut prev_end = 0;
                for i in 0..p {
                    let (s, e) = chunk_bounds(len, p, i);
                    assert_eq!(s, prev_end);
                    total += e - s;
                    prev_end = e;
                }
                assert_eq!(total, len);
            }
        }
    }

    #[test]
    fn ring_allreduce_matches_sum() {
        for p in [1, 2, 3, 4, 6] {
            for len in [1, 5, 64, 257] {
                let out = run_world(p, move |mut c| {
                    let mut d = payload(c.rank(), len);
                    ring_allreduce(&mut c, &mut d);
                    d
                });
                let want = expected_sum(p, len);
                for d in out {
                    assert_eq!(d, want, "p={p} len={len}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_owned_chunk_is_reduced() {
        let p = 4;
        let len = 64;
        let out = run_world(p, move |mut c| {
            let mut d = payload(c.rank(), len);
            let owned = ring_reduce_scatter(&mut c, &mut d);
            let (s, e) = chunk_bounds(len, p, owned);
            (owned, d[s..e].to_vec())
        });
        let want = expected_sum(p, len);
        for (r, (owned, chunk)) in out.iter().enumerate() {
            assert_eq!(*owned, (r + 1) % p);
            let (s, e) = chunk_bounds(len, p, *owned);
            assert_eq!(chunk[..], want[s..e], "rank {r}");
        }
    }

    #[test]
    fn multi_ring_equals_single_ring() {
        let p = 3;
        let len = 100;
        for rings in [1, 2, 4, 7] {
            let out = run_world(p, move |mut c| {
                let mut d = payload(c.rank(), len);
                multi_ring_allreduce(&mut c, &mut d, rings);
                d
            });
            let want = expected_sum(p, len);
            for d in out {
                assert_eq!(d, want, "rings={rings}");
            }
        }
    }

    #[test]
    fn tensor_allreduce_sums_all_devices_all_workers() {
        let p = 3;
        let g = 2;
        let len = 50;
        let out = run_world(p, move |mut c| {
            let vecs: Vec<Vec<f32>> = (0..g)
                .map(|d| payload(c.rank() * g + d, len))
                .collect();
            let mut t = NodeTensor::from_vecs(vecs);
            tensor_allreduce(&mut c, &mut t, 2, HostReduce::Host);
            t
        });
        let mut want = vec![0.0; len];
        for v in 0..p * g {
            add_assign(&mut want, &payload(v, len));
        }
        for t in out {
            for v in &t.vecs {
                assert_eq!(*v, want);
            }
        }
    }

    #[test]
    fn tensor_allreduce_custom_reducer_used() {
        let p = 2;
        let out = run_world(p, move |mut c| {
            let mut t = NodeTensor::from_vecs(vec![vec![1.0; 8], vec![2.0; 8]]);
            let reducer = |t: &NodeTensor| t.reduce_to_host();
            tensor_allreduce(&mut c, &mut t, 1, HostReduce::Custom(&reducer));
            t.vecs[0][0]
        });
        // 2 workers x (1+2) = 6.
        assert!(out.iter().all(|&x| x == 6.0));
    }

    #[test]
    fn ring_allreduce_len_smaller_than_ranks() {
        let p = 5;
        let out = run_world(p, move |mut c| {
            let mut d = vec![c.rank() as f32 + 1.0; 2]; // len < p
            ring_allreduce(&mut c, &mut d);
            d
        });
        for d in out {
            assert_eq!(d, vec![15.0, 15.0]);
        }
    }

    #[test]
    fn halving_doubling_matches_sum_all_sizes() {
        for p in [1, 2, 3, 4, 5, 6, 7, 8] {
            for len in [0, 1, 2, 5, 64, 257] {
                let out = run_world(p, move |mut c| {
                    let mut d = payload(c.rank(), len);
                    halving_doubling_allreduce(&mut c, &mut d);
                    d
                });
                let want = expected_sum(p, len);
                for d in out {
                    assert_eq!(d, want, "p={p} len={len}");
                }
            }
        }
    }

    #[test]
    fn hierarchical_matches_sum_all_groupings() {
        for p in [1, 2, 3, 4, 6, 8] {
            for group in [1, 2, 3, 4, 16] {
                let len = 77;
                let out = run_world(p, move |mut c| {
                    let mut d = payload(c.rank(), len);
                    hierarchical_allreduce(&mut c, &mut d, group);
                    d
                });
                let want = expected_sum(p, len);
                for d in out {
                    assert_eq!(d, want, "p={p} group={group}");
                }
            }
        }
    }

    #[test]
    fn subset_ring_reduces_only_members() {
        // Leaders {0, 2} of a 4-rank world allreduce among themselves;
        // ranks 1 and 3 stay untouched.
        let out = run_world(4, move |mut c| {
            let mut d = vec![(c.rank() + 1) as f32; 8];
            if c.rank() % 2 == 0 {
                ring_allreduce_subset(&mut c, &[0, 2], &mut d);
            }
            d
        });
        assert_eq!(out[0], vec![4.0; 8]); // 1 + 3
        assert_eq!(out[2], vec![4.0; 8]);
        assert_eq!(out[1], vec![2.0; 8]);
        assert_eq!(out[3], vec![4.0; 8]);
    }

    #[test]
    fn back_to_back_mixed_algorithms_no_cross_talk() {
        let p = 6;
        let out = run_world(p, move |mut c| {
            let mut a = payload(c.rank(), 33);
            halving_doubling_allreduce(&mut c, &mut a);
            let mut b = payload(c.rank() + 10, 17);
            hierarchical_allreduce(&mut c, &mut b, 2);
            let mut d = payload(c.rank(), 9);
            multi_ring_allreduce(&mut c, &mut d, 2);
            (a, b, d)
        });
        let wa = expected_sum(p, 33);
        let wb: Vec<f32> = {
            let mut out = vec![0.0; 17];
            for r in 0..p {
                add_assign(&mut out, &payload(r + 10, 17));
            }
            out
        };
        let wd = expected_sum(p, 9);
        for (a, b, d) in out {
            assert_eq!(a, wa);
            assert_eq!(b, wb);
            assert_eq!(d, wd);
        }
    }

    #[test]
    fn fused_allreduce_matches_per_key() {
        let p = 3;
        for fusion_bytes in [0usize, 64, 1 << 20] {
            let out = run_world(p, move |mut c| {
                let mut bufs: Vec<Vec<f32>> = (0..5)
                    .map(|k| payload(c.rank() * 10 + k, 3 + k * 7))
                    .collect();
                fused_allreduce(
                    AlgoKind::Ring,
                    &mut c,
                    &mut bufs,
                    fusion_bytes,
                    2,
                    2,
                    &CostParams::testbed1(),
                );
                bufs
            });
            for k in 0..5usize {
                let len = 3 + k * 7;
                let mut want = vec![0.0f32; len];
                for r in 0..p {
                    add_assign(&mut want, &payload(r * 10 + k, len));
                }
                for bufs in &out {
                    assert_eq!(bufs[k], want, "fusion={fusion_bytes} key={k}");
                }
            }
        }
    }

    #[test]
    fn allreduce_with_auto_resolves_and_sums() {
        let p = 4;
        let params = CostParams::minsky();
        for len in [4usize, 100_000] {
            let pr = params.clone();
            let out = run_world(p, move |mut c| {
                let mut d = payload(c.rank(), len);
                allreduce_with(AlgoKind::Auto, &mut c, &mut d, 2, 2, &pr);
                d
            });
            let want = expected_sum(p, len);
            for d in out {
                assert_eq!(d, want, "len={len}");
            }
        }
    }

    #[test]
    fn compressed_allreduce_identity_is_bitwise_plain_path() {
        use crate::compress::{EfState, Identity};
        let p = 4;
        let params = CostParams::testbed1();
        for kind in AlgoKind::DATA_PATH {
            let pr = params.clone();
            let out = run_world(p, move |mut c| {
                let mut a = payload(c.rank(), 113);
                let mut b = a.clone();
                allreduce_with(kind, &mut c, &mut a, 2, 2, &pr);
                let mut ef = EfState::new();
                compressed_allreduce(kind, &mut c, &mut b, &Identity, 0, &mut ef, 2, 2, &pr);
                (a, b)
            });
            for (a, b) in out {
                assert_eq!(a, b, "{}", kind.name());
            }
        }
    }

    #[test]
    fn compressed_allreduce_consistent_and_close_to_sum() {
        use crate::compress::{EfState, Int8, TopK};
        let p = 3;
        let len = 500;
        let params = CostParams::testbed1();
        for lossy in [true, false] {
            let pr = params.clone();
            let out = run_world(p, move |mut c| {
                let mut d = payload(c.rank(), len);
                let mut ef = EfState::new();
                if lossy {
                    compressed_allreduce(
                        AlgoKind::Ring, &mut c, &mut d,
                        &TopK { ratio: 0.5 }, 0, &mut ef, 2, 2, &pr,
                    );
                } else {
                    compressed_allreduce(
                        AlgoKind::Ring, &mut c, &mut d,
                        &Int8 { bucket: 64 }, 0, &mut ef, 2, 2, &pr,
                    );
                }
                d
            });
            // Every rank decoded the identical payload set.
            for d in &out[1..] {
                assert_eq!(*d, out[0]);
            }
            // Int8 stays within quantization tolerance of the true sum.
            if !lossy {
                let want = expected_sum(p, len);
                let maxabs = want.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                for (a, b) in out[0].iter().zip(&want) {
                    assert!((a - b).abs() <= p as f32 * maxabs / 100.0, "{a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn fused_compressed_buckets_conserve_mass_via_residuals() {
        use crate::compress::{EfState, TopK};
        // Repeated fused compressed allreduces: the EF books must balance
        // exactly — cumulative decoded results plus every rank's final
        // residual equal the cumulative true sums (up to f32 association).
        let p = 2;
        let iters = 6usize;
        let out = run_world(p, move |mut c| {
            let params = CostParams::testbed1();
            let mut ef = EfState::new();
            let codec = TopK { ratio: 0.5 };
            // One fused bucket: lens 4+5+6 = 15 elems = 60 bytes <= 64.
            let mut cumulative = vec![0.0f32; 15];
            for _iter in 0..iters {
                let mut bufs: Vec<Vec<f32>> = (0..3)
                    .map(|k| payload(c.rank() * 10 + k, 4 + k))
                    .collect();
                let ef_keys: Vec<u64> = (0..3).map(|k| 1000 + k as u64).collect();
                fused_allreduce_compressed(
                    AlgoKind::Ring, &mut c, &mut bufs, &ef_keys, 64,
                    &codec, &mut ef, 2, 2, &params,
                );
                let mut flat = Vec::new();
                for b in &bufs {
                    flat.extend_from_slice(b);
                }
                add_assign(&mut cumulative, &flat);
            }
            let residual = ef.residual(1000).expect("bucket residual").to_vec();
            (cumulative, residual)
        });
        // All ranks computed the identical round results.
        for (cum, _) in &out[1..] {
            assert_eq!(*cum, out[0].0);
        }
        // Books: Sum_t result_t + Sum_r residual_r == iters * true_sum.
        let mut want = vec![0.0f32; 15];
        for r in 0..p {
            let mut flat = Vec::new();
            for k in 0..3 {
                flat.extend_from_slice(&payload(r * 10 + k, 4 + k));
            }
            add_assign(&mut want, &flat);
        }
        let mut lhs = out[0].0.clone();
        for (_, resid) in &out {
            add_assign(&mut lhs, resid);
        }
        for (i, (&l, &w)) in lhs.iter().zip(&want).enumerate() {
            let total = iters as f32 * w;
            let tol = total.abs().max(1.0) * 1e-4;
            assert!((l - total).abs() <= tol, "elem {i}: {l} vs {total}");
        }
    }

    #[test]
    fn algo_kind_parse_round_trip() {
        for k in [
            AlgoKind::Ring,
            AlgoKind::HalvingDoubling,
            AlgoKind::Hierarchical,
            AlgoKind::TwoTier,
            AlgoKind::Auto,
        ] {
            assert_eq!(AlgoKind::parse(k.name()), Some(k));
        }
        assert_eq!(AlgoKind::parse("hd"), Some(AlgoKind::HalvingDoubling));
        assert_eq!(AlgoKind::parse("two_level"), Some(AlgoKind::Hierarchical));
        assert_eq!(AlgoKind::parse("two-tier"), Some(AlgoKind::TwoTier));
        assert_eq!(AlgoKind::parse("twotier"), Some(AlgoKind::TwoTier));
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn two_tier_matches_sum_all_device_counts() {
        for p in [1usize, 2, 3, 4, 6, 8] {
            for devices in [1usize, 2, 3, 4, 8] {
                for chunks in [1usize, 2] {
                    let len = 77;
                    let out = run_world(p, move |mut c| {
                        let mut d = payload(c.rank(), len);
                        two_tier_allreduce_pipelined(&mut c, &mut d, devices, chunks);
                        d
                    });
                    let want = expected_sum(p, len);
                    for d in out {
                        assert_eq!(d, want, "p={p} devices={devices} chunks={chunks}");
                    }
                }
            }
        }
    }

    #[test]
    fn two_tier_bitwise_equals_flat_on_exact_payloads() {
        // The test payloads are small multiples of 0.25, so every partial
        // sum is exact in f32 and the fold order cannot matter: the
        // two-tier result must be *bitwise* the flat ring result at every
        // device count (the ISSUE-8 order-independence property).
        for p in [2usize, 4, 6, 8] {
            for devices in [1usize, 2, 4, 8] {
                let len = 113;
                let out = run_world(p, move |mut c| {
                    let mut flat = payload(c.rank(), len);
                    let mut tiered = flat.clone();
                    ring_allreduce(&mut c, &mut flat);
                    two_tier_allreduce(&mut c, &mut tiered, devices);
                    (flat, tiered)
                });
                for (flat, tiered) in out {
                    assert_eq!(flat, tiered, "p={p} devices={devices}");
                }
            }
        }
    }

    #[test]
    fn two_tier_composes_with_compression() {
        use crate::compress::{EfState, TopK};
        // Per-device-rank EF residuals over the two-tier schedule: all
        // ranks must agree, and the identity delegate stays covered by
        // `compressed_allreduce_identity_is_bitwise_plain_path` (TwoTier
        // is in DATA_PATH).
        let p = 4;
        let len = 200;
        let params = {
            let mut pr = CostParams::testbed1();
            pr.devices = 2;
            pr
        };
        let out = run_world(p, move |mut c| {
            let mut d = payload(c.rank(), len);
            let mut ef = EfState::new();
            compressed_allreduce(
                AlgoKind::TwoTier, &mut c, &mut d,
                &TopK { ratio: 0.5 }, 7, &mut ef, 2, 2, &params,
            );
            d
        });
        for d in &out[1..] {
            assert_eq!(*d, out[0]);
        }
    }

    #[test]
    fn boxed_strategies_all_sum() {
        let p = 4;
        let params = CostParams::testbed1();
        for kind in AlgoKind::DATA_PATH {
            let pr = params.clone();
            let out = run_world(p, move |mut c| {
                let algo = build_algo(kind, 2, 2, 1024, p, &pr);
                let mut d = payload(c.rank(), 50);
                algo.allreduce(&mut c, &mut d);
                d
            });
            let want = expected_sum(p, 50);
            for d in out {
                assert_eq!(d, want, "{}", kind.name());
            }
        }
    }
}
