//! Synchronization shim: `std::sync` re-exports that the racecheck virtual
//! scheduler can intercept.
//!
//! Every type here wraps its `std` counterpart and is API-compatible with
//! it (`LockResult`, poisoning, `mpsc` error types). On a normal thread the
//! wrappers delegate straight to `std` — the only extra work is one
//! thread-local read per visible operation — so production behavior,
//! including bitwise results, is unchanged. On a thread registered with the
//! virtual scheduler ([`crate::analysis::sched`]), each *visible* operation
//! (lock, condvar wait/notify, channel send/recv, spawn/join) first asks
//! the scheduler for permission, which serializes threads and lets the
//! model checker enumerate interleavings deterministically.
//!
//! The soundness invariant: a checked thread never blocks inside a `std`
//! primitive. The scheduler grants a virtual lock before the `std` lock is
//! touched (so the `std` acquisition cannot contend), condvar waiters park
//! on scheduler gates instead of the real condvar, and channel receives are
//! only granted when the virtual queue length proves a message is already
//! buffered.

use crate::analysis::sched;
use std::fmt;
use std::sync::mpsc::{RecvError, SendError, TryRecvError};
use std::sync::{LockResult, OnceLock, PoisonError};

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// `std::sync::Mutex` wrapper with a racecheck hook and a lock-order class
/// name (used by the lock-order-inversion detector).
pub struct Mutex<T: ?Sized> {
    vid: OnceLock<u32>,
    class: &'static str,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Self::named(value, "mutex")
    }

    /// Like [`Mutex::new`] but names the lock-order class this mutex
    /// belongs to (e.g. `"engine.state"`). All mutexes of one class are one
    /// node in racecheck's lock-order graph.
    pub fn named(value: T, class: &'static str) -> Self {
        Self { vid: OnceLock::new(), class, inner: std::sync::Mutex::new(value) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        sched::on_lock(&self.vid, self.class);
        wrap_lock(self, self.inner.lock())
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T: ?Sized> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").field("class", &self.class).finish_non_exhaustive()
    }
}

fn wrap_lock<'a, T>(
    lock: &'a Mutex<T>,
    res: LockResult<std::sync::MutexGuard<'a, T>>,
) -> LockResult<MutexGuard<'a, T>> {
    match res {
        Ok(g) => Ok(MutexGuard { lock, inner: Some(g) }),
        Err(p) => Err(PoisonError::new(MutexGuard { lock, inner: Some(p.into_inner()) })),
    }
}

/// Guard for [`Mutex`]; releases the virtual lock (if any) on drop, after
/// the `std` guard.
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already split")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already split")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.inner.take() {
            drop(g); // std release first, then the virtual one
            sched::on_unlock(&self.lock.vid);
        }
    }
}

impl<'a, T> MutexGuard<'a, T> {
    /// Hand back the raw `std` guard without emitting a virtual unlock
    /// (used by the pass-through condvar path, which keeps holding).
    fn split(mut self) -> (&'a Mutex<T>, std::sync::MutexGuard<'a, T>) {
        let lock = self.lock;
        let inner = self.inner.take().expect("guard already split");
        std::mem::forget(self);
        (lock, inner)
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// `std::sync::Condvar` wrapper. In checked mode, waiters park on scheduler
/// gates and notifies are schedule points (with a which-waiter choice for
/// `notify_one`); the real condvar is still signaled so that threads
/// released into pass-through mode after an aborted execution block in
/// `std` instead of spinning.
pub struct Condvar {
    vid: OnceLock<u32>,
    class: &'static str,
    inner: std::sync::Condvar,
}

impl Condvar {
    pub fn new() -> Self {
        Self::named("condvar")
    }

    /// Named variant; the class labels diagnostics (e.g. `"engine.worker_cv"`).
    pub fn named(class: &'static str) -> Self {
        Self { vid: OnceLock::new(), class, inner: std::sync::Condvar::new() }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let m_vid = guard.lock.vid.get().copied();
        match m_vid {
            Some(m) if sched::virtual_wait_applicable() => {
                let lock = guard.lock;
                drop(guard); // std unlock + virtual release, atomically from the model's view
                sched::on_cv_wait(&self.vid, self.class, m);
                // Granted (or released into pass-through): the scheduler
                // already holds the virtual lock for us, so re-acquire raw.
                wrap_lock(lock, lock.inner.lock())
            }
            _ => {
                // Plain production path (also: unregistered mutex, aborted
                // session): a real condvar wait, keeping the virtual hold.
                let (lock, inner) = guard.split();
                wrap_lock(lock, self.inner.wait(inner))
            }
        }
    }

    pub fn notify_one(&self) {
        sched::on_notify(&self.vid, self.class, false);
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        sched::on_notify(&self.vid, self.class, true);
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").field("class", &self.class).finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// mpsc channel
// ---------------------------------------------------------------------------

struct ChanMeta {
    vid: OnceLock<u32>,
    class: &'static str,
}

/// `std::sync::mpsc::channel` with racecheck hooks. The virtual scheduler
/// tracks queue length and live-sender count, so a checked `recv` is only
/// granted when a message is provably buffered (or all senders are gone).
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    channel_named("chan")
}

/// Named variant; the class labels diagnostics (e.g. `"mpisim.mailbox"`).
pub fn channel_named<T>(class: &'static str) -> (Sender<T>, Receiver<T>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let meta = std::sync::Arc::new(ChanMeta { vid: OnceLock::new(), class });
    (Sender { inner: tx, meta: meta.clone() }, Receiver { inner: rx, meta })
}

pub struct Sender<T> {
    inner: std::sync::mpsc::Sender<T>,
    meta: std::sync::Arc<ChanMeta>,
}

impl<T> Sender<T> {
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        sched::on_send(&self.meta.vid, self.meta.class);
        let res = self.inner.send(value);
        if res.is_err() {
            // Receiver is gone; retract the optimistic queue accounting.
            sched::on_send_failed(&self.meta.vid);
        }
        res
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        sched::on_sender_clone(&self.meta.vid, self.meta.class);
        Self { inner: self.inner.clone(), meta: self.meta.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        sched::on_sender_drop(&self.meta.vid, self.meta.class);
    }
}

impl<T> fmt::Debug for Sender<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Sender").field("class", &self.meta.class).finish_non_exhaustive()
    }
}

pub struct Receiver<T> {
    inner: std::sync::mpsc::Receiver<T>,
    meta: std::sync::Arc<ChanMeta>,
}

impl<T> Receiver<T> {
    pub fn recv(&self) -> Result<T, RecvError> {
        match sched::on_recv(&self.meta.vid, self.meta.class) {
            sched::RecvGrant::Std => self.inner.recv(),
            sched::RecvGrant::Data => {
                Ok(self.inner.recv().expect("virtual channel accounting out of sync"))
            }
            sched::RecvGrant::Closed => Err(RecvError),
        }
    }

    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        match sched::on_try_recv(&self.meta.vid, self.meta.class) {
            sched::TryGrant::Std => self.inner.try_recv(),
            sched::TryGrant::Data => {
                Ok(self.inner.try_recv().expect("virtual channel accounting out of sync"))
            }
            sched::TryGrant::Empty => Err(TryRecvError::Empty),
            sched::TryGrant::Closed => Err(TryRecvError::Disconnected),
        }
    }
}

impl<T> fmt::Debug for Receiver<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Receiver").field("class", &self.meta.class).finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// `std::thread::JoinHandle` wrapper; a checked `join` is a schedule point
/// that only becomes enabled once the child has exited, so the underlying
/// `std` join never blocks a checked thread.
pub struct JoinHandle<T> {
    inner: std::thread::JoinHandle<T>,
    vtid: Option<usize>,
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        if let Some(tid) = self.vtid {
            sched::on_join(tid);
        }
        self.inner.join()
    }
}

impl<T> fmt::Debug for JoinHandle<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JoinHandle").field("vtid", &self.vtid).finish_non_exhaustive()
    }
}

/// `std::thread::Builder` lookalike. Threads spawned from a checked thread
/// are registered with the same scheduler session; everything else goes
/// straight to `std`.
pub struct Builder {
    name: Option<String>,
}

impl Builder {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { name: None }
    }

    pub fn name(mut self, name: String) -> Self {
        self.name = Some(name);
        self
    }

    pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let name = self.name.unwrap_or_else(|| "sync-worker".to_string());
        let b = std::thread::Builder::new().name(name.clone());
        match sched::spawn_ctl(name) {
            Some(ctl) => {
                let vtid = ctl.tid();
                let inner = b.spawn(move || sched::run_checked(ctl, f))?;
                Ok(JoinHandle { inner, vtid: Some(vtid) })
            }
            None => {
                let inner = b.spawn(f)?;
                Ok(JoinHandle { inner, vtid: None })
            }
        }
    }
}

/// `std::thread::spawn` lookalike (unnamed).
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    Builder::new().spawn(f).expect("failed to spawn thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    // All tests here run unchecked, i.e. exercise the production
    // pass-through path: behavior must be indistinguishable from std.

    #[test]
    fn mutex_and_condvar_pass_through() {
        let pair = Arc::new((Mutex::named(false, "test.flag"), Condvar::named("test.cv")));
        let p2 = pair.clone();
        let h = spawn(move || {
            let (m, cv) = &*p2;
            *m.lock().unwrap() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
        drop(g);
        h.join().unwrap();
    }

    #[test]
    fn channel_pass_through_matches_std_semantics() {
        let (tx, rx) = channel();
        tx.send(7u32).unwrap();
        assert_eq!(rx.try_recv().unwrap(), 7);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        let tx2 = tx.clone();
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_to_dropped_receiver_errors() {
        let (tx, rx) = channel();
        drop(rx);
        assert!(tx.send(1u8).is_err());
    }

    #[test]
    fn poisoning_propagates_like_std() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = m.clone();
        let h = spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        });
        assert!(h.join().is_err());
        assert!(m.lock().is_err(), "poisoned lock must surface the PoisonError");
        // And the data is still reachable through the error, like std.
        let g = m.lock();
        let v = match g {
            Err(p) => *p.into_inner(),
            Ok(g) => *g,
        };
        assert_eq!(v, 0);
    }

    #[test]
    fn named_builder_spawns() {
        let h = Builder::new()
            .name("named-test".into())
            .spawn(|| std::thread::current().name().map(|s| s.to_string()))
            .unwrap();
        assert_eq!(h.join().unwrap().as_deref(), Some("named-test"));
    }
}
