//! Small shared utilities: deterministic PRNG, stats helpers, and the
//! [`sync`] shim the threaded plane is built on.
//!
//! We use our own SplitMix64-style generator instead of the `rand` crate so
//! that synthetic data, worker jitter and experiment seeds are bit-stable
//! across platforms and crate upgrades.

pub mod sync;

/// SplitMix64 — tiny, fast, deterministic PRNG (Steele et al.).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    /// Derive an independent stream (e.g. per worker) from this seed.
    pub fn fork(&self, stream: u64) -> Self {
        Rng::new(self.state ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is negligible for the ranges we use (n << 2^64).
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with N(mu, sigma) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], mu: f32, sigma: f32) {
        for v in buf.iter_mut() {
            *v = mu + sigma * self.normal() as f32;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Pretty-format a byte count.
pub fn fmt_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{x:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_fork_streams_differ() {
        let base = Rng::new(7);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(4 << 20), "4.0 MiB");
    }
}
