//! racecheck — deterministic concurrency model checker for the threaded
//! plane (the concurrency sibling of commcheck).
//!
//! commcheck verifies *what* the communication schedules send, but it runs
//! on a single-threaded tracing fabric and is blind to interleavings. This
//! module drives the virtual scheduler in [`super::sched`] over the crate's
//! real concurrency protocols — the engine worker pool, mpisim slot
//! matching / split rendezvous / request cancellation, the kvstore
//! Pending/engine-var handoff, and the PS quorum barrier — exploring
//! bounded-world schedules (2–4 threads, the shapes commcheck already
//! sweeps) and reporting:
//!
//! - **deadlock** — all threads blocked with no wakeup avenue left;
//! - **lost wakeup** — a waiter parked while its predicate held;
//! - **lock-order inversion** — a cycle in the class-level lock-order graph
//!   accumulated across a scenario's executions;
//! - **non-determinism** — two schedules of the same scenario producing
//!   different digests (the determinism contract made checkable);
//! - **panic / step-limit / stall** — a thread unwound, livelocked, or
//!   escaped the scheduler.
//!
//! Exploration is preorder DFS with replay over the decision tape: the
//! first execution takes choice 0 everywhere, then untried sibling choices
//! are stacked shallowest-on-top and each prefix replayed, exhausting the
//! schedule tree or the per-world execution budget, followed by seeded
//! random walks to spot-check beyond the horizon. Every diagnostic carries
//! a *replayable
//! seed* (`rc1:<scenario>:w<world>:<tape>`): feeding it back through
//! [`replay`] (CLI: `mxnet-mpi racecheck --seed`) reproduces the identical
//! interleaving and diagnostic bit for bit.
//!
//! Like commcheck, the verifier is itself verified: [`run_mutant_suite`]
//! runs seeded concurrency bugs (a `notify_one` where `notify_all` is
//! required, a missing notify, a `while` collapsed to `if`, a swapped lock
//! order, an unordered last-writer-wins, a channel cycle) that racecheck
//! must catch with the expected diagnostic class or the CI gate fails.

use super::sched::{run_execution, Event, ExecConfig, ExecReport};
use crate::engine::Engine;
use crate::kvstore::{KvType, KvWorker};
use crate::mpisim::World;
use crate::ps::{ClusterScheduler, Role};
use crate::util::sync;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------------

/// Diagnostic class a finding (or a seeded mutant) belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RaceKind {
    Deadlock,
    LostWakeup,
    LockOrder,
    NonDeterminism,
    Panic,
    StepLimit,
    Stalled,
}

impl RaceKind {
    pub fn name(&self) -> &'static str {
        match self {
            RaceKind::Deadlock => "deadlock",
            RaceKind::LostWakeup => "lost-wakeup",
            RaceKind::LockOrder => "lock-order",
            RaceKind::NonDeterminism => "non-determinism",
            RaceKind::Panic => "panic",
            RaceKind::StepLimit => "step-limit",
            RaceKind::Stalled => "stalled",
        }
    }
}

/// One confirmed finding, with the seed that replays it.
#[derive(Debug, Clone)]
pub struct RaceDiagnostic {
    pub scenario: String,
    pub world: usize,
    pub kind: RaceKind,
    pub detail: String,
    /// Replayable schedule seed (`rc1:<scenario>:w<world>:<tape>`).
    pub seed: String,
}

impl fmt::Display for RaceDiagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] {} (world {}): {} [replay: --seed {}]",
            self.kind.name(),
            self.scenario,
            self.world,
            self.detail,
            self.seed
        )
    }
}

/// Aggregate result of a racecheck run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub scenarios: usize,
    pub worlds: usize,
    pub executions: usize,
    pub diagnostics: Vec<RaceDiagnostic>,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Exploration budget per (scenario, world) pair.
#[derive(Debug, Clone)]
pub struct Budget {
    /// Systematic executions (preorder DFS over the schedule tree).
    pub dfs: usize,
    /// Seeded random walks past the DFS horizon.
    pub random: usize,
    /// Per-execution schedule-point cap (livelock guard).
    pub step_cap: usize,
}

impl Default for Budget {
    fn default() -> Self {
        Self { dfs: 192, random: 32, step_cap: 20_000 }
    }
}

impl Budget {
    /// Small budget for unit tests (still catches every seeded mutant).
    pub fn quick() -> Self {
        Self { dfs: 48, random: 8, step_cap: 20_000 }
    }
}

// ---------------------------------------------------------------------------
// Replayable seeds
// ---------------------------------------------------------------------------

/// Encode a schedule seed: `rc1:<scenario>:w<world>:<c0,c1,...>` (`-` for
/// the empty tape).
pub fn format_seed(scenario: &str, world: usize, tape: &[u32]) -> String {
    let t = if tape.is_empty() {
        "-".to_string()
    } else {
        tape.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
    };
    format!("rc1:{scenario}:w{world}:{t}")
}

/// Decode a schedule seed back into (scenario, world, tape).
pub fn parse_seed(seed: &str) -> Result<(String, usize, Vec<u32>), String> {
    let mut parts = seed.splitn(4, ':');
    let magic = parts.next().unwrap_or_default();
    if magic != "rc1" {
        return Err(format!("bad seed {seed:?}: expected 'rc1:' prefix"));
    }
    let name = parts.next().ok_or_else(|| format!("bad seed {seed:?}: missing scenario"))?;
    let world = parts
        .next()
        .and_then(|w| w.strip_prefix('w'))
        .and_then(|w| w.parse::<usize>().ok())
        .ok_or_else(|| format!("bad seed {seed:?}: missing 'w<world>' field"))?;
    let tape_s = parts.next().ok_or_else(|| format!("bad seed {seed:?}: missing tape"))?;
    let tape = if tape_s == "-" {
        Vec::new()
    } else {
        tape_s
            .split(',')
            .map(|c| c.trim().parse::<u32>())
            .collect::<Result<Vec<u32>, _>>()
            .map_err(|e| format!("bad seed {seed:?}: tape entry: {e}"))?
    };
    Ok((name.to_string(), world, tape))
}

// ---------------------------------------------------------------------------
// Scenario table — the ported protocols under check
// ---------------------------------------------------------------------------

type Body = fn(usize) -> Vec<u64>;

struct Scenario {
    name: &'static str,
    /// World sizes to sweep (meaning is per-scenario: engine worker count,
    /// MPI ranks, PS workers).
    worlds: &'static [usize],
    body: Body,
}

fn scenarios() -> &'static [Scenario] {
    &[
        Scenario { name: "engine-pool", worlds: &[1, 2, 3], body: sc_engine_pool },
        Scenario { name: "engine-wait-var", worlds: &[1, 2], body: sc_engine_wait_var },
        Scenario { name: "mpisim-p2p", worlds: &[2, 3], body: sc_mpisim_p2p },
        Scenario { name: "mpisim-split", worlds: &[2, 3], body: sc_mpisim_split },
        Scenario { name: "mpisim-wait-any", worlds: &[2, 3], body: sc_mpisim_wait_any },
        Scenario { name: "kvstore-pending", worlds: &[1, 2], body: sc_kvstore_pending },
        Scenario { name: "ps-quorum", worlds: &[1, 2], body: sc_ps_quorum },
    ]
}

/// Names of all checkable scenarios (for `--scenario` validation).
pub fn scenario_names() -> Vec<&'static str> {
    scenarios().iter().map(|s| s.name).collect()
}

/// Engine worker pool: `world` workers racing over the (state, worker_cv,
/// idle_cv) triple. Non-commutative updates on per-var cells must come out
/// identical under every schedule (the engine serializes per-var FIFO).
fn sc_engine_pool(world: usize) -> Vec<u64> {
    let engine = Arc::new(Engine::new(world));
    let cells: Vec<Arc<AtomicU64>> = (0..2).map(|_| Arc::new(AtomicU64::new(1))).collect();
    let vars: Vec<_> = cells.iter().map(|_| engine.new_var()).collect();
    for step in 0..3u64 {
        for (i, cell) in cells.iter().enumerate() {
            let c = cell.clone();
            let k = 3 + step + i as u64;
            engine.push(
                move || {
                    // Exclusive by the engine's per-var serialization; the
                    // op body has no schedule point, so load/store is
                    // atomic from the model's view.
                    let v = c.load(Ordering::SeqCst);
                    c.store(v.wrapping_mul(k).wrapping_add(1), Ordering::SeqCst);
                },
                &[],
                &[vars[i]],
            );
        }
    }
    engine.wait_all();
    cells.iter().map(|c| c.load(Ordering::SeqCst)).collect()
}

/// `Engine::wait_var` handoff: an op chain `a -> b` observed mid-flight.
/// After `wait_var(b)` both ops must have landed, under every schedule.
fn sc_engine_wait_var(world: usize) -> Vec<u64> {
    let engine = Arc::new(Engine::new(world));
    let a = engine.new_var();
    let b = engine.new_var();
    let cell = Arc::new(AtomicU64::new(0));
    let (c1, c2) = (cell.clone(), cell.clone());
    engine.push(
        move || {
            c1.fetch_add(5, Ordering::SeqCst);
        },
        &[],
        &[a],
    );
    engine.push(
        move || {
            c2.fetch_add(11, Ordering::SeqCst);
        },
        &[a],
        &[b],
    );
    engine.wait_var(b);
    let after_b = cell.load(Ordering::SeqCst);
    engine.wait_all();
    vec![after_b, cell.load(Ordering::SeqCst)]
}

/// mpisim point-to-point ring: posted-receive slot matching under traffic,
/// plus the Request-drop cancellation path (an irecv nobody answers is
/// dropped while messages are in flight).
fn sc_mpisim_p2p(world: usize) -> Vec<u64> {
    let comms = World::create(world);
    let ranks: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(r, mut comm)| {
            sync::Builder::new()
                .name(format!("rank-{r}"))
                .spawn(move || {
                    let n = comm.size();
                    let next = (r + 1) % n;
                    let prev = (r + n - 1) % n;
                    let dropped = comm.irecv(prev, 7); // never matched
                    let req = comm.irecv(prev, 1);
                    comm.send(next, 1, vec![r as f32, 1.0]);
                    drop(dropped); // MPI_Cancel path, mid-traffic
                    let got = comm.wait(req);
                    got.iter().map(|&x| x.to_bits() as u64).sum::<u64>()
                })
                .expect("spawn rank thread")
        })
        .collect();
    ranks.into_iter().map(|h| h.join().expect("rank thread")).collect()
}

/// `Comm::split` rendezvous: every rank splits twice with alternating
/// colors; subcommunicator shapes must be schedule-independent.
fn sc_mpisim_split(world: usize) -> Vec<u64> {
    let comms = World::create(world);
    let ranks: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(r, mut comm)| {
            sync::Builder::new()
                .name(format!("rank-{r}"))
                .spawn(move || {
                    let mut digest = Vec::new();
                    for round in 0..2usize {
                        let color = ((r + round) % 2) as i64;
                        match comm.split(color, r) {
                            Some(sub) => {
                                digest.push(sub.size() as u64);
                                digest.push(sub.rank() as u64);
                            }
                            None => digest.push(u64::MAX),
                        }
                    }
                    digest
                })
                .expect("spawn rank thread")
        })
        .collect();
    ranks.into_iter().flat_map(|h| h.join().expect("rank thread")).collect()
}

/// `Comm::wait_any` under racing senders. Completion *order* is genuinely
/// schedule-dependent, so the digest is the sorted multiset of payloads —
/// which must be schedule-independent (nothing lost, nothing duplicated).
fn sc_mpisim_wait_any(world: usize) -> Vec<u64> {
    let mut comms = World::create(world).into_iter();
    let mut c0 = comms.next().expect("rank 0");
    let senders: Vec<_> = comms
        .enumerate()
        .map(|(i, mut comm)| {
            let r = i + 1;
            sync::Builder::new()
                .name(format!("rank-{r}"))
                .spawn(move || {
                    for k in 0..2u64 {
                        comm.send(0, 1, vec![(r as f32) * 10.0 + k as f32]);
                    }
                })
                .expect("spawn sender thread")
        })
        .collect();
    let mut reqs = Vec::new();
    for r in 1..world {
        for _ in 0..2 {
            reqs.push(c0.irecv(r, 1));
        }
    }
    let mut got: Vec<u64> = Vec::new();
    while !reqs.is_empty() {
        let (_, data) = c0.wait_any(&mut reqs);
        got.push(data[0].to_bits() as u64);
    }
    got.sort_unstable();
    for h in senders {
        h.join().expect("sender thread");
    }
    got
}

/// kvstore Pending/engine-var handoff: a `pull` issued between two pushes
/// must observe exactly the first one (push-order serialization through
/// the engine var), under every schedule.
fn sc_kvstore_pending(world: usize) -> Vec<u64> {
    let engine = Arc::new(Engine::new(world));
    let kv = KvWorker::create(KvType::Local, engine, None, None);
    kv.init(0, vec![1.0, 2.0], true);
    kv.push(0, vec![0.5, 0.25]);
    let pending = kv.pull(0);
    kv.push(0, vec![1.0, 1.0]);
    let got = pending.wait();
    kv.wait_all();
    got.iter().map(|&x| x.to_bits() as u64).collect()
}

/// PS quorum: `world` workers plus one server registering against a
/// ClusterScheduler-minted quorum; the launch barrier must release
/// everyone, and membership churn must publish a deterministic view.
fn sc_ps_quorum(world: usize) -> Vec<u64> {
    let cluster = ClusterScheduler::new();
    let sched = cluster.register_job(1, world, 1).expect("register job 1");
    let server = {
        let s = sched.handle();
        sync::Builder::new()
            .name("ps-server".to_string())
            .spawn(move || s.register(Role::Server))
            .expect("spawn server thread")
    };
    let workers: Vec<_> = (0..world)
        .map(|w| {
            let s = sched.handle();
            sync::Builder::new()
                .name(format!("ps-worker-{w}"))
                .spawn(move || s.register_as(w))
                .expect("spawn worker thread")
        })
        .collect();
    for h in workers {
        h.join().expect("worker thread");
    }
    server.join().expect("server thread");
    sched.deregister(0);
    let v1 = cluster.view(1).expect("job 1 registered");
    sched.admit(world);
    let v2 = sched.publish_view();
    let mut digest = vec![v1.epoch, v2.epoch, cluster.live_workers() as u64];
    digest.extend(v2.workers.iter().map(|&w| w as u64));
    digest
}

// ---------------------------------------------------------------------------
// Exploration
// ---------------------------------------------------------------------------

fn run_one(body: Body, world: usize, tape: Vec<u32>, rng_seed: Option<u64>, step_cap: usize) -> ExecReport {
    run_execution(move || body(world), ExecConfig { tape, rng_seed, step_cap })
}

fn diag_from_event(scenario: &str, world: usize, seed: String, ev: &Event) -> RaceDiagnostic {
    let (kind, detail) = match ev {
        Event::Deadlock { detail } => (RaceKind::Deadlock, detail.clone()),
        Event::LostWakeup { thread, cv } => (
            RaceKind::LostWakeup,
            format!("{thread} was parked on {cv} with its predicate already true; no notify could have woken it"),
        ),
        Event::Panic { thread, msg } => (RaceKind::Panic, format!("{thread} panicked: {msg}")),
        Event::StepLimit { steps } => (
            RaceKind::StepLimit,
            format!("exceeded {steps} schedule points (livelock?)"),
        ),
        Event::Stalled => (
            RaceKind::Stalled,
            "a checked thread blocked outside the scheduler's control".to_string(),
        ),
    };
    RaceDiagnostic { scenario: scenario.to_string(), world, kind, detail, seed }
}

/// Find a cycle in the class-level lock-order graph; returns the cycle
/// path (first node repeated at the end) if one exists.
fn find_cycle(edges: &BTreeSet<(&'static str, &'static str)>) -> Option<Vec<&'static str>> {
    let mut adj: BTreeMap<&'static str, Vec<&'static str>> = BTreeMap::new();
    for &(a, b) in edges {
        adj.entry(a).or_default().push(b);
        adj.entry(b).or_default();
    }
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Grey,
        Black,
    }
    fn visit(
        n: &'static str,
        adj: &BTreeMap<&'static str, Vec<&'static str>>,
        color: &mut BTreeMap<&'static str, Color>,
        path: &mut Vec<&'static str>,
    ) -> Option<Vec<&'static str>> {
        color.insert(n, Color::Grey);
        path.push(n);
        for &m in &adj[n] {
            match color[m] {
                Color::Grey => {
                    let start = path.iter().position(|&p| p == m).expect("grey node on path");
                    let mut cycle = path[start..].to_vec();
                    cycle.push(m);
                    return Some(cycle);
                }
                Color::White => {
                    if let Some(c) = visit(m, adj, color, path) {
                        return Some(c);
                    }
                }
                Color::Black => {}
            }
        }
        path.pop();
        color.insert(n, Color::Black);
        None
    }
    let nodes: Vec<&'static str> = adj.keys().copied().collect();
    let mut color: BTreeMap<&'static str, Color> =
        nodes.iter().map(|&n| (n, Color::White)).collect();
    let mut path = Vec::new();
    for &n in &nodes {
        if color[n] == Color::White {
            if let Some(c) = visit(n, &adj, &mut color, &mut path) {
                return Some(c);
            }
        }
    }
    None
}

/// Cross-execution state a scenario's exploration accumulates: the first
/// clean digest (with its seed, for non-determinism reports) and the union
/// of observed lock-order edges.
#[derive(Default)]
struct Accum {
    baseline: Option<(Vec<u64>, String)>,
    edges: BTreeSet<(&'static str, &'static str)>,
}

/// Judge one execution: first kernel event wins; otherwise check the
/// accumulated lock-order graph for cycles, then the digest against the
/// baseline.
fn judge(scenario: &str, world: usize, r: &ExecReport, acc: &mut Accum) -> Option<RaceDiagnostic> {
    let seed = format_seed(scenario, world, &r.taken);
    if let Some(ev) = r.events.first() {
        return Some(diag_from_event(scenario, world, seed, ev));
    }
    acc.edges.extend(r.edges.iter().copied());
    if let Some(cycle) = find_cycle(&acc.edges) {
        return Some(RaceDiagnostic {
            scenario: scenario.to_string(),
            world,
            kind: RaceKind::LockOrder,
            detail: format!("lock-order cycle: {}", cycle.join(" -> ")),
            seed,
        });
    }
    if let Some(d) = &r.digest {
        match &acc.baseline {
            None => acc.baseline = Some((d.clone(), seed)),
            Some((b, bseed)) if b != d => {
                return Some(RaceDiagnostic {
                    scenario: scenario.to_string(),
                    world,
                    kind: RaceKind::NonDeterminism,
                    detail: format!("digest {d:?} differs from baseline {b:?} (baseline seed {bseed})"),
                    seed,
                });
            }
            _ => {}
        }
    }
    None
}

struct Explored {
    execs: usize,
    diag: Option<RaceDiagnostic>,
}

/// Explore one (scenario, world): preorder DFS over the schedule tree —
/// untried sibling choices are stacked shallowest-on-top, so the search
/// dives consecutively along early divergences (the "park the waiter
/// before the notify" shapes are reached within ~depth executions) before
/// exhausting deep tail variations — then seeded random walks past the
/// systematic horizon. Stops at the first diagnostic: exploration past a
/// confirmed finding only costs budget.
fn explore(scenario: &str, world: usize, body: Body, budget: &Budget) -> Explored {
    let mut acc = Accum::default();
    let mut execs = 0usize;
    let mut frontier: Vec<Vec<u32>> = vec![Vec::new()];
    while execs < budget.dfs {
        let Some(tape) = frontier.pop() else { break };
        let forced = tape.len();
        let r = run_one(body, world, tape, None, budget.step_cap);
        execs += 1;
        if let Some(d) = judge(scenario, world, &r, &mut acc) {
            return Explored { execs, diag: Some(d) };
        }
        // Stack the untried siblings of every free (un-forced) decision,
        // deepest pushed first: the next pop takes the shallowest new
        // deviation with its smallest untried choice (preorder).
        for i in (forced..r.taken.len()).rev() {
            for c in ((r.taken[i] + 1)..r.options[i]).rev() {
                let mut t = r.taken[..i].to_vec();
                t.push(c);
                frontier.push(t);
            }
        }
    }
    for s in 0..budget.random {
        let seed = 0x5EED_0000_u64.wrapping_add(s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let r = run_one(body, world, Vec::new(), Some(seed), budget.step_cap);
        execs += 1;
        if let Some(d) = judge(scenario, world, &r, &mut acc) {
            return Explored { execs, diag: Some(d) };
        }
    }
    Explored { execs, diag: None }
}

/// Model-check every ported protocol at every swept world size. `filter`
/// restricts to a single scenario name (CLI `--scenario`).
pub fn run_racecheck(budget: &Budget, filter: Option<&str>) -> Report {
    let mut report = Report::default();
    for sc in scenarios() {
        if filter.is_some_and(|f| f != sc.name) {
            continue;
        }
        report.scenarios += 1;
        for &w in sc.worlds {
            report.worlds += 1;
            let ex = explore(sc.name, w, sc.body, budget);
            report.executions += ex.execs;
            if let Some(d) = ex.diag {
                report.diagnostics.push(d);
                break; // first diagnostic per scenario; move on
            }
        }
    }
    report
}

// ---------------------------------------------------------------------------
// Seeded-mutant suite — the verifier verified
// ---------------------------------------------------------------------------

struct Mutant {
    label: &'static str,
    expected: &'static [RaceKind],
    body: Body,
}

fn mutants() -> &'static [Mutant] {
    &[
        Mutant {
            label: "notify-one-shutdown",
            expected: &[RaceKind::LostWakeup],
            body: mut_notify_one_shutdown,
        },
        Mutant {
            label: "missed-notify",
            expected: &[RaceKind::LostWakeup],
            body: mut_missed_notify,
        },
        Mutant { label: "if-not-while", expected: &[RaceKind::Panic], body: mut_if_not_while },
        Mutant {
            label: "swapped-lock-order",
            expected: &[RaceKind::LockOrder],
            body: mut_swapped_lock_order,
        },
        Mutant {
            label: "nondet-outcome",
            expected: &[RaceKind::NonDeterminism],
            body: mut_nondet_outcome,
        },
        Mutant { label: "channel-cycle", expected: &[RaceKind::Deadlock], body: mut_channel_cycle },
    ]
}

/// A broadcast gated behind `notify_one`: with both waiters parked, one
/// never wakes.
fn mut_notify_one_shutdown(_world: usize) -> Vec<u64> {
    let pair = Arc::new((sync::Mutex::named(false, "mut.flag"), sync::Condvar::named("mut.cv")));
    let waiters: Vec<_> = (0..2)
        .map(|i| {
            let p = pair.clone();
            sync::Builder::new()
                .name(format!("waiter-{i}"))
                .spawn(move || {
                    let (m, cv) = &*p;
                    let mut g = m.lock().expect("flag lock");
                    while !*g {
                        g = cv.wait(g).expect("flag lock");
                    }
                })
                .expect("spawn waiter")
        })
        .collect();
    {
        let (m, cv) = &*pair;
        *m.lock().expect("flag lock") = true;
        cv.notify_one(); // seeded bug: shutdown broadcast needs notify_all
    }
    for h in waiters {
        h.join().expect("waiter");
    }
    vec![1]
}

/// The predicate is set but the notify is forgotten entirely.
fn mut_missed_notify(_world: usize) -> Vec<u64> {
    let pair = Arc::new((sync::Mutex::named(false, "mut.flag"), sync::Condvar::named("mut.cv")));
    let p = pair.clone();
    let w = sync::Builder::new()
        .name("waiter".to_string())
        .spawn(move || {
            let (m, cv) = &*p;
            let mut g = m.lock().expect("flag lock");
            while !*g {
                g = cv.wait(g).expect("flag lock");
            }
        })
        .expect("spawn waiter");
    {
        let (m, _cv) = &*pair;
        *m.lock().expect("flag lock") = true; // seeded bug: no notify after the write
    }
    w.join().expect("waiter");
    vec![1]
}

/// A consumer whose `while` predicate loop collapsed to `if`: woken without
/// the item it raced another consumer for, it pops an empty queue.
fn mut_if_not_while(_world: usize) -> Vec<u64> {
    let q = Arc::new((
        sync::Mutex::named(Vec::<u64>::new(), "mut.queue"),
        sync::Condvar::named("mut.queue_cv"),
    ));
    let qa = q.clone();
    let a = sync::Builder::new()
        .name("consumer-while".to_string())
        .spawn(move || {
            let (m, cv) = &*qa;
            let mut g = m.lock().expect("queue lock");
            while g.is_empty() {
                g = cv.wait(g).expect("queue lock");
            }
            g.pop().expect("non-empty after while re-check")
        })
        .expect("spawn consumer");
    let qb = q.clone();
    let b = sync::Builder::new()
        .name("consumer-if".to_string())
        .spawn(move || {
            let (m, cv) = &*qb;
            let mut g = m.lock().expect("queue lock");
            if g.is_empty() {
                // seeded bug: no re-check after waking
                g = cv.wait(g).expect("queue lock");
            }
            g.pop().expect("woken with an empty queue")
        })
        .expect("spawn consumer");
    {
        let (m, cv) = &*q;
        for item in [1u64, 2] {
            m.lock().expect("queue lock").push(item);
            cv.notify_all();
        }
    }
    let x = a.join().expect("consumer-while");
    let y = b.join().expect("consumer-if");
    vec![x + y]
}

/// Two threads taking the same two locks in opposite orders.
fn mut_swapped_lock_order(_world: usize) -> Vec<u64> {
    let a = Arc::new(sync::Mutex::named(0u64, "mut.a"));
    let b = Arc::new(sync::Mutex::named(0u64, "mut.b"));
    let (a2, b2) = (a.clone(), b.clone());
    let t = sync::Builder::new()
        .name("inverted".to_string())
        .spawn(move || {
            let mut gb = b2.lock().expect("lock b"); // seeded bug: b-then-a
            let mut ga = a2.lock().expect("lock a");
            *ga += 1;
            *gb += 1;
        })
        .expect("spawn inverted");
    {
        let mut ga = a.lock().expect("lock a");
        let mut gb = b.lock().expect("lock b");
        *ga += 1;
        *gb += 1;
    }
    t.join().expect("inverted");
    let x = *a.lock().expect("lock a");
    let y = *b.lock().expect("lock b");
    vec![x, y]
}

/// Unordered last-writer-wins: the final value depends on the schedule.
fn mut_nondet_outcome(_world: usize) -> Vec<u64> {
    let cell = Arc::new(sync::Mutex::named(0u64, "mut.cell"));
    let writers: Vec<_> = (1..=2u64)
        .map(|i| {
            let c = cell.clone();
            sync::Builder::new()
                .name(format!("writer-{i}"))
                .spawn(move || {
                    *c.lock().expect("cell lock") = i; // seeded bug: no ordering
                })
                .expect("spawn writer")
        })
        .collect();
    for h in writers {
        h.join().expect("writer");
    }
    let v = *cell.lock().expect("cell lock");
    vec![v]
}

/// Two threads each receiving what only the other would send.
fn mut_channel_cycle(_world: usize) -> Vec<u64> {
    let (tx_a, rx_a) = sync::channel_named::<u8>("mut.chan_a");
    let (tx_b, rx_b) = sync::channel_named::<u8>("mut.chan_b");
    let t = sync::Builder::new()
        .name("peer".to_string())
        .spawn(move || {
            let v = rx_b.recv().unwrap_or(0);
            let _ = tx_a.send(v);
        })
        .expect("spawn peer");
    let v = rx_a.recv().unwrap_or(0); // seeded bug: recv-before-send cycle
    let _ = tx_b.send(v);
    let _ = t.join();
    vec![u64::from(v)]
}

/// Outcome of one seeded mutant run.
#[derive(Debug)]
pub struct MutantOutcome {
    pub label: &'static str,
    pub expected: &'static [RaceKind],
    /// Diagnostic classes racecheck actually reported.
    pub found: Vec<RaceKind>,
    pub diag: Option<RaceDiagnostic>,
    /// A diagnostic of an expected class was reported.
    pub caught: bool,
}

/// Run every seeded mutant; each must be caught with its expected
/// diagnostic class (the gate fails on any escape).
pub fn run_mutant_suite(budget: &Budget) -> Vec<MutantOutcome> {
    // Exploration stops at the first catch, so a deeper floor costs
    // nothing when the mutant is caught early — and it keeps the seeded
    // bugs inside the systematic horizon even under Budget::quick().
    let budget = Budget {
        dfs: budget.dfs.max(192),
        random: budget.random.max(16),
        step_cap: budget.step_cap,
    };
    mutants()
        .iter()
        .map(|m| {
            let name = format!("mutant/{}", m.label);
            let ex = explore(&name, 2, m.body, &budget);
            let found: Vec<RaceKind> = ex.diag.iter().map(|d| d.kind).collect();
            let caught = found.iter().any(|k| m.expected.contains(k));
            MutantOutcome { label: m.label, expected: m.expected, found, diag: ex.diag, caught }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------------

fn find_body(name: &str) -> Option<(Body, bool)> {
    if let Some(sc) = scenarios().iter().find(|s| s.name == name) {
        return Some((sc.body, false));
    }
    let label = name.strip_prefix("mutant/")?;
    mutants().iter().find(|m| m.label == label).map(|m| (m.body, true))
}

/// Replay a schedule seed: re-runs the scenario under the exact decision
/// tape and reproduces the diagnostic bit for bit. A baseline (empty-tape)
/// execution is run first so the cross-execution detectors — digest
/// comparison and lock-order accumulation — judge the replayed schedule
/// the same way exploration did.
pub fn replay(seed: &str, step_cap: usize) -> Result<(Report, Vec<u32>), String> {
    let (name, world, tape) = parse_seed(seed)?;
    let (body, _is_mutant) =
        find_body(&name).ok_or_else(|| format!("unknown scenario {name:?} in seed"))?;
    let mut acc = Accum::default();
    let mut report =
        Report { scenarios: 1, worlds: 1, executions: 0, diagnostics: Vec::new() };
    // Baseline pass (events ignored: it only seeds the cross-execution
    // detectors; if the empty tape itself fails, the replayed tape will
    // reproduce that failure below).
    let base = run_one(body, world, Vec::new(), None, step_cap);
    report.executions += 1;
    if base.events.is_empty() {
        let _ = judge(&name, world, &base, &mut acc);
    } else {
        acc.edges.extend(base.edges.iter().copied());
    }
    let r = run_one(body, world, tape, None, step_cap);
    report.executions += 1;
    if let Some(d) = judge(&name, world, &r, &mut acc) {
        report.diagnostics.push(d);
    }
    Ok((report, r.taken))
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_grammar_round_trips() {
        let s = format_seed("engine-pool", 3, &[0, 2, 1]);
        assert_eq!(s, "rc1:engine-pool:w3:0,2,1");
        assert_eq!(parse_seed(&s).expect("parse"), ("engine-pool".to_string(), 3, vec![0, 2, 1]));
        let empty = format_seed("mutant/channel-cycle", 2, &[]);
        assert_eq!(empty, "rc1:mutant/channel-cycle:w2:-");
        assert_eq!(
            parse_seed(&empty).expect("parse"),
            ("mutant/channel-cycle".to_string(), 2, vec![])
        );
        assert!(parse_seed("bogus").is_err());
        assert!(parse_seed("rc1:x:3:-").is_err(), "world field must be 'w<n>'");
    }

    #[test]
    fn lock_order_cycle_detection() {
        let mut edges = BTreeSet::new();
        edges.insert(("a", "b"));
        edges.insert(("b", "c"));
        assert!(find_cycle(&edges).is_none());
        edges.insert(("c", "a"));
        let cycle = find_cycle(&edges).expect("cycle");
        assert_eq!(cycle.first(), cycle.last());
        assert!(cycle.len() >= 3);
    }

    #[test]
    fn clean_scenarios_pass_quick_budget() {
        let budget = Budget::quick();
        let report = run_racecheck(&budget, None);
        assert_eq!(report.scenarios, scenarios().len());
        assert!(report.executions > 0);
        assert!(
            report.ok(),
            "expected clean run, got: {}",
            report
                .diagnostics
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn every_seeded_mutant_is_caught() {
        let budget = Budget::quick();
        for out in run_mutant_suite(&budget) {
            assert!(
                out.caught,
                "mutant {} escaped: expected one of {:?}, found {:?}",
                out.label, out.expected, out.found
            );
        }
    }

    #[test]
    fn replayed_seed_reproduces_diagnostic_bitwise() {
        let budget = Budget::quick();
        let outcomes = run_mutant_suite(&budget);
        for label in ["channel-cycle", "swapped-lock-order", "nondet-outcome"] {
            let out = outcomes
                .iter()
                .find(|o| o.label == label)
                .expect("mutant in suite");
            let diag = out.diag.as_ref().expect("mutant diagnostic");
            let (report, taken) = replay(&diag.seed, 20_000).expect("replay");
            assert_eq!(
                report.diagnostics.len(),
                1,
                "{label}: replay must reproduce exactly the diagnostic"
            );
            assert_eq!(
                report.diagnostics[0].to_string(),
                diag.to_string(),
                "{label}: replayed diagnostic must be bitwise identical"
            );
            // And the interleaving itself is identical: the replayed tape
            // re-derives the seed it was fed.
            let (name, world, _) = parse_seed(&diag.seed).expect("parse");
            assert_eq!(format_seed(&name, world, &taken), diag.seed);
        }
    }

    #[test]
    fn scenario_filter_limits_the_sweep() {
        let budget = Budget { dfs: 4, random: 0, step_cap: 20_000 };
        let report = run_racecheck(&budget, Some("engine-pool"));
        assert_eq!(report.scenarios, 1);
        assert_eq!(report.worlds, 3);
    }
}
