//! Elastic-epoch safety: exhaustive model checking of `FaultPlan` ×
//! [`ElasticHub`] for small worlds.
//!
//! PR 3's claim — *no collective ever spans a dead rank* — was validated
//! only by a randomized thread model. This module re-proves it
//! exhaustively for every enumerated plan: single and paired churn
//! events (kill, straggle, join) over 2/3/4-worker worlds, both epoch
//! cadences, every event iteration in a small window. For each plan the
//! hub's precomputed epoch tables are checked against an independent
//! model of the membership semantics, invalid plans must be *rejected*
//! (not silently mangled), and — the trace-level proof — the registered
//! collectives are run over each epoch's survivor world on the tracing
//! fabric, asserting that no captured `(src, dst)` event maps to a rank
//! the plan kills at that boundary.
//!
//! The `Comm::split` rule rides along: for every epoch with kills, a
//! real threaded split is performed over the pre-epoch world; dying
//! ranks pass a negative color (MPI_UNDEFINED) and must get `None`,
//! survivors must land at exactly the rank `Group::exclude` translation
//! predicts.

use super::trace::{run_traced, TraceEvent};
use super::{CheckKind, Diagnostic, Report, ScheduleId};
use crate::cluster::{simulate as cluster_simulate, AllocPolicy, ArrivalPlan, ClusterSpec};
use crate::collectives::AlgoKind;
use crate::compress::{Codec, EfState};
use crate::kvstore::KvType;
use crate::launcher::{ElasticHub, JobSpec};
use crate::mpisim::{Group, World};
use crate::netsim::CostParams;
use crate::ps::{FaultEvent, FaultKind, FaultPlan, Scheduler, SyncMode};
use std::collections::BTreeMap;

/// The enumerated worlds: (workers, clients). Small enough to be
/// exhaustive, large enough to cover multi-client kills and joins.
const WORLDS: &[(usize, usize)] = &[(2, 1), (3, 1), (4, 2)];

/// Epoch cadences (`reconfig_every`): every iteration and lazy-sync.
const CADENCES: &[u64] = &[1, 2];

/// Event iterations for single-event plans.
const ITERS: &[u64] = &[0, 1, 2];

/// One enumerated churn event, rendered into the `--fault` grammar so
/// the check exercises the real parser too.
#[derive(Debug, Clone, Copy)]
enum Ev {
    Kill(usize),
    Straggle(usize),
    Join,
}

impl Ev {
    fn render(&self, at: u64) -> String {
        match self {
            Ev::Kill(r) => format!("kill:{r}@{at}"),
            Ev::Straggle(r) => format!("straggle:{r}@{at}x2"),
            Ev::Join => format!("join@{at}"),
        }
    }
}

/// Independent model of one epoch's membership tables (the spec the
/// hub's precomputation is checked against).
struct ModelEpoch {
    boundary: u64,
    kills: Vec<usize>,
    joins: Vec<usize>,
    /// Post-kill pre-join live set: (ps_rank, client) ascending.
    survivors: Vec<(usize, usize)>,
    /// Post-join live set: (ps_rank, client) ascending.
    members_after: Vec<(usize, usize)>,
    straggle: BTreeMap<usize, f64>,
}

/// Replay the documented membership semantics: events take effect at the
/// first cadence boundary at/after their iteration, kills must target
/// live ranks, each boundary must keep at least one survivor and keep
/// client 0 populated, joins land post-kill on the explicit or emptiest
/// client with ranks allocated from `workers` upward.
fn model_epochs(
    workers: usize,
    clients: usize,
    cadence: u64,
    events: &[FaultEvent],
) -> Result<Vec<ModelEpoch>, String> {
    let wpc = workers / clients.max(1);
    let mut live: BTreeMap<usize, usize> = (0..workers).map(|r| (r, r / wpc)).collect();
    let mut straggle: BTreeMap<usize, f64> = BTreeMap::new();
    let mut next_join_rank = workers;
    let mut grouped: BTreeMap<u64, Vec<FaultKind>> = BTreeMap::new();
    for ev in events {
        let boundary = (ev.at_iter + cadence) / cadence * cadence - 1;
        grouped.entry(boundary).or_default().push(ev.kind);
    }
    let mut out = Vec::new();
    for (boundary, kinds) in grouped {
        let mut kills = Vec::new();
        let mut joins = Vec::new();
        for kind in &kinds {
            match *kind {
                FaultKind::Kill { rank } => {
                    if live.remove(&rank).is_none() {
                        return Err(format!("kills non-live rank {rank} at {boundary}"));
                    }
                    kills.push(rank);
                }
                FaultKind::Straggle { rank, factor } => {
                    if !live.contains_key(&rank) {
                        return Err(format!("straggles non-live rank {rank} at {boundary}"));
                    }
                    *straggle.entry(rank).or_insert(1.0) *= factor;
                }
                FaultKind::Join { .. } => {}
            }
        }
        if live.is_empty() {
            return Err(format!("no survivors at {boundary}"));
        }
        if !live.values().any(|&c| c == 0) {
            return Err(format!("client 0 emptied at {boundary}"));
        }
        let survivors: Vec<(usize, usize)> = live.iter().map(|(&r, &c)| (r, c)).collect();
        for kind in &kinds {
            if let FaultKind::Join { client } = *kind {
                let target = client.unwrap_or_else(|| {
                    let mut counts: BTreeMap<usize, usize> =
                        (0..clients).map(|c| (c, 0)).collect();
                    for &c in live.values() {
                        *counts.entry(c).or_insert(0) += 1;
                    }
                    counts
                        .iter()
                        .min_by_key(|&(&c, &n)| (n, c))
                        .map(|(&c, _)| c)
                        .unwrap_or(0)
                });
                if target >= clients {
                    return Err(format!("join targets client {target} of {clients}"));
                }
                live.insert(next_join_rank, target);
                joins.push(next_join_rank);
                next_join_rank += 1;
            }
        }
        out.push(ModelEpoch {
            boundary,
            kills,
            joins,
            survivors,
            members_after: live.iter().map(|(&r, &c)| (r, c)).collect(),
            straggle: straggle.clone(),
        });
    }
    Ok(out)
}

fn spec_for(workers: usize, clients: usize, plan: FaultPlan, cadence: u64) -> JobSpec {
    JobSpec {
        workers,
        servers: 0,
        clients,
        ktype: KvType::SyncMpi,
        server_mode: SyncMode::Sync,
        engine_threads: 1,
        collective: AlgoKind::Ring,
        fusion_bytes: 0,
        rings: 1,
        group: 2,
        devices: 1,
        cost: CostParams::testbed1(),
        codec: Codec::identity(),
        topk_ratio: 0.25,
        fault: plan,
        reconfig_every: cadence,
    }
}

/// Check one plan end to end; `plan_str` identifies it in diagnostics.
fn check_plan(
    workers: usize,
    clients: usize,
    cadence: u64,
    plan_str: &str,
    report: &mut Report,
) {
    report.configs_checked += 1;
    let diag = |kind: CheckKind, detail: String| Diagnostic {
        schedule: format!("elastic[{workers}w/{clients}c@{cadence}] {plan_str}"),
        p: workers,
        chunks: 0,
        len: 0,
        kind,
        detail,
    };
    let plan = match FaultPlan::parse(plan_str) {
        Ok(p) => p,
        Err(e) => {
            report
                .diagnostics
                .push(diag(CheckKind::ElasticEpoch, format!("plan failed to parse: {e}")));
            return;
        }
    };
    let expected = model_epochs(workers, clients, cadence, &plan.events);
    let spec = spec_for(workers, clients, plan, cadence);
    let hub = ElasticHub::new(&spec, Scheduler::new(0, 0), None);
    match (&expected, &hub) {
        (Err(_), Err(_)) => return, // correctly rejected
        (Err(why), Ok(_)) => {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!("hub accepted an inconsistent plan (model rejects it: {why})"),
            ));
            return;
        }
        (Ok(_), Err(e)) => {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!("hub rejected a consistent plan: {e}"),
            ));
            return;
        }
        (Ok(_), Ok(_)) => {}
    }
    let expected = expected.expect("checked above");
    let hub = hub.expect("checked above");
    if hub.n_epochs() != expected.len() {
        report.diagnostics.push(diag(
            CheckKind::ElasticEpoch,
            format!("hub plans {} epochs, model expects {}", hub.n_epochs(), expected.len()),
        ));
        return;
    }
    let mut prev_members: Vec<(usize, usize)> = {
        let wpc = workers / clients.max(1);
        (0..workers).map(|r| (r, r / wpc)).collect()
    };
    let mut prev_boundary: Option<u64> = None;
    for (e, want) in expected.iter().enumerate() {
        let eu = e as u64;
        // -- table equivalence against the independent model ------------
        if hub.boundary_iter(eu) != Some(want.boundary) {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!(
                    "epoch {e}: boundary {:?}, model expects {}",
                    hub.boundary_iter(eu),
                    want.boundary
                ),
            ));
        }
        if let Some(pb) = prev_boundary {
            if want.boundary <= pb {
                report.diagnostics.push(diag(
                    CheckKind::ElasticEpoch,
                    format!("epoch {e}: boundary {} not after previous {pb}", want.boundary),
                ));
            }
        }
        prev_boundary = Some(want.boundary);
        if hub.dying_at(eu) != want.kills.as_slice() {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!("epoch {e}: kills {:?}, model expects {:?}", hub.dying_at(eu), want.kills),
            ));
        }
        if hub.joins_at(eu) != want.joins.as_slice() {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!("epoch {e}: joins {:?}, model expects {:?}", hub.joins_at(eu), want.joins),
            ));
        }
        if hub.members_after(eu) != want.members_after.as_slice() {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!(
                    "epoch {e}: members {:?}, model expects {:?}",
                    hub.members_after(eu),
                    want.members_after
                ),
            ));
            return; // downstream checks would cascade
        }
        for client in 0..clients {
            let model_master = want
                .survivors
                .iter()
                .find(|&&(_, c)| c == client)
                .map(|&(r, _)| r);
            if hub.ckpt_master(eu, client) != model_master {
                report.diagnostics.push(diag(
                    CheckKind::ElasticEpoch,
                    format!(
                        "epoch {e}: ckpt master of client {client} is {:?}, model expects {:?}",
                        hub.ckpt_master(eu, client),
                        model_master
                    ),
                ));
            }
        }
        for &(r, _) in &want.members_after {
            let f = hub.straggle_after(eu, r);
            let wf = want.straggle.get(&r).copied().unwrap_or(1.0);
            if f != wf || f < 1.0 {
                report.diagnostics.push(diag(
                    CheckKind::ElasticEpoch,
                    format!("epoch {e}: straggle of rank {r} is {f}, model expects {wf}"),
                ));
            }
        }
        // -- the safety property itself ---------------------------------
        // Kills must be gone from the post-epoch membership...
        for k in &want.kills {
            if hub.members_after(eu).iter().any(|&(r, _)| r == *k) {
                report.diagnostics.push(diag(
                    CheckKind::ElasticEpoch,
                    format!("epoch {e}: killed rank {k} still in members_after"),
                ));
            }
        }
        // ...and no traced collective event over any rebuilt per-client
        // world may map back to a killed ps_rank.
        report
            .diagnostics
            .extend(epoch_trace_diags(&hub, eu, clients, &want.kills, &diag));
        // The split rule for this epoch's world teardown.
        if !want.kills.is_empty() {
            report
                .diagnostics
                .extend(split_rule_diags(&prev_members, &want.kills, &diag));
        }
        prev_members = want.members_after.clone();
    }
    // Joiner seeds must agree with the epoch tables they index into.
    for (rank, client, epoch) in hub.joiner_seeds() {
        if !hub.joins_at(epoch).contains(&rank)
            || !hub.members_after(epoch).contains(&(rank, client))
        {
            report.diagnostics.push(diag(
                CheckKind::ElasticEpoch,
                format!("joiner seed ({rank}, {client}, {epoch}) not in the epoch tables"),
            ));
        }
    }
}

/// Run the registered collectives over each rebuilt per-client world on
/// the tracing fabric and map every event endpoint back to ps_ranks: an
/// event targeting a killed rank is the exact bug class PR 3 guards
/// against.
fn epoch_trace_diags(
    hub: &ElasticHub,
    epoch: u64,
    clients: usize,
    kills: &[usize],
    diag: &dyn Fn(CheckKind, String) -> Diagnostic,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let schedules = [
        ScheduleId::Ring { rings: 1 },               // neighbor pattern
        ScheduleId::Compressed { codec: Codec::named("topk") }, // all-pairs
    ];
    for client in 0..clients {
        let ranks: Vec<usize> = hub
            .members_after(epoch)
            .iter()
            .filter(|&&(_, c)| c == client)
            .map(|&(r, _)| r)
            .collect();
        if ranks.len() < 2 {
            continue;
        }
        for id in &schedules {
            let run = run_traced(ranks.len(), |c| {
                let mut bufs = vec![vec![1.0f32; 7]];
                let mut ef = EfState::new();
                id.run(c, &mut bufs, 1, &mut ef);
            });
            if !run.clean() {
                out.push(diag(
                    CheckKind::ElasticEpoch,
                    format!(
                        "epoch {epoch}: {} over client {client}'s rebuilt world did not \
                         run clean",
                        id.name()
                    ),
                ));
                continue;
            }
            'events: for (new_rank, evs) in run.events.iter().enumerate() {
                for ev in evs {
                    let peer = match ev {
                        TraceEvent::Send { to, .. } => *to,
                        TraceEvent::Recv { from, .. } => *from,
                        TraceEvent::Cancel { .. } => continue,
                    };
                    let ps = ranks[peer];
                    if kills.contains(&ps) {
                        out.push(diag(
                            CheckKind::ElasticEpoch,
                            format!(
                                "epoch {epoch}: {} event of new rank {new_rank} targets \
                                 ps_rank {ps}, which this epoch kills",
                                id.name()
                            ),
                        ));
                        break 'events;
                    }
                }
            }
        }
    }
    out
}

/// The negative-color split rule, on the *real* mpisim fabric: over the
/// pre-epoch world, dying ranks split with MPI_UNDEFINED and must get no
/// communicator; survivors must land exactly where the `Group::exclude`
/// translation says, in a world of exactly the survivor count.
fn split_rule_diags(
    prev_members: &[(usize, usize)],
    kills: &[usize],
    diag: &dyn Fn(CheckKind, String) -> Diagnostic,
) -> Vec<Diagnostic> {
    let prev: Vec<usize> = prev_members.iter().map(|&(r, _)| r).collect();
    let n = prev.len();
    if n < 2 {
        return Vec::new();
    }
    let prev_group = Group::new(prev.clone());
    let new_group = prev_group.exclude(kills);
    let comms = World::create(n);
    let errors: Vec<Option<String>> = std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(idx, mut comm)| {
                let ps = prev[idx];
                let dying = kills.contains(&ps);
                let new_group = &new_group;
                s.spawn(move || {
                    if dying {
                        match comm.split(-1, 0) {
                            None => None,
                            Some(_) => Some(format!(
                                "dying ps_rank {ps} got a communicator from split(-1)"
                            )),
                        }
                    } else {
                        let want_rank = new_group.rank_of(ps).expect("survivor in new group");
                        match comm.split(0, idx) {
                            None => Some(format!("survivor ps_rank {ps} got None from split(0)")),
                            Some(sub) if sub.size() != new_group.size() => Some(format!(
                                "survivor ps_rank {ps}: sub-world size {} != {}",
                                sub.size(),
                                new_group.size()
                            )),
                            Some(sub) if sub.rank() != want_rank => Some(format!(
                                "survivor ps_rank {ps}: sub-rank {} != Group::exclude \
                                 translation {want_rank}",
                                sub.rank()
                            )),
                            Some(_) => None,
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("split-rule worker panicked"))
            .collect()
    });
    errors
        .into_iter()
        .flatten()
        .map(|e| diag(CheckKind::SplitRule, e))
        .collect()
}

/// Exhaustive sweep: single events over every (world, cadence, iter)
/// cell, plus ordered event pairs over the 2-client world (including
/// invalid pairs — kill the same rank twice — which must be rejected).
pub fn check_elastic() -> Report {
    let mut report = Report::default();
    for &(workers, clients) in WORLDS {
        let mut singles: Vec<Ev> = Vec::new();
        for r in 0..workers {
            singles.push(Ev::Kill(r));
            singles.push(Ev::Straggle(r));
        }
        singles.push(Ev::Join);
        for &cadence in CADENCES {
            for ev in &singles {
                for &at in ITERS {
                    check_plan(workers, clients, cadence, &ev.render(at), &mut report);
                }
            }
        }
    }
    // Pairs on the multi-client world: kills × kills (same-rank pairs are
    // invalid and must be rejected), kills × join, join × kills.
    let (workers, clients) = (4, 2);
    let mut pair_events: Vec<Ev> = (0..workers).map(Ev::Kill).collect();
    pair_events.push(Ev::Join);
    for first in &pair_events {
        for second in &pair_events {
            for &(a, b) in &[(0u64, 0u64), (0, 2)] {
                let plan = format!("{},{}", first.render(a), second.render(b));
                check_plan(workers, clients, 2, &plan, &mut report);
            }
        }
    }
    report
}

// ---------------------------------------------------------------------------
// Multi-job cluster view (PR 9)
// ---------------------------------------------------------------------------

/// Cluster scenarios checked by [`check_cluster`]: pool size × arrival
/// plan, each run under both allocation policies. The plans are chosen so
/// the elastic runs exercise grow, shrink, queueing behind a grown
/// allocation, and heterogeneous codecs on one pool.
const CLUSTER_SCENARIOS: &[(usize, &str)] = &[
    (4, "mpi-SGD:2x3@0,mpi-SGD:2x2@6"),
    (6, "mpi-SGD:2x8@0,mpi-SGD:6x2@9"),
    (8, "mpi-SGD:2x4@0,mpi-SGD:4x3@30,mpi-ESGD.int8:2x4@45"),
];

/// The multi-job extension of the elastic model check: run each cluster
/// scenario on virtual time under both allocation policies and verify
///
/// * **pool conservation** — `free + allocated == nodes` at every audited
///   event and no node is ever double-booked,
/// * **plan validity** — every synthesized churn schedule, re-rendered
///   through the `--fault` grammar, passes the full single-job
///   [`check_plan`] (table equivalence, trace safety, split rule), and an
///   [`ElasticHub`] built from the job's own launch spec reproduces the
///   authority's width trajectory on the epoch grid,
/// * **policy contracts** — static allocation synthesizes no churn and
///   never moves a job off its gang width; no policy shrinks a job below
///   its gang; total useful samples are fixed by the arrival plan alone.
pub fn check_cluster() -> Report {
    let mut report = Report::default();
    for &(nodes, plan_str) in CLUSTER_SCENARIOS {
        let mut totals: Vec<u64> = Vec::new();
        for policy in [AllocPolicy::Static, AllocPolicy::Elastic] {
            report.configs_checked += 1;
            let diag = |detail: String| Diagnostic {
                schedule: format!("cluster[{nodes}n/{}] {plan_str}", policy.name()),
                p: nodes,
                chunks: 0,
                len: 0,
                kind: CheckKind::ClusterPool,
                detail,
            };
            let plan = match ArrivalPlan::parse(plan_str) {
                Ok(p) => p,
                Err(e) => {
                    report
                        .diagnostics
                        .push(diag(format!("arrival plan failed to parse: {e:#}")));
                    continue;
                }
            };
            let n_jobs = plan.jobs.len();
            let mut cspec = ClusterSpec::with_defaults(nodes, policy, plan);
            cspec.iters_per_epoch = 4;
            cspec.batch = 8;
            cspec.compute_s = 1.0;
            cspec.bytes = 1 << 20;
            let out = match cluster_simulate(&cspec) {
                Ok(o) => o,
                Err(e) => {
                    report.diagnostics.push(diag(format!("simulate failed: {e:#}")));
                    continue;
                }
            };
            if out.audit.double_booked != 0 {
                report.diagnostics.push(diag(format!(
                    "{} double-booked node claims across {} audit snapshots",
                    out.audit.double_booked, out.audit.snapshots
                )));
            }
            if out.audit.alloc_free_min != nodes || out.audit.alloc_free_max != nodes {
                report.diagnostics.push(diag(format!(
                    "pool not conserved: free+allocated ranged {}..={} on a {nodes}-node pool",
                    out.audit.alloc_free_min, out.audit.alloc_free_max
                )));
            }
            if out.jobs.len() != n_jobs {
                report
                    .diagnostics
                    .push(diag(format!("only {} of {n_jobs} jobs completed", out.jobs.len())));
                continue;
            }
            totals.push(out.total_samples);
            for j in &out.jobs {
                if j.widths.first() != Some(&j.base_workers)
                    || j.widths.iter().any(|&w| w < j.base_workers)
                {
                    report.diagnostics.push(diag(format!(
                        "{}: width trajectory {:?} undercuts the gang width {}",
                        j.name, j.widths, j.base_workers
                    )));
                }
                if policy == AllocPolicy::Static && !j.fault.is_empty() {
                    report.diagnostics.push(diag(format!(
                        "{}: static allocation synthesized churn: {}",
                        j.name,
                        j.fault.render()
                    )));
                }
                if j.fault.is_empty() {
                    continue;
                }
                // Feed the synthesized plan back through the single-job
                // model check, via the real grammar round-trip.
                check_plan(
                    j.base_workers,
                    1,
                    cspec.iters_per_epoch,
                    &j.fault.render(),
                    &mut report,
                );
                // And the hub replaying the job's own launch spec must
                // land on the authority's widths, on the epoch grid.
                match ElasticHub::new(&j.spec, Scheduler::new(0, 0), None) {
                    Err(e) => report.diagnostics.push(diag(format!(
                        "{}: hub rejected the synthesized launch spec: {e:#}",
                        j.name
                    ))),
                    Ok(hub) => {
                        for e in 0..hub.n_epochs() as u64 {
                            let Some(b) = hub.boundary_iter(e) else { continue };
                            if (b + 1) % cspec.iters_per_epoch != 0 {
                                report.diagnostics.push(diag(format!(
                                    "{}: epoch {e} boundary {b} is off the {}-iteration grid",
                                    j.name, cspec.iters_per_epoch
                                )));
                                continue;
                            }
                            let idx = ((b + 1) / cspec.iters_per_epoch) as usize;
                            let w = hub.members_after(e).len();
                            if j.widths.get(idx) != Some(&w) {
                                report.diagnostics.push(diag(format!(
                                    "{}: hub width {w} at epoch index {idx} diverges from \
                                     the authority's trajectory {:?}",
                                    j.name, j.widths
                                )));
                            }
                        }
                    }
                }
            }
        }
        if totals.len() == 2 && totals[0] != totals[1] {
            report.diagnostics.push(Diagnostic {
                schedule: format!("cluster[{nodes}n] {plan_str}"),
                p: nodes,
                chunks: 0,
                len: 0,
                kind: CheckKind::ClusterPool,
                detail: format!(
                    "total useful samples depend on the policy: static {} vs elastic {}",
                    totals[0], totals[1]
                ),
            });
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_matches_hub_on_known_plan() {
        let mut report = Report::default();
        check_plan(4, 2, 2, "kill:1@0,join@3", &mut report);
        assert!(report.ok(), "diagnostics: {:?}", report.diagnostics);
    }

    #[test]
    fn killing_everyone_is_rejected_by_both() {
        let mut report = Report::default();
        check_plan(2, 1, 1, "kill:0@0,kill:1@0", &mut report);
        assert!(report.ok(), "both model and hub must reject: {:?}", report.diagnostics);
    }

    #[test]
    fn full_elastic_sweep_is_clean() {
        let report = check_elastic();
        assert!(report.ok(), "elastic diagnostics: {:?}", report.diagnostics);
        assert!(report.configs_checked > 100);
    }

    #[test]
    fn cluster_pool_sweep_is_clean() {
        let report = check_cluster();
        assert!(report.ok(), "cluster diagnostics: {:?}", report.diagnostics);
        // Both policies over every scenario, plus one single-job model
        // check per synthesized plan.
        assert!(report.configs_checked >= 2 * 3);
    }
}
