//! Deterministic virtual scheduler for racecheck (CHESS-style stateless
//! model checking).
//!
//! A *checked execution* runs real OS threads, but serialized: at most one
//! thread is ever running, and it runs exactly until its next *visible
//! operation* (lock, condvar wait/notify, channel send/recv, join — the
//! hooks in [`crate::util::sync`]). At that point it parks on a private
//! gate and the coordinator picks the next thread to grant from the set of
//! *enabled* ones (a lock is enabled iff free, a recv iff a message is
//! buffered or all senders are gone, a join iff the child exited). Every
//! point where more than one option exists consumes one entry from a
//! *decision tape*; replaying the same tape replays the same interleaving
//! bit for bit, which is what racecheck's replayable seeds are.
//!
//! Detectors built into the kernel:
//! - **Deadlock**: no enabled thread, no waiter left to probe.
//! - **Lost wakeup**: at quiescence the coordinator delivers a *spurious
//!   wake* to a condvar waiter. A correct waiter re-checks its predicate
//!   and re-parks (`while`-loop protocol); a waiter that instead proceeds
//!   had a true predicate with no notify in flight — nothing could ever
//!   have woken it — and is reported.
//! - **Lock-order edges**: every acquire-while-holding records a
//!   class-level edge; racecheck checks the accumulated graph for cycles.
//! - **Panic**: any checked thread that unwinds is recorded (first panic
//!   wins the diagnostic; the execution keeps being scheduled so sibling
//!   threads can drain).
//!
//! Aborted executions (deadlock, step limit, stall) release every parked
//! thread into *pass-through mode*: all shim hooks become no-ops for that
//! session and the threads fall back to plain `std` blocking. Genuinely
//! deadlocked threads then block in `std` forever and are leaked — bounded,
//! because exploration stops at the first diagnostic. Shim objects must not
//! outlive the execution that first registered them (scenarios construct
//! all state inside the checked body, so this holds by construction).

use crate::util::Rng;
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, OnceLock};
use std::time::Duration;

/// Watchdog for a single grant: if the running thread does not come back to
/// a schedule point within this long, the kernel assumes it blocked inside
/// a real primitive (an invariant violation) and aborts the execution
/// instead of hanging CI.
const WATCHDOG: Duration = Duration::from_secs(10);

// ---------------------------------------------------------------------------
// Public result types
// ---------------------------------------------------------------------------

/// A fact the kernel established during one execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// All live threads blocked with every wakeup avenue exhausted.
    Deadlock { detail: String },
    /// `thread` was parked on `cv` while its predicate held: no pending
    /// notify could ever have woken it (detected by the spurious-wake
    /// probe at quiescence).
    LostWakeup { thread: String, cv: String },
    /// A checked thread unwound.
    Panic { thread: String, msg: String },
    /// The execution exceeded the per-execution schedule-point budget
    /// (livelock guard).
    StepLimit { steps: usize },
    /// A checked thread blocked outside the kernel's control (internal
    /// invariant violation — should never fire).
    Stalled,
}

/// Per-execution knobs.
pub struct ExecConfig {
    /// Decision tape to replay; choices beyond its end default to 0 (or to
    /// random draws when `rng_seed` is set).
    pub tape: Vec<u32>,
    /// Seed for random-walk choices past the tape end.
    pub rng_seed: Option<u64>,
    /// Schedule-point budget (livelock guard).
    pub step_cap: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        Self { tape: Vec::new(), rng_seed: None, step_cap: 50_000 }
    }
}

/// What one checked execution did.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub events: Vec<Event>,
    /// Scenario digest (the body's return value); `None` when the
    /// execution was aborted before the main thread could finish.
    pub digest: Option<Vec<u64>>,
    /// The decision actually taken at each branch point (>= 2 options);
    /// feeding this back as the tape replays the execution exactly.
    pub taken: Vec<u32>,
    /// Number of options at each branch point (for DFS backtracking).
    pub options: Vec<u32>,
    /// Schedule points granted.
    pub steps: usize,
    /// Class-level lock-order edges observed (held -> acquired).
    pub edges: Vec<(&'static str, &'static str)>,
    pub aborted: bool,
}

// ---------------------------------------------------------------------------
// Kernel state
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Grant {
    Proceed,
    /// Session aborted: fall back to plain `std` behavior.
    Freed,
    RecvData,
    RecvClosed,
    TryData,
    TryEmpty,
    TryClosed,
}

struct Gate {
    slot: StdMutex<Option<Grant>>,
    cv: StdCondvar,
}

impl Gate {
    fn new() -> Self {
        Self { slot: StdMutex::new(None), cv: StdCondvar::new() }
    }

    fn park(&self) -> Grant {
        let mut slot = self.slot.lock().expect("racecheck gate poisoned");
        loop {
            if let Some(g) = slot.take() {
                return g;
            }
            slot = self.cv.wait(slot).expect("racecheck gate poisoned");
        }
    }

    fn open(&self, g: Grant) {
        *self.slot.lock().expect("racecheck gate poisoned") = Some(g);
        self.cv.notify_one();
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Op {
    Begin,
    Lock(u32),
    Send(u32),
    Recv(u32),
    TryRecv(u32),
    NotifyOne(u32),
    NotifyAll(u32),
    Join(usize),
    CvWait { cv: u32, m: u32 },
}

enum ThState {
    Running,
    Decision(Op),
    CvWaiting { cv: u32, m: u32 },
    Exited,
}

struct Th {
    name: String,
    gate: Arc<Gate>,
    state: ThState,
    /// Virtually held mutexes (for lock-order edges and diagnostics).
    held: Vec<u32>,
    /// Already probed-and-re-parked in the current wait episode.
    probed: bool,
    /// Set when probe-woken: the cv to compare the thread's next visible
    /// op against (re-wait on the same cv = benign; anything else = lost
    /// wakeup).
    probe_watch: Option<u32>,
}

enum ObjKind {
    Mutex { holder: Option<usize>, class: &'static str },
    Cv,
    Chan { len: usize, senders: usize },
}

struct Obj {
    label: String,
    kind: ObjKind,
}

#[derive(Default)]
struct Chooser {
    tape: Vec<u32>,
    rng: Option<Rng>,
    options: Vec<u32>,
    taken: Vec<u32>,
}

impl Chooser {
    /// Pick one of `n` options. Only real branch points (n >= 2) consume
    /// tape and are recorded.
    fn choose(&mut self, n: u32) -> u32 {
        if n <= 1 {
            return 0;
        }
        let pos = self.taken.len();
        let c = if pos < self.tape.len() {
            self.tape[pos].min(n - 1)
        } else if let Some(r) = &mut self.rng {
            r.below(n as u64) as u32
        } else {
            0
        };
        self.options.push(n);
        self.taken.push(c);
        c
    }
}

struct Kernel {
    threads: Vec<Th>,
    objs: Vec<Obj>,
    class_counts: BTreeMap<&'static str, usize>,
    chooser: Chooser,
    running: Option<usize>,
    live: usize,
    steps: usize,
    step_cap: usize,
    events: Vec<Event>,
    edges: BTreeSet<(&'static str, &'static str)>,
}

pub(crate) struct Session {
    kernel: StdMutex<Kernel>,
    /// Coordinator wakeup: signaled whenever `running` drops to `None`.
    wake: StdCondvar,
    aborted: AtomicBool,
}

/// Per-thread handle to a session; installed in TLS by [`run_checked`].
pub(crate) struct ThreadCtl {
    sess: Arc<Session>,
    tid: usize,
    gate: Arc<Gate>,
}

thread_local! {
    static CTL: RefCell<Option<Arc<ThreadCtl>>> = const { RefCell::new(None) };
}

fn cur() -> Option<Arc<ThreadCtl>> {
    CTL.with(|c| c.borrow().clone())
}

/// Current thread is checked and its session is still live.
fn with_ctl() -> Option<Arc<ThreadCtl>> {
    let ctl = cur()?;
    if ctl.sess.aborted.load(Ordering::Acquire) {
        None
    } else {
        Some(ctl)
    }
}

enum Reg {
    Mutex(&'static str),
    Cv,
    Chan,
}

fn reg_obj(k: &mut Kernel, vid: &OnceLock<u32>, class: &'static str, reg: Reg) -> u32 {
    if let Some(&id) = vid.get() {
        return id;
    }
    let n = k.class_counts.entry(class).or_insert(0);
    let label = format!("{class}#{n}");
    *n += 1;
    let id = k.objs.len() as u32;
    let kind = match reg {
        Reg::Mutex(c) => ObjKind::Mutex { holder: None, class: c },
        Reg::Cv => ObjKind::Cv,
        Reg::Chan => ObjKind::Chan { len: 0, senders: 1 },
    };
    k.objs.push(Obj { label, kind });
    let _ = vid.set(id);
    id
}

impl ThreadCtl {
    pub(crate) fn tid(&self) -> usize {
        self.tid
    }

    fn kernel(&self) -> std::sync::MutexGuard<'_, Kernel> {
        self.sess.kernel.lock().expect("racecheck kernel poisoned")
    }

    fn register(&self, vid: &OnceLock<u32>, class: &'static str, reg: Reg) -> u32 {
        reg_obj(&mut self.kernel(), vid, class, reg)
    }

    /// Post a visible op, hand control to the coordinator, park until
    /// granted (or freed by an abort).
    fn decide(&self, op: Op) -> Grant {
        {
            let mut k = self.kernel();
            if self.sess.aborted.load(Ordering::Acquire) {
                return Grant::Freed;
            }
            // Probe-watch observation: a probe-woken waiter that does
            // anything but re-park on the same cv had a true predicate
            // while parked — a lost wakeup.
            if let Some(watch) = k.threads[self.tid].probe_watch.take() {
                let benign = matches!(op, Op::CvWait { cv, .. } if cv == watch);
                if benign {
                    k.threads[self.tid].probed = true;
                } else {
                    let ev = Event::LostWakeup {
                        thread: k.threads[self.tid].name.clone(),
                        cv: k.objs[watch as usize].label.clone(),
                    };
                    k.events.push(ev);
                }
            }
            k.threads[self.tid].state = match op {
                Op::CvWait { cv, m } => ThState::CvWaiting { cv, m },
                _ => ThState::Decision(op),
            };
            k.running = None;
            self.sess.wake.notify_one();
        }
        self.gate.park()
    }
}

// ---------------------------------------------------------------------------
// Hooks called by util::sync (all no-ops on unchecked threads)
// ---------------------------------------------------------------------------

pub(crate) fn on_lock(vid: &OnceLock<u32>, class: &'static str) {
    let Some(ctl) = with_ctl() else { return };
    let id = ctl.register(vid, class, Reg::Mutex(class));
    let _ = ctl.decide(Op::Lock(id));
}

/// Eager release (not a schedule point: releases only enable others).
pub(crate) fn on_unlock(vid: &OnceLock<u32>) {
    let Some(ctl) = with_ctl() else { return };
    let Some(&id) = vid.get() else { return };
    let mut k = ctl.kernel();
    if let ObjKind::Mutex { holder, .. } = &mut k.objs[id as usize].kind {
        if *holder == Some(ctl.tid) {
            *holder = None;
            k.threads[ctl.tid].held.retain(|&h| h != id);
        }
    }
}

/// True iff the virtual condvar protocol should be used for a wait.
pub(crate) fn virtual_wait_applicable() -> bool {
    with_ctl().is_some()
}

/// Park on `cv` having already released mutex `m`; returns once a notify
/// (or the quiescence probe) woke this thread *and* the virtual lock on
/// `m` was re-granted. The caller then re-acquires the `std` mutex raw.
pub(crate) fn on_cv_wait(vid: &OnceLock<u32>, class: &'static str, m: u32) {
    let Some(ctl) = with_ctl() else { return };
    let cv = ctl.register(vid, class, Reg::Cv);
    let _ = ctl.decide(Op::CvWait { cv, m });
}

pub(crate) fn on_notify(vid: &OnceLock<u32>, class: &'static str, all: bool) {
    let Some(ctl) = with_ctl() else { return };
    let cv = ctl.register(vid, class, Reg::Cv);
    let _ = ctl.decide(if all { Op::NotifyAll(cv) } else { Op::NotifyOne(cv) });
}

pub(crate) fn on_send(vid: &OnceLock<u32>, class: &'static str) {
    let Some(ctl) = with_ctl() else { return };
    let id = ctl.register(vid, class, Reg::Chan);
    let _ = ctl.decide(Op::Send(id));
}

/// The `std` send failed (receiver gone): retract the queue increment.
pub(crate) fn on_send_failed(vid: &OnceLock<u32>) {
    let Some(ctl) = with_ctl() else { return };
    let Some(&id) = vid.get() else { return };
    let mut k = ctl.kernel();
    if let ObjKind::Chan { len, .. } = &mut k.objs[id as usize].kind {
        *len = len.saturating_sub(1);
    }
}

pub(crate) fn on_sender_clone(vid: &OnceLock<u32>, class: &'static str) {
    let Some(ctl) = with_ctl() else { return };
    let id = ctl.register(vid, class, Reg::Chan);
    let mut k = ctl.kernel();
    if let ObjKind::Chan { senders, .. } = &mut k.objs[id as usize].kind {
        *senders += 1;
    }
}

/// Eager sender-count decrement (can only enable receivers).
pub(crate) fn on_sender_drop(vid: &OnceLock<u32>, class: &'static str) {
    let Some(ctl) = with_ctl() else { return };
    let id = ctl.register(vid, class, Reg::Chan);
    let mut k = ctl.kernel();
    if let ObjKind::Chan { senders, .. } = &mut k.objs[id as usize].kind {
        *senders = senders.saturating_sub(1);
    }
}

pub(crate) enum RecvGrant {
    Std,
    Data,
    Closed,
}

pub(crate) fn on_recv(vid: &OnceLock<u32>, class: &'static str) -> RecvGrant {
    let Some(ctl) = with_ctl() else { return RecvGrant::Std };
    let id = ctl.register(vid, class, Reg::Chan);
    match ctl.decide(Op::Recv(id)) {
        Grant::RecvData => RecvGrant::Data,
        Grant::RecvClosed => RecvGrant::Closed,
        _ => RecvGrant::Std,
    }
}

pub(crate) enum TryGrant {
    Std,
    Data,
    Empty,
    Closed,
}

pub(crate) fn on_try_recv(vid: &OnceLock<u32>, class: &'static str) -> TryGrant {
    let Some(ctl) = with_ctl() else { return TryGrant::Std };
    let id = ctl.register(vid, class, Reg::Chan);
    match ctl.decide(Op::TryRecv(id)) {
        Grant::TryData => TryGrant::Data,
        Grant::TryEmpty => TryGrant::Empty,
        Grant::TryClosed => TryGrant::Closed,
        _ => TryGrant::Std,
    }
}

pub(crate) fn on_join(tid: usize) {
    let Some(ctl) = with_ctl() else { return };
    let _ = ctl.decide(Op::Join(tid));
}

/// Register a child thread of the current checked thread. `None` when the
/// spawner is unchecked (or the session aborted): spawn plain.
pub(crate) fn spawn_ctl(name: String) -> Option<Arc<ThreadCtl>> {
    let ctl = with_ctl()?;
    let mut k = ctl.kernel();
    let tid = k.threads.len();
    let gate = Arc::new(Gate::new());
    k.threads.push(Th {
        name,
        gate: gate.clone(),
        state: ThState::Decision(Op::Begin),
        held: Vec::new(),
        probed: false,
        probe_watch: None,
    });
    k.live += 1;
    Some(Arc::new(ThreadCtl { sess: ctl.sess.clone(), tid, gate }))
}

/// Thread body wrapper for checked threads: installs the control block,
/// waits for the Begin grant, runs `f`, and reports the exit (with the
/// panic message, if any) to the kernel before unwinding onward.
pub(crate) fn run_checked<F, T>(ctl: Arc<ThreadCtl>, f: F) -> T
where
    F: FnOnce() -> T,
{
    enter(ctl);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    exit_current(res.as_ref().err().map(|p| panic_msg(&**p)));
    match res {
        Ok(v) => v,
        Err(p) => std::panic::resume_unwind(p),
    }
}

fn enter(ctl: Arc<ThreadCtl>) {
    let gate = ctl.gate.clone();
    CTL.with(|c| *c.borrow_mut() = Some(ctl));
    let _ = gate.park(); // Begin grant (or Freed)
}

fn exit_current(panic: Option<String>) {
    let Some(ctl) = CTL.with(|c| c.borrow_mut().take()) else { return };
    if ctl.sess.aborted.load(Ordering::Acquire) {
        return;
    }
    let mut k = ctl.kernel();
    if let Some(watch) = k.threads[ctl.tid].probe_watch.take() {
        let ev = Event::LostWakeup {
            thread: k.threads[ctl.tid].name.clone(),
            cv: k.objs[watch as usize].label.clone(),
        };
        k.events.push(ev);
    }
    if let Some(msg) = panic {
        let ev = Event::Panic { thread: k.threads[ctl.tid].name.clone(), msg };
        k.events.push(ev);
    }
    k.threads[ctl.tid].state = ThState::Exited;
    k.live -= 1;
    k.running = None;
    ctl.sess.wake.notify_one();
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic".to_string()
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

fn op_enabled(k: &Kernel, op: Op) -> bool {
    match op {
        Op::Begin | Op::Send(_) | Op::TryRecv(_) | Op::NotifyOne(_) | Op::NotifyAll(_) => true,
        Op::Lock(m) => matches!(&k.objs[m as usize].kind, ObjKind::Mutex { holder: None, .. }),
        Op::Recv(c) => match &k.objs[c as usize].kind {
            ObjKind::Chan { len, senders } => *len > 0 || *senders == 0,
            _ => false,
        },
        Op::Join(t) => matches!(k.threads[t].state, ThState::Exited),
        Op::CvWait { .. } => false, // never posted as a Decision
    }
}

fn mutex_class(k: &Kernel, m: u32) -> &'static str {
    match &k.objs[m as usize].kind {
        ObjKind::Mutex { class, .. } => class,
        _ => "?",
    }
}

fn grant_lock(k: &mut Kernel, t: usize, m: u32) {
    let m_class = mutex_class(k, m);
    let held = k.threads[t].held.clone();
    for h in held {
        let hc = mutex_class(k, h);
        if hc != m_class {
            k.edges.insert((hc, m_class));
        }
    }
    if let ObjKind::Mutex { holder, .. } = &mut k.objs[m as usize].kind {
        *holder = Some(t);
    }
    k.threads[t].held.push(m);
}

fn cv_waiters(k: &Kernel, cv: u32) -> Vec<usize> {
    (0..k.threads.len())
        .filter(|&t| matches!(k.threads[t].state, ThState::CvWaiting { cv: c, .. } if c == cv))
        .collect()
}

fn wake_waiter(k: &mut Kernel, w: usize) {
    let m = match k.threads[w].state {
        ThState::CvWaiting { m, .. } => m,
        _ => unreachable!("waking a thread that is not cv-waiting"),
    };
    k.threads[w].state = ThState::Decision(Op::Lock(m));
    k.threads[w].probed = false;
}

fn apply_op(k: &mut Kernel, t: usize) -> Grant {
    let op = match k.threads[t].state {
        ThState::Decision(op) => op,
        _ => unreachable!("granting a thread without a posted decision"),
    };
    match op {
        Op::Begin | Op::Join(_) => Grant::Proceed,
        Op::Lock(m) => {
            grant_lock(k, t, m);
            Grant::Proceed
        }
        Op::Send(c) => {
            if let ObjKind::Chan { len, .. } = &mut k.objs[c as usize].kind {
                *len += 1;
            }
            Grant::Proceed
        }
        Op::Recv(c) => {
            if let ObjKind::Chan { len, .. } = &mut k.objs[c as usize].kind {
                if *len > 0 {
                    *len -= 1;
                    return Grant::RecvData;
                }
            }
            Grant::RecvClosed
        }
        Op::TryRecv(c) => {
            if let ObjKind::Chan { len, senders } = &mut k.objs[c as usize].kind {
                if *len > 0 {
                    *len -= 1;
                    Grant::TryData
                } else if *senders == 0 {
                    Grant::TryClosed
                } else {
                    Grant::TryEmpty
                }
            } else {
                Grant::TryEmpty
            }
        }
        Op::NotifyOne(cv) => {
            let ws = cv_waiters(k, cv);
            if !ws.is_empty() {
                let i = k.chooser.choose(ws.len() as u32) as usize;
                wake_waiter(k, ws[i]);
            }
            Grant::Proceed
        }
        Op::NotifyAll(cv) => {
            for w in cv_waiters(k, cv) {
                wake_waiter(k, w);
            }
            Grant::Proceed
        }
        Op::CvWait { .. } => unreachable!("cv-wait is never granted as a decision"),
    }
}

fn describe_blocked(k: &Kernel) -> String {
    let mut parts = Vec::new();
    for th in &k.threads {
        let desc = match &th.state {
            ThState::Exited => continue,
            ThState::Running => "running".to_string(),
            ThState::CvWaiting { cv, .. } => {
                format!("waiting on {} (wakeups exhausted)", k.objs[*cv as usize].label)
            }
            ThState::Decision(op) => match op {
                Op::Lock(m) => format!("blocked locking {}", k.objs[*m as usize].label),
                Op::Recv(c) => format!("blocked receiving on {}", k.objs[*c as usize].label),
                Op::Join(t) => format!("joining {}", k.threads[*t].name),
                other => format!("at {other:?}"),
            },
        };
        let held = if th.held.is_empty() {
            String::new()
        } else {
            let names: Vec<&str> =
                th.held.iter().map(|&h| k.objs[h as usize].label.as_str()).collect();
            format!(" [holds {}]", names.join(", "))
        };
        parts.push(format!("{}: {desc}{held}", th.name));
    }
    parts.join("; ")
}

fn abort_locked(sess: &Session, k: &mut Kernel) {
    sess.aborted.store(true, Ordering::Release);
    for th in &k.threads {
        if !matches!(th.state, ThState::Exited) {
            th.gate.open(Grant::Freed);
        }
    }
}

fn coordinate(sess: &Arc<Session>) {
    let mut k = sess.kernel.lock().expect("racecheck kernel poisoned");
    loop {
        // Wait for the granted thread to come back to a schedule point.
        while k.running.is_some() {
            let (guard, timeout) = sess
                .wake
                .wait_timeout(k, WATCHDOG)
                .expect("racecheck kernel poisoned");
            k = guard;
            if timeout.timed_out() && k.running.is_some() {
                k.events.push(Event::Stalled);
                abort_locked(sess, &mut k);
                return;
            }
        }
        if k.live == 0 {
            return;
        }
        if k.steps >= k.step_cap {
            let steps = k.steps;
            k.events.push(Event::StepLimit { steps });
            abort_locked(sess, &mut k);
            return;
        }
        let enabled: Vec<usize> = (0..k.threads.len())
            .filter(|&t| match k.threads[t].state {
                ThState::Decision(op) => op_enabled(&k, op),
                _ => false,
            })
            .collect();
        if enabled.is_empty() {
            // Quiescence: deliver a spurious wake to an unprobed waiter
            // (deterministic: lowest tid), else it is a deadlock.
            let probe = (0..k.threads.len()).find(|&t| {
                matches!(k.threads[t].state, ThState::CvWaiting { .. }) && !k.threads[t].probed
            });
            if let Some(t) = probe {
                let cv = match k.threads[t].state {
                    ThState::CvWaiting { cv, .. } => cv,
                    _ => unreachable!(),
                };
                let m = match k.threads[t].state {
                    ThState::CvWaiting { m, .. } => m,
                    _ => unreachable!(),
                };
                k.threads[t].state = ThState::Decision(Op::Lock(m));
                k.threads[t].probe_watch = Some(cv);
                continue;
            }
            let detail = describe_blocked(&k);
            k.events.push(Event::Deadlock { detail });
            abort_locked(sess, &mut k);
            return;
        }
        let pick = enabled[k.chooser.choose(enabled.len() as u32) as usize];
        let grant = apply_op(&mut k, pick);
        k.threads[pick].state = ThState::Running;
        k.running = Some(pick);
        k.steps += 1;
        let gate = k.threads[pick].gate.clone();
        gate.open(grant);
    }
}

// ---------------------------------------------------------------------------
// Execution driver
// ---------------------------------------------------------------------------

/// Run `body` as the main thread of one checked execution under `cfg`'s
/// decision tape. The body's `Vec<u64>` return value is the scenario
/// digest used by the non-determinism detector.
pub fn run_execution<F>(body: F, cfg: ExecConfig) -> ExecReport
where
    F: FnOnce() -> Vec<u64> + Send + 'static,
{
    let sess = Arc::new(Session {
        kernel: StdMutex::new(Kernel {
            threads: Vec::new(),
            objs: Vec::new(),
            class_counts: BTreeMap::new(),
            chooser: Chooser {
                tape: cfg.tape,
                rng: cfg.rng_seed.map(Rng::new),
                options: Vec::new(),
                taken: Vec::new(),
            },
            running: None,
            live: 0,
            steps: 0,
            step_cap: cfg.step_cap,
            events: Vec::new(),
            edges: BTreeSet::new(),
        }),
        wake: StdCondvar::new(),
        aborted: AtomicBool::new(false),
    });

    let gate = Arc::new(Gate::new());
    {
        let mut k = sess.kernel.lock().expect("racecheck kernel poisoned");
        k.threads.push(Th {
            name: "main".to_string(),
            gate: gate.clone(),
            state: ThState::Decision(Op::Begin),
            held: Vec::new(),
            probed: false,
            probe_watch: None,
        });
        k.live = 1;
    }
    let ctl = Arc::new(ThreadCtl { sess: sess.clone(), tid: 0, gate });

    let handle = std::thread::Builder::new()
        .name("racecheck-main".to_string())
        .spawn(move || run_checked(ctl, body))
        .expect("spawn racecheck main thread");

    coordinate(&sess);

    let aborted = sess.aborted.load(Ordering::Acquire);
    let digest = if aborted {
        drop(handle); // leaked/pass-through threads; do not block on them
        None
    } else {
        handle.join().ok()
    };

    let k = sess.kernel.lock().expect("racecheck kernel poisoned");
    ExecReport {
        events: k.events.clone(),
        digest,
        taken: k.chooser.taken.clone(),
        options: k.chooser.options.clone(),
        steps: k.steps,
        edges: k.edges.iter().cloned().collect(),
        aborted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync;
    use std::sync::atomic::AtomicU64;

    fn counter_body() -> Vec<u64> {
        let n = Arc::new(AtomicU64::new(0));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let n = n.clone();
                sync::spawn(move || {
                    n.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join().expect("worker");
        }
        vec![n.load(Ordering::SeqCst)]
    }

    #[test]
    fn clean_execution_completes_with_digest() {
        let r = run_execution(counter_body, ExecConfig::default());
        assert!(r.events.is_empty(), "unexpected events: {:?}", r.events);
        assert_eq!(r.digest, Some(vec![2]));
        assert!(!r.aborted);
        assert!(r.steps > 0);
    }

    #[test]
    fn same_tape_same_schedule() {
        let a = run_execution(counter_body, ExecConfig::default());
        let b = run_execution(
            counter_body,
            ExecConfig { tape: a.taken.clone(), ..ExecConfig::default() },
        );
        assert_eq!(a.taken, b.taken);
        assert_eq!(a.options, b.options);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.digest, b.digest);
    }

    #[test]
    fn channel_cycle_is_a_deadlock() {
        let r = run_execution(
            || {
                let (tx_a, rx_a) = sync::channel_named::<u8>("test.a");
                let (tx_b, rx_b) = sync::channel_named::<u8>("test.b");
                let t = sync::Builder::new()
                    .name("peer".to_string())
                    .spawn(move || {
                        let v = rx_b.recv().unwrap_or(0);
                        let _ = tx_a.send(v);
                    })
                    .expect("spawn");
                // Main waits for the peer, the peer waits for main: cycle.
                let v = rx_a.recv().unwrap_or(0);
                let _ = tx_b.send(v);
                let _ = t.join();
                vec![]
            },
            ExecConfig::default(),
        );
        assert!(r.aborted);
        assert!(
            r.events.iter().any(|e| matches!(e, Event::Deadlock { .. })),
            "expected deadlock, got {:?}",
            r.events
        );
    }

    #[test]
    fn mutex_handoff_and_lock_edges() {
        let r = run_execution(
            || {
                let a = Arc::new(sync::Mutex::named(0u64, "test.outer"));
                let b = Arc::new(sync::Mutex::named(0u64, "test.inner"));
                let (a2, b2) = (a.clone(), b.clone());
                let t = sync::spawn(move || {
                    let mut ga = a2.lock().expect("outer");
                    let mut gb = b2.lock().expect("inner");
                    *ga += 1;
                    *gb += 2;
                });
                t.join().expect("worker");
                let va = *a.lock().expect("outer");
                let vb = *b.lock().expect("inner");
                vec![va, vb]
            },
            ExecConfig::default(),
        );
        assert!(r.events.is_empty(), "unexpected events: {:?}", r.events);
        assert_eq!(r.digest, Some(vec![1, 2]));
        assert!(r.edges.contains(&("test.outer", "test.inner")), "edges: {:?}", r.edges);
    }
}
