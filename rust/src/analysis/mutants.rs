//! Seeded-mutant suite: proof that the verifier catches what it claims
//! to catch.
//!
//! Each mutant wraps the tracing fabric in a [`MutantComm`] that injects
//! exactly one schedule bug on rank 0 — drop the n-th send, shift its
//! tag, truncate its payload, or leak an in-flight request — and the
//! suite asserts the analyses flag it with the *right* diagnostic class.
//! A verifier that passes its clean matrix but misses a seeded mutant is
//! worse than no verifier, so `commcheck` (CLI and CI) runs this suite
//! alongside the clean sweep.

use super::trace::run_traced;
use super::{
    dense_exact_diags, structural_diags, tag_lint, CheckKind, Diagnostic, RankOut, ScheduleId,
    TAG_SPACING,
};
use crate::compress::EfState;
use crate::mpisim::CommOps;
use std::collections::BTreeSet;

/// One injected schedule bug. `nth` counts the affected operation on the
/// mutated rank (rank 0), so each mutant is deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// Silently skip the n-th send — the classic lost-message deadlock.
    DropSend { nth: usize },
    /// Send the n-th message under `tag + delta` instead of `tag`. A
    /// small delta mismatches within the family (deadlock/misroute); a
    /// delta of many [`TAG_SPACING`] windows lands in undeclared tag
    /// space (tag-window lint).
    ShiftTag { nth: usize, delta: u64 },
    /// Truncate the n-th send's payload to half its length — the
    /// mismatched-count bug MPI hides until the buffers disagree.
    TruncateChunk { nth: usize },
    /// Drop one pending request out of the n-th `wait_any` set — the
    /// leaked-`Request` bug the PR 3 slot-reclamation fix closed.
    LeakRequest { nth: usize },
}

/// A [`CommOps`] fabric that forwards to `inner`, injecting `mutation`
/// into this rank's operation stream. Generic over the fabric, so the
/// same wrapper can corrupt a traced run (here) or a live mpisim run.
pub struct MutantComm<'a, C: CommOps> {
    inner: &'a mut C,
    mutation: Option<Mutation>,
    sends: usize,
    waits: usize,
}

impl<'a, C: CommOps> MutantComm<'a, C> {
    pub fn new(inner: &'a mut C, mutation: Option<Mutation>) -> Self {
        Self { inner, mutation, sends: 0, waits: 0 }
    }
}

impl<C: CommOps> CommOps for MutantComm<'_, C> {
    type Req = C::Req;

    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) {
        let n = self.sends;
        self.sends += 1;
        match self.mutation {
            Some(Mutation::DropSend { nth }) if n == nth => {}
            Some(Mutation::ShiftTag { nth, delta }) if n == nth => {
                self.inner.send(to, tag.wrapping_add(delta), data)
            }
            Some(Mutation::TruncateChunk { nth }) if n == nth => {
                let keep = data.len() / 2;
                self.inner.send(to, tag, data[..keep].to_vec())
            }
            _ => self.inner.send(to, tag, data),
        }
    }

    fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        self.inner.recv(from, tag)
    }

    fn irecv(&mut self, from: usize, tag: u64) -> C::Req {
        self.inner.irecv(from, tag)
    }

    fn wait(&mut self, req: C::Req) -> Vec<f32> {
        self.inner.wait(req)
    }

    fn wait_any(&mut self, reqs: &mut Vec<C::Req>) -> (usize, Vec<f32>) {
        if let Some(Mutation::LeakRequest { nth }) = self.mutation {
            let n = self.waits;
            self.waits += 1;
            if n == nth && reqs.len() > 1 {
                // Drop a pending request on the floor; its Drop impl
                // takes the MPI_Cancel path the verifier must flag.
                let _leaked = reqs.remove(0);
            }
        }
        self.inner.wait_any(reqs)
    }
}

/// One seeded mutant: a (schedule, world, bug) triple and the diagnostic
/// classes that count as catching it.
pub struct MutantCase {
    pub label: &'static str,
    pub schedule: ScheduleId,
    pub p: usize,
    pub chunks: usize,
    pub mutation: Mutation,
    /// Catching = at least one diagnostic of one of these kinds.
    pub expected: &'static [CheckKind],
}

/// The verdict for one mutant after running the analyses over its trace.
pub struct MutantOutcome {
    pub label: &'static str,
    pub expected: &'static [CheckKind],
    pub found: Vec<CheckKind>,
    pub caught: bool,
}

/// The seeded bug classes from the issue — drop a send, shift a tag
/// (both within-family and into undeclared space), truncate a chunk,
/// leak a request — across ring and halving-doubling worlds.
pub fn seeded_mutants() -> Vec<MutantCase> {
    vec![
        MutantCase {
            label: "ring/drop-send",
            schedule: ScheduleId::Ring { rings: 1 },
            p: 4,
            chunks: 1,
            mutation: Mutation::DropSend { nth: 1 },
            expected: &[CheckKind::Deadlock],
        },
        MutantCase {
            label: "hd/drop-send",
            schedule: ScheduleId::HalvingDoubling,
            p: 4,
            chunks: 2,
            mutation: Mutation::DropSend { nth: 2 },
            expected: &[CheckKind::Deadlock],
        },
        MutantCase {
            label: "two-tier/drop-local-bcast",
            schedule: ScheduleId::TwoTier { devices: 2 },
            p: 4,
            chunks: 1,
            // Rank 0 leads clique {0, 1}: it sends nothing during the
            // device gather, one subset-RS and one subset-AG message in
            // the 2-leader ring (sends 0 and 1), then the DEV_BCAST leg
            // back to rank 1 (send 2). Dropping send 2 is exactly "forget
            // the local broadcast": rank 1 never learns the global sum
            // and parks forever on its bcast receive.
            mutation: Mutation::DropSend { nth: 2 },
            expected: &[CheckKind::Deadlock],
        },
        MutantCase {
            label: "ring/shift-tag-in-family",
            schedule: ScheduleId::Ring { rings: 1 },
            p: 4,
            chunks: 1,
            // +3 stays inside the ring family but matches a receive
            // posted for a different step: misroute or deadlock.
            mutation: Mutation::ShiftTag { nth: 1, delta: 3 },
            expected: &[CheckKind::Deadlock, CheckKind::Coverage, CheckKind::UnmatchedSend],
        },
        MutantCase {
            label: "ring/shift-tag-out-of-family",
            schedule: ScheduleId::Ring { rings: 1 },
            p: 4,
            chunks: 1,
            // 42 windows away: undeclared tag space — the lint must fire
            // even though the run also wedges.
            mutation: Mutation::ShiftTag { nth: 1, delta: 42 * TAG_SPACING },
            expected: &[CheckKind::TagWindow],
        },
        MutantCase {
            label: "ring/truncate-chunk",
            schedule: ScheduleId::Ring { rings: 1 },
            p: 4,
            chunks: 1,
            mutation: Mutation::TruncateChunk { nth: 0 },
            expected: &[CheckKind::Coverage, CheckKind::Panic],
        },
        MutantCase {
            label: "ring/leak-request",
            schedule: ScheduleId::Ring { rings: 1 },
            p: 4,
            chunks: 2,
            mutation: Mutation::LeakRequest { nth: 1 },
            expected: &[CheckKind::LeakedRequest, CheckKind::Deadlock],
        },
    ]
}

/// Run one mutant and collect the diagnostic kinds the analyses emit.
pub fn run_mutant(case: &MutantCase) -> MutantOutcome {
    let len = 2 * case.p + 3;
    let lens = case.schedule.buf_lens(len);
    let run = run_traced(case.p, |c| {
        let rank = c.rank();
        let mutation = if rank == 0 { Some(case.mutation) } else { None };
        let mut off = 0usize;
        let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(lens.len());
        for &l in &lens {
            bufs.push((0..l).map(|i| super::weighted(rank, off + i)).collect());
            off += l;
        }
        let mut ef = EfState::new();
        let mut mc = MutantComm::new(c, mutation);
        case.schedule.run(&mut mc, &mut bufs, case.chunks, &mut ef);
        RankOut { bufs, residuals: Vec::new() }
    });
    let mut diags: Vec<Diagnostic> = Vec::new();
    diags.extend(structural_diags(&case.schedule, case.p, case.chunks, len, &run));
    diags.extend(tag_lint(&case.schedule, case.p, case.chunks, len, &run.events));
    if run.deadlock.is_none() && run.panics.is_empty() && run.results.iter().all(|r| r.is_some())
    {
        diags.extend(dense_exact_diags(&case.schedule, case.p, case.chunks, len, &lens, &run));
    }
    let found: Vec<CheckKind> = diags
        .iter()
        .map(|d| d.kind)
        .collect::<BTreeSet<_>>()
        .into_iter()
        .collect();
    let caught = case.expected.iter().any(|k| found.contains(k));
    MutantOutcome { label: case.label, expected: case.expected, found, caught }
}

/// Run the full suite. The gate fails unless *every* mutant is caught.
pub fn run_mutant_suite() -> Vec<MutantOutcome> {
    seeded_mutants().iter().map(run_mutant).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_seeded_mutant_is_caught() {
        for outcome in run_mutant_suite() {
            assert!(
                outcome.caught,
                "mutant {} escaped: expected one of {:?}, found {:?}",
                outcome.label, outcome.expected, outcome.found
            );
        }
    }

    #[test]
    fn mutant_free_wrapper_is_transparent() {
        // A MutantComm with no mutation must not change the schedule:
        // the clean config check still passes through the wrapper.
        let id = ScheduleId::Ring { rings: 1 };
        let run = run_traced(3, |c| {
            let mut bufs = vec![(0..9).map(|i| super::super::weighted(c.rank(), i)).collect()];
            let mut ef = EfState::new();
            let mut mc = MutantComm::new(c, None);
            id.run(&mut mc, &mut bufs, 2, &mut ef);
            RankOut { bufs, residuals: Vec::new() }
        });
        assert!(run.clean());
        assert!(dense_exact_diags(&id, 3, 2, 9, &[9], &run).is_empty());
    }
}
