//! The tracing communicator: runs a real schedule, records every
//! communication event, and detects deadlocks instead of hanging.
//!
//! [`TraceComm`] implements [`CommOps`], so any generic schedule function
//! from [`crate::collectives`] runs against it unmodified. Payloads do
//! move (schedules slice and fold real buffers), but everything routes
//! through one central [`TraceHub`] that keeps, per rank: the unexpected
//! -message queue, the posted-receive slab with MPI posting-order
//! matching, the event log, and — the part the real fabric cannot give
//! us — a **blocked registry**. Sends are eager (buffered, like
//! [`crate::mpisim`]), so the moment every live rank is parked in a
//! `wait`/`wait_any` whose slots are all unfilled, no future send can
//! ever occur and the state is a proven deadlock: the hub poisons
//! itself, every parked thread unwinds with a [`DeadlockMark`] panic
//! (silenced by a scoped panic hook), and [`run_traced`] reports the
//! cross-rank wait-for edges instead of hanging CI.
//!
//! Dropped-but-armed receive requests take the `MPI_Cancel` path exactly
//! like [`crate::mpisim::Request`]: the drop is recorded as a
//! [`TraceEvent::Cancel`] so the verifier can flag leaked requests — the
//! static twin of the slot-reclamation regression test.

use crate::mpisim::CommOps;
use std::cell::Cell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One recorded communication event on a rank's timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    /// This rank sent `len` elements to `to` under `tag`.
    Send { to: usize, tag: u64, len: usize },
    /// This rank completed a receive of `len` elements from `from`.
    Recv { from: usize, tag: u64, len: usize },
    /// A receive request for `(from, tag)` was dropped while still armed
    /// (the MPI_Cancel path) — a leaked request.
    Cancel { from: usize, tag: u64 },
}

/// One blocked receive at deadlock time: `rank` waits on `(from, tag)`.
/// The set of edges is the cross-rank wait-for graph restricted to the
/// final (stuck) state; every edge is unsatisfiable by construction.
#[derive(Debug, Clone)]
pub struct WaitEdge {
    pub rank: usize,
    pub from: usize,
    pub tag: u64,
}

/// Panic payload used to unwind parked threads once the hub is poisoned.
/// Carried through `catch_unwind` and recognized by [`run_traced`]; the
/// scoped panic hook keeps it off stderr.
struct DeadlockMark;

struct MailMsg {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

struct PostedRec {
    from: usize,
    tag: u64,
    data: Option<Vec<f32>>,
    seq: u64,
}

struct HubState {
    /// Per-destination unexpected-message queues, arrival order.
    mail: Vec<Vec<MailMsg>>,
    /// Per-rank posted-receive slabs (`None` = consumed slot).
    posted: Vec<Vec<Option<PostedRec>>>,
    post_seq: Vec<u64>,
    /// Slots each rank is currently parked on (`None` = running).
    blocked: Vec<Option<Vec<usize>>>,
    done: Vec<bool>,
    poisoned: bool,
    deadlock: Option<Vec<WaitEdge>>,
    events: Vec<Vec<TraceEvent>>,
}

/// Central mailbox + blocked registry shared by every [`TraceComm`] of a
/// traced world.
pub struct TraceHub {
    m: Mutex<HubState>,
    cv: Condvar,
}

impl TraceHub {
    fn new(size: usize) -> Arc<Self> {
        Arc::new(Self {
            m: Mutex::new(HubState {
                mail: (0..size).map(|_| Vec::new()).collect(),
                posted: (0..size).map(|_| Vec::new()).collect(),
                post_seq: vec![0; size],
                blocked: (0..size).map(|_| None).collect(),
                done: vec![false; size],
                poisoned: false,
                deadlock: None,
                events: (0..size).map(|_| Vec::new()).collect(),
            }),
            cv: Condvar::new(),
        })
    }

    /// Deadlock iff some rank is still live and every live rank is parked
    /// on receives that are all unfilled: sends are eager, so a state in
    /// which nobody can run is a state in which nobody will ever run.
    fn deadlock_check(st: &mut HubState) {
        if st.poisoned {
            return;
        }
        let live: Vec<usize> = (0..st.done.len()).filter(|&r| !st.done[r]).collect();
        if live.is_empty() {
            return;
        }
        let stuck = live.iter().all(|&r| match &st.blocked[r] {
            None => false,
            Some(slots) => slots.iter().all(|&s| {
                st.posted[r][s]
                    .as_ref()
                    .map(|p| p.data.is_none())
                    .unwrap_or(false)
            }),
        });
        if stuck {
            let mut edges = Vec::new();
            for &r in &live {
                for &s in st.blocked[r].as_ref().expect("stuck rank is blocked") {
                    if let Some(p) = &st.posted[r][s] {
                        edges.push(WaitEdge { rank: r, from: p.from, tag: p.tag });
                    }
                }
            }
            st.deadlock = Some(edges);
            st.poisoned = true;
        }
    }

    fn send(&self, from: usize, to: usize, tag: u64, data: Vec<f32>) {
        let mut st = self.m.lock().expect("trace hub poisoned by panic");
        st.events[from].push(TraceEvent::Send { to, tag, len: data.len() });
        // Earliest-posted matching receive wins (MPI's matching rule).
        let target = st.posted[to]
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.as_ref().map(|p| (i, p)))
            .filter(|(_, p)| p.data.is_none() && p.from == from && p.tag == tag)
            .min_by_key(|(_, p)| p.seq)
            .map(|(i, _)| i);
        match target {
            Some(i) => st.posted[to][i].as_mut().expect("matched slot").data = Some(data),
            None => st.mail[to].push(MailMsg { from, tag, data }),
        }
        self.cv.notify_all();
    }

    fn post_recv(&self, rank: usize, from: usize, tag: u64) -> usize {
        let mut st = self.m.lock().expect("trace hub poisoned by panic");
        // Unexpected queue first, in arrival order.
        let data = st.mail[rank]
            .iter()
            .position(|m| m.from == from && m.tag == tag)
            .map(|pos| st.mail[rank].remove(pos).data);
        let seq = st.post_seq[rank];
        st.post_seq[rank] += 1;
        st.posted[rank].push(Some(PostedRec { from, tag, data, seq }));
        st.posted[rank].len() - 1
    }

    /// Park until one of `slots` has data; returns (position-in-`slots`,
    /// payload). Panics with [`DeadlockMark`] if the hub poisons while
    /// parked.
    fn wait_any_slots(&self, rank: usize, slots: &[usize]) -> (usize, Vec<f32>) {
        let mut st = self.m.lock().expect("trace hub poisoned by panic");
        loop {
            if st.poisoned {
                panic::panic_any(DeadlockMark);
            }
            let ready = slots.iter().position(|&s| {
                st.posted[rank][s]
                    .as_ref()
                    .map(|p| p.data.is_some())
                    .unwrap_or(false)
            });
            if let Some(i) = ready {
                let rec = st.posted[rank][slots[i]].take().expect("ready slot");
                let data = rec.data.expect("ready slot has data");
                st.events[rank].push(TraceEvent::Recv {
                    from: rec.from,
                    tag: rec.tag,
                    len: data.len(),
                });
                st.blocked[rank] = None;
                return (i, data);
            }
            st.blocked[rank] = Some(slots.to_vec());
            Self::deadlock_check(&mut st);
            if st.poisoned {
                self.cv.notify_all();
                panic::panic_any(DeadlockMark);
            }
            st = self.cv.wait(st).expect("trace hub poisoned by panic");
        }
    }

    /// The MPI_Cancel drop path: withdraw a still-armed receive and log
    /// it as a leaked request (secondary cancels during deadlock
    /// unwinding are not logged — the deadlock is the diagnosis).
    fn cancel(&self, rank: usize, slot: usize) {
        let mut st = self.m.lock().expect("trace hub poisoned by panic");
        if st.poisoned {
            st.posted[rank][slot] = None;
            return;
        }
        if let Some(p) = st.posted[rank][slot].take() {
            st.events[rank].push(TraceEvent::Cancel { from: p.from, tag: p.tag });
        }
    }

    fn mark_done(&self, rank: usize) {
        let mut st = self.m.lock().expect("trace hub poisoned by panic");
        st.done[rank] = true;
        st.blocked[rank] = None;
        Self::deadlock_check(&mut st);
        self.cv.notify_all();
    }
}

/// One rank's endpoint of a traced world. Implements [`CommOps`], so the
/// generic schedule functions run against it exactly as against the real
/// [`crate::mpisim::Comm`].
pub struct TraceComm {
    rank: usize,
    size: usize,
    hub: Arc<TraceHub>,
}

/// Request handle of the traced fabric. Dropping it while armed records
/// a [`TraceEvent::Cancel`] — the leaked-request verifier rule.
pub struct TraceReq {
    slot: usize,
    armed: bool,
    rank: usize,
    hub: Arc<TraceHub>,
}

impl Drop for TraceReq {
    fn drop(&mut self) {
        if self.armed {
            self.hub.cancel(self.rank, self.slot);
        }
    }
}

impl CommOps for TraceComm {
    type Req = TraceReq;

    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) {
        self.hub.send(self.rank, to, tag, data);
    }

    fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        let req = self.irecv(from, tag);
        self.wait(req)
    }

    fn irecv(&mut self, from: usize, tag: u64) -> TraceReq {
        TraceReq {
            slot: self.hub.post_recv(self.rank, from, tag),
            armed: true,
            rank: self.rank,
            hub: self.hub.clone(),
        }
    }

    fn wait(&mut self, mut req: TraceReq) -> Vec<f32> {
        req.armed = false;
        let (_, data) = self.hub.wait_any_slots(self.rank, &[req.slot]);
        data
    }

    fn wait_any(&mut self, reqs: &mut Vec<TraceReq>) -> (usize, Vec<f32>) {
        assert!(!reqs.is_empty(), "wait_any on no requests");
        let slots: Vec<usize> = reqs.iter().map(|r| r.slot).collect();
        let (i, data) = self.hub.wait_any_slots(self.rank, &slots);
        let mut req = reqs.remove(i);
        req.armed = false;
        (i, data)
    }
}

/// Everything captured by one traced run.
pub struct TraceRun<R> {
    /// Per-rank closure results; `None` where the rank panicked (or was
    /// unwound by deadlock poisoning).
    pub results: Vec<Option<R>>,
    /// Per-rank event timelines (sends, completed receives, cancels).
    pub events: Vec<Vec<TraceEvent>>,
    /// The stuck wait-for edges, when the run deadlocked.
    pub deadlock: Option<Vec<WaitEdge>>,
    /// Sends that no receive ever consumed: (from, to, tag, len).
    pub unmatched_sends: Vec<(usize, usize, u64, usize)>,
    /// Receive requests dropped while armed: (rank, from, tag).
    pub leaked: Vec<(usize, usize, u64)>,
    /// Non-deadlock panics: (rank, message).
    pub panics: Vec<(usize, String)>,
}

impl<R> TraceRun<R> {
    /// True when the schedule ran to completion with nothing left over:
    /// no deadlock, no panic, no leaked request, no unmatched send.
    pub fn clean(&self) -> bool {
        self.deadlock.is_none()
            && self.panics.is_empty()
            && self.leaked.is_empty()
            && self.unmatched_sends.is_empty()
    }
}

thread_local! {
    static COMMCHECK_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Silence panics on commcheck worker threads (deadlock unwinding and
/// seeded-mutant crashes are *expected* there and reported as
/// diagnostics); every other thread keeps the previous hook. Installed
/// once per process.
fn install_silent_hook() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if COMMCHECK_WORKER.with(|w| w.get()) {
                return;
            }
            prev(info);
        }));
    });
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f` once per rank of a `p`-rank traced world and collect the
/// per-rank timelines plus every teardown finding. Deadlocks terminate
/// (poison + unwind) instead of hanging, which is what makes the traced
/// interpreter usable as a CI gate.
pub fn run_traced<R, F>(p: usize, f: F) -> TraceRun<R>
where
    R: Send,
    F: Fn(&mut TraceComm) -> R + Sync,
{
    assert!(p > 0);
    install_silent_hook();
    let hub = TraceHub::new(p);
    let mut results: Vec<Option<R>> = Vec::with_capacity(p);
    let mut panics = Vec::new();
    let outcomes: Vec<Result<R, Box<dyn std::any::Any + Send>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let hub = hub.clone();
                let f = &f;
                s.spawn(move || {
                    COMMCHECK_WORKER.with(|w| w.set(true));
                    let mut comm = TraceComm { rank, size: p, hub: hub.clone() };
                    let out = panic::catch_unwind(AssertUnwindSafe(|| f(&mut comm)));
                    // Dropping `comm`'s outstanding requests happened
                    // during unwinding; only now is the rank done.
                    hub.mark_done(rank);
                    COMMCHECK_WORKER.with(|w| w.set(false));
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("commcheck worker died outside catch_unwind"))
            .collect()
    });
    for (rank, out) in outcomes.into_iter().enumerate() {
        match out {
            Ok(r) => results.push(Some(r)),
            Err(payload) => {
                results.push(None);
                if !payload.is::<DeadlockMark>() {
                    panics.push((rank, panic_message(payload.as_ref())));
                }
            }
        }
    }
    let st = hub.m.lock().expect("trace hub poisoned by panic");
    let events = st.events.clone();
    let deadlock = st.deadlock.clone();
    let mut unmatched_sends = Vec::new();
    for (to, mail) in st.mail.iter().enumerate() {
        for m in mail {
            unmatched_sends.push((m.from, to, m.tag, m.data.len()));
        }
    }
    let mut leaked = Vec::new();
    for (rank, evs) in events.iter().enumerate() {
        for ev in evs {
            if let TraceEvent::Cancel { from, tag } = ev {
                leaked.push((rank, *from, *tag));
            }
        }
    }
    drop(st);
    TraceRun { results, events, deadlock, unmatched_sends, leaked, panics }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_exchange_traces_events() {
        let run = run_traced(2, |c| {
            if c.rank() == 0 {
                c.send(1, 7, vec![1.0, 2.0]);
                Vec::new()
            } else {
                c.recv(0, 7)
            }
        });
        assert!(run.clean());
        assert_eq!(run.results[1], Some(vec![1.0, 2.0]));
        assert_eq!(run.events[0], vec![TraceEvent::Send { to: 1, tag: 7, len: 2 }]);
        assert_eq!(run.events[1], vec![TraceEvent::Recv { from: 0, tag: 7, len: 2 }]);
    }

    #[test]
    fn missing_send_is_reported_as_deadlock_not_hang() {
        let run = run_traced(2, |c| {
            if c.rank() == 1 {
                let _ = c.recv(0, 9); // nobody ever sends tag 9
            }
        });
        let edges = run.deadlock.expect("deadlock detected");
        assert!(edges.iter().any(|e| e.rank == 1 && e.from == 0 && e.tag == 9));
        assert!(run.results[1].is_none());
        assert!(run.panics.is_empty(), "deadlock marks are not panics");
    }

    #[test]
    fn cross_wait_cycle_detected() {
        // 0 waits on 1 and 1 waits on 0, nobody sends first: a 2-cycle.
        let run = run_traced(2, |c| {
            let from = 1 - c.rank();
            let _ = c.recv(from, 5);
        });
        let edges = run.deadlock.expect("cycle detected");
        assert_eq!(edges.len(), 2);
    }

    #[test]
    fn unconsumed_send_is_unmatched() {
        let run = run_traced(2, |c| {
            if c.rank() == 0 {
                c.send(1, 3, vec![1.0]);
            }
        });
        assert!(run.deadlock.is_none());
        assert_eq!(run.unmatched_sends, vec![(0, 1, 3, 1)]);
    }

    #[test]
    fn dropped_armed_request_is_leaked() {
        let run = run_traced(2, |c| {
            if c.rank() == 0 {
                c.send(1, 4, vec![2.0]);
            } else {
                let req = c.irecv(0, 4);
                drop(req); // armed: MPI_Cancel path
            }
        });
        assert!(run.leaked.iter().any(|&(r, f, t)| (r, f, t) == (1, 0, 4)));
    }

    #[test]
    fn worker_panic_is_captured() {
        let run = run_traced(2, |c| {
            if c.rank() == 1 {
                panic!("seeded crash");
            }
        });
        assert_eq!(run.panics.len(), 1);
        assert!(run.panics[0].1.contains("seeded crash"));
        assert!(run.results[0].is_some());
    }

    #[test]
    fn posting_order_matching_matches_mpisim() {
        let run = run_traced(2, |c| {
            if c.rank() == 0 {
                c.send(1, 9, vec![1.0]);
                c.send(1, 9, vec![2.0]);
                Vec::new()
            } else {
                let r1 = c.irecv(0, 9);
                let r2 = c.irecv(0, 9);
                let second = c.wait(r2);
                let first = c.wait(r1);
                vec![first[0], second[0]]
            }
        });
        assert_eq!(run.results[1], Some(vec![1.0, 2.0]));
    }
}
