//! `commcheck` — the static communication-schedule verifier (the CI gate
//! behind `mxnet-mpi commcheck`).
//!
//! Every correctness claim the collective plane makes rests on dynamic
//! tests of a nondeterministic message-passing system. This module checks
//! the same claims *symbolically*: each registered schedule runs against
//! the tracing fabric of [`trace`] — every `(src, dst, tag, len)` event
//! captured per rank, deadlocks detected instead of hung — and the
//! captured traces are fed through four analyses:
//!
//! 1. **Deadlock / structural** — the cross-rank wait-for graph over
//!    blocking `recv`/`wait` edges: cycles and unsatisfiable waits are
//!    reported with their stuck edges; unmatched sends and leaked
//!    (dropped-while-armed) `Request`s each get their own rule, pinning
//!    the PR 3 slot-reclamation behavior statically.
//! 2. **Tag-window lint** — an independent model of each schedule's tag
//!    layout (family base + `steps × chunks` budget, the contract behind
//!    [`crate::collectives::TAG_SPACING`]) is checked against every traced
//!    tag: no event may leave its declared family, exceed the window
//!    budget, or set the mpisim collective bit. The runtime side of the
//!    same contract is the checked clamp in `clamp_pipeline_chunks`.
//! 3. **Coverage / conservation** — element provenance. A weighted run
//!    (rank r contributes `r·1000 + i` at element i; all sums are exact
//!    in f32) must produce the exact per-element total on every rank;
//!    per-source indicator runs (rank j contributes all-ones, others
//!    zero) prove each rank's contribution reaches every rank *exactly
//!    once* — 0 = dropped, ≥2 = duplicated. Lossy codecs are checked by
//!    cross-rank bitwise agreement plus the error-feedback conservation
//!    law `Σ inputs = result + Σ residuals`. Length mismatches surface
//!    here too (the traced fabric moves real payloads, so a truncated
//!    chunk either garbles sums or panics a `copy_from_slice`).
//! 4. **Elastic-epoch safety** — exhaustive small-world model checking of
//!    `FaultPlan` × `ElasticHub` in [`elastic`], including the
//!    negative-color `Comm::split` rule.
//! 5. **Cluster-pool conservation** — the multi-job view: deterministic
//!    cluster sims under both allocation policies, checking the integer
//!    node-pool ledger, feeding every *synthesized* churn plan back
//!    through the single-job elastic model check, and holding the
//!    authority to its own width trajectory ([`elastic::check_cluster`]).
//!
//! The verifier is itself verified: [`mutants`] injects schedule bugs
//! (drop a send, shift a tag, truncate a chunk, leak a request) and the
//! test suite asserts each one is caught with the right diagnostic.

pub mod elastic;
pub mod mutants;
pub mod racecheck;
pub mod sched;
pub mod trace;

use crate::collectives::{
    self, compressed_allreduce, fused_allreduce_compressed, fusion_buckets,
    halving_doubling_allreduce_pipelined, hierarchical_allreduce_pipelined,
    multi_ring_allreduce_pipelined, pow2_floor, two_tier_allreduce_pipelined, AlgoKind,
    DEV_BCAST_TAG, DEV_GATHER_TAG, HD_AG_TAG, HD_FOLD_TAG, HD_RS_TAG, HIER_BCAST_TAG,
    HIER_GATHER_TAG, RING_AG_TAG, RING_RS_TAG, SUBSET_AG_TAG, SUBSET_RS_TAG, TAG_SPACING,
};
use crate::collectives::COMPRESS_TAG;
use crate::compress::{Codec, EfState};
use crate::mpisim::{CommOps, COLL_BIT};
use crate::netsim::CostParams;
use std::collections::BTreeSet;
use std::fmt;
use trace::{run_traced, TraceEvent, TraceRun};

/// The swept rank counts: every small world (2..=9) plus two sizes that
/// exercise the non-power-of-two fold (17) and a deeper power of two (16).
pub const P_SWEEP: &[usize] = &[2, 3, 4, 5, 6, 7, 8, 9, 16, 17];

/// The swept pipeline depths.
pub const CHUNK_SWEEP: &[usize] = &[1, 2, 4, 8];

/// EF-residual key base used by traced compressed runs (bucket `i` of a
/// fused schedule uses `EF_KEY_BASE + start-index`).
const EF_KEY_BASE: u64 = 100;

/// Keep-ratio handed to the `topk` codec when tracing it.
const TOPK_RATIO: f64 = 0.25;

/// Per-(config, rule) cap on emitted diagnostics, so one broken schedule
/// doesn't bury the report. The count of *suppressed* findings is always
/// reported.
const MAX_DIAGS: usize = 4;

/// EF conservation tolerance, relative: f32 error feedback stores
/// `acc − decode(code)`, and `decode + residual` re-rounds, so the books
/// balance only to rounding. Real coverage bugs lose whole contributions
/// (orders of magnitude above this).
const EF_REL_TOL: f32 = 1e-3;

// ---------------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------------

/// The verifier rule a diagnostic came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CheckKind {
    /// Unsatisfiable cross-rank wait (cycle or missing send).
    Deadlock,
    /// A sent message no receive ever consumed.
    UnmatchedSend,
    /// A receive request dropped while armed (MPI_Cancel leak).
    LeakedRequest,
    /// A tag outside its declared family window or budget.
    TagWindow,
    /// An element contribution dropped, duplicated, or garbled.
    Coverage,
    /// A rank panicked mid-schedule (e.g. a length-mismatched copy).
    Panic,
    /// A traced event targets a rank the fault plan killed, or an
    /// `ElasticHub` epoch table violates a membership invariant.
    ElasticEpoch,
    /// A `Comm::split` outcome disagrees with the group-translation rule.
    SplitRule,
    /// The bucket issue plan is non-deterministic or overlapping.
    EngineDag,
    /// A key no bucket covers: its `Pending` var would never be signaled.
    PendingVar,
    /// The cluster authority broke the node-pool ledger, synthesized an
    /// invalid churn plan, or diverged from its own width trajectory.
    ClusterPool,
}

impl CheckKind {
    pub fn name(&self) -> &'static str {
        match self {
            CheckKind::Deadlock => "deadlock",
            CheckKind::UnmatchedSend => "unmatched-send",
            CheckKind::LeakedRequest => "leaked-request",
            CheckKind::TagWindow => "tag-window",
            CheckKind::Coverage => "coverage",
            CheckKind::Panic => "panic",
            CheckKind::ElasticEpoch => "elastic-epoch",
            CheckKind::SplitRule => "split-rule",
            CheckKind::EngineDag => "engine-dag",
            CheckKind::PendingVar => "pending-var",
            CheckKind::ClusterPool => "cluster-pool",
        }
    }
}

/// One verifier finding, tied to the configuration that produced it.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Schedule name (or pseudo-schedule: "elastic", "engine-plan").
    pub schedule: String,
    pub p: usize,
    pub chunks: usize,
    pub len: usize,
    pub kind: CheckKind,
    pub detail: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] {} (p={}, chunks={}, len={}): {}",
            self.kind.name(),
            self.schedule,
            self.p,
            self.chunks,
            self.len,
            self.detail
        )
    }
}

/// Aggregated verifier result: configuration count plus every finding.
#[derive(Debug, Default)]
pub struct Report {
    pub configs_checked: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn merge(&mut self, other: Report) {
        self.configs_checked += other.configs_checked;
        self.diagnostics.extend(other.diagnostics);
    }
}

// ---------------------------------------------------------------------------
// Schedule registry
// ---------------------------------------------------------------------------

/// Every collective schedule the verifier knows how to drive — the
/// checkable counterpart of [`AlgoKind`] plus the compression and fusion
/// planes. Each variant is a concrete, parameterized schedule; the
/// registry enumerates the instances the CI gate sweeps.
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleId {
    /// Bucket multi-ring (§6.2/§6.3.2); `rings == 1` is the plain ring.
    Ring { rings: usize },
    /// Recursive vector halving-doubling with non-power-of-two fold-in.
    HalvingDoubling,
    /// Two-level hierarchical: group gather → leader subset ring → bcast.
    Hierarchical { group: usize },
    /// Two-tier device allreduce: ranks are device-ranks, `devices` per
    /// node — intra-node gather onto the node leader, subset ring over
    /// leaders, leader broadcast (DEV tag families).
    TwoTier { devices: usize },
    /// Error-feedback compressed allgather-reduce (identity delegates to
    /// the dense ring, bitwise).
    Compressed { codec: Codec },
    /// Gradient-fusion bucketing over three buffers, compressed per
    /// bucket.
    FusedBuckets { fusion_bytes: usize, codec: Codec },
}

impl ScheduleId {
    /// Every schedule instance the CI gate verifies: the three dense
    /// schedules (ring twice — single and multi-ring — and two
    /// hierarchical group sizes) plus the compressed and fused planes
    /// under every registered codec.
    pub fn registry() -> Vec<ScheduleId> {
        let mut out = vec![
            ScheduleId::Ring { rings: 1 },
            ScheduleId::Ring { rings: 2 },
            ScheduleId::HalvingDoubling,
            ScheduleId::Hierarchical { group: 2 },
            ScheduleId::Hierarchical { group: 3 },
            ScheduleId::TwoTier { devices: 2 },
            ScheduleId::TwoTier { devices: 3 },
            ScheduleId::TwoTier { devices: 4 },
        ];
        for codec in Codec::all() {
            out.push(ScheduleId::Compressed { codec });
            out.push(ScheduleId::FusedBuckets { fusion_bytes: 64, codec });
        }
        out
    }

    pub fn name(&self) -> String {
        match self {
            ScheduleId::Ring { rings } => format!("ring[x{rings}]"),
            ScheduleId::HalvingDoubling => "halving_doubling".to_string(),
            ScheduleId::Hierarchical { group } => format!("hierarchical[g{group}]"),
            ScheduleId::TwoTier { devices } => format!("two_tier[k{devices}]"),
            ScheduleId::Compressed { codec } => format!("compressed[{}]", codec.name()),
            ScheduleId::FusedBuckets { fusion_bytes, codec } => {
                format!("fused[{}B,{}]", fusion_bytes, codec.name())
            }
        }
    }

    /// True when the schedule's wire payloads are lossy-coded, so exact
    /// per-element provenance is replaced by the EF conservation law.
    pub fn is_lossy(&self) -> bool {
        match self {
            ScheduleId::Compressed { codec } | ScheduleId::FusedBuckets { codec, .. } => {
                !codec.is_identity()
            }
            _ => false,
        }
    }

    /// Buffer lengths for a traced run parameterized by the base `len`.
    /// Fused schedules carry three buffers so the bucketing logic (merge
    /// vs own-bucket) actually executes.
    pub fn buf_lens(&self, len: usize) -> Vec<usize> {
        match self {
            ScheduleId::FusedBuckets { .. } => vec![len, (len / 2).max(1), len + 3],
            _ => vec![len],
        }
    }

    /// Run this schedule on `comm` over `bufs` (one buffer per entry of
    /// [`Self::buf_lens`]). Works on any [`CommOps`] fabric — the real
    /// mpisim, the tracing fabric, or a mutant wrapper.
    pub fn run<C: CommOps>(&self, comm: &mut C, bufs: &mut [Vec<f32>], chunks: usize, ef: &mut EfState) {
        match self {
            ScheduleId::Ring { rings } => {
                multi_ring_allreduce_pipelined(comm, &mut bufs[0], *rings, chunks)
            }
            ScheduleId::HalvingDoubling => {
                halving_doubling_allreduce_pipelined(comm, &mut bufs[0], chunks)
            }
            ScheduleId::Hierarchical { group } => {
                hierarchical_allreduce_pipelined(comm, &mut bufs[0], *group, chunks)
            }
            ScheduleId::TwoTier { devices } => {
                two_tier_allreduce_pipelined(comm, &mut bufs[0], *devices, chunks)
            }
            ScheduleId::Compressed { codec } => {
                let mut params = CostParams::testbed1();
                params.pipeline_chunks = chunks;
                let boxed = codec.build(TOPK_RATIO);
                compressed_allreduce(
                    AlgoKind::Ring,
                    comm,
                    &mut bufs[0],
                    boxed.as_ref(),
                    EF_KEY_BASE,
                    ef,
                    1,
                    2,
                    &params,
                );
            }
            ScheduleId::FusedBuckets { fusion_bytes, codec } => {
                let mut params = CostParams::testbed1();
                params.pipeline_chunks = chunks;
                let ef_keys: Vec<u64> =
                    (0..bufs.len()).map(|i| EF_KEY_BASE + i as u64).collect();
                let boxed = codec.build(TOPK_RATIO);
                fused_allreduce_compressed(
                    AlgoKind::Ring,
                    comm,
                    bufs,
                    &ef_keys,
                    *fusion_bytes,
                    boxed.as_ref(),
                    ef,
                    1,
                    2,
                    &params,
                );
            }
        }
    }

    /// The schedule's declared tag families: `(base, budget)` windows an
    /// event tag must fall in. This is an *independent* model of the tag
    /// layout (recomputed from the schedule's step structure, not read
    /// back from the code under test) — the lint proves the traced tags
    /// match it.
    fn tag_families(&self, p: usize, chunks: usize, len: usize) -> Vec<Family> {
        match self {
            ScheduleId::Ring { .. } => ring_families(p, chunks),
            ScheduleId::HalvingDoubling => {
                let q = pow2_floor(p);
                let tz = (q.trailing_zeros() as u64).max(1);
                let k = clamp_model(chunks, 2 * tz as usize);
                let mut fams = vec![
                    Family { base: HD_RS_TAG, budget: tz * k, name: "hd-rs" },
                    // The AG step counter continues from the RS phase, so
                    // its offsets live in [tz·k, 2·tz·k).
                    Family { base: HD_AG_TAG, budget: 2 * tz * k, name: "hd-ag" },
                ];
                if p != q {
                    fams.push(Family { base: HD_FOLD_TAG, budget: 2, name: "hd-fold" });
                }
                fams
            }
            ScheduleId::Hierarchical { group } => {
                let g = (*group).clamp(1, p);
                let kh = clamp_model(chunks.min(len.max(1)), 1);
                let mut fams = vec![
                    Family { base: HIER_GATHER_TAG, budget: kh, name: "hier-gather" },
                    Family { base: HIER_BCAST_TAG, budget: kh, name: "hier-bcast" },
                ];
                let leaders = p.div_ceil(g);
                if leaders > 1 {
                    let ks = clamp_model(chunks, leaders - 1);
                    let budget = (leaders - 1) as u64 * ks;
                    fams.push(Family { base: SUBSET_RS_TAG, budget, name: "subset-rs" });
                    fams.push(Family { base: SUBSET_AG_TAG, budget, name: "subset-ag" });
                }
                fams
            }
            ScheduleId::TwoTier { devices } => {
                // Same step structure as `Hierarchical` (one shared state
                // machine), modeled independently here with the device
                // clique in place of the host group and the DEV tag bases.
                let d = (*devices).clamp(1, p);
                let kh = clamp_model(chunks.min(len.max(1)), 1);
                let mut fams = vec![
                    Family { base: DEV_GATHER_TAG, budget: kh, name: "dev-gather" },
                    Family { base: DEV_BCAST_TAG, budget: kh, name: "dev-bcast" },
                ];
                let leaders = p.div_ceil(d);
                if leaders > 1 {
                    let ks = clamp_model(chunks, leaders - 1);
                    let budget = (leaders - 1) as u64 * ks;
                    fams.push(Family { base: SUBSET_RS_TAG, budget, name: "subset-rs" });
                    fams.push(Family { base: SUBSET_AG_TAG, budget, name: "subset-ag" });
                }
                fams
            }
            ScheduleId::Compressed { codec } | ScheduleId::FusedBuckets { codec, .. } => {
                if codec.is_identity() {
                    // Identity codecs delegate to the dense ring path.
                    ring_families(p, chunks)
                } else {
                    vec![Family { base: COMPRESS_TAG, budget: 1, name: "compress" }]
                }
            }
        }
    }
}

/// One declared tag window: tags must satisfy
/// `base <= tag < base + budget` (and `budget <= TAG_SPACING`).
struct Family {
    base: u64,
    budget: u64,
    name: &'static str,
}

/// The lint's own copy of the pipeline-depth clamp (pure — no logging, no
/// assert): `min(requested, TAG_SPACING / steps)`, at least 1.
fn clamp_model(requested: usize, steps: usize) -> u64 {
    let limit = (TAG_SPACING as usize / steps.max(1)).max(1);
    requested.max(1).min(limit) as u64
}

fn ring_families(p: usize, chunks: usize) -> Vec<Family> {
    let steps = p.saturating_sub(1).max(1);
    let budget = steps as u64 * clamp_model(chunks, steps);
    vec![
        Family { base: RING_RS_TAG, budget, name: "ring-rs" },
        Family { base: RING_AG_TAG, budget, name: "ring-ag" },
    ]
}

// ---------------------------------------------------------------------------
// Per-configuration checking
// ---------------------------------------------------------------------------

/// What each traced rank returns: its final buffers plus, for lossy runs,
/// the EF residual of every bucket (keyed by bucket-start buffer index).
pub struct RankOut {
    pub bufs: Vec<Vec<f32>>,
    pub residuals: Vec<(usize, Option<Vec<f32>>)>,
}

/// Buffer lengths swept per (schedule, p, chunks) configuration: one
/// shorter than the chunk count (degenerate/empty sub-chunks) and one
/// with an awkward remainder.
pub fn lens_for(p: usize) -> [usize; 2] {
    [(p - 1).max(1), 2 * p + 3]
}

/// The weighted provenance payload: rank `r`'s element at flattened
/// index `g` is `r·1000 + g`. Integer-valued and small enough that every
/// partial sum is exact in f32 (p ≤ 17, len ≤ 41 ⇒ sums < 2^24), so a
/// correct allreduce must reproduce the closed-form total *bitwise*.
fn weighted(rank: usize, g: usize) -> f32 {
    (rank * 1000 + g) as f32
}

fn weighted_total(p: usize, g: usize) -> f32 {
    (1000 * (p * (p - 1) / 2) + p * g) as f32
}

/// Trace one (schedule, p, chunks) configuration and run the structural,
/// tag, and coverage analyses over it.
pub fn check_config(id: &ScheduleId, p: usize, chunks: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for len in lens_for(p) {
        let lens = id.buf_lens(len);
        let run = run_traced(p, |c| {
            let rank = c.rank();
            let mut off = 0usize;
            let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(lens.len());
            for &l in &lens {
                bufs.push((0..l).map(|i| weighted(rank, off + i)).collect());
                off += l;
            }
            let mut ef = EfState::new();
            id.run(c, &mut bufs, chunks, &mut ef);
            let residuals = collect_residuals(id, &lens, &ef);
            RankOut { bufs, residuals }
        });
        out.extend(structural_diags(id, p, chunks, len, &run));
        out.extend(tag_lint(id, p, chunks, len, &run.events));
        if run.clean() && run.results.iter().all(|r| r.is_some()) {
            if id.is_lossy() {
                out.extend(lossy_diags(id, p, chunks, len, &lens, &run));
            } else {
                out.extend(dense_exact_diags(id, p, chunks, len, &lens, &run));
            }
        }
    }
    // Per-source indicator passes: exact single-contribution provenance,
    // dense schedules on the exhaustive small worlds.
    if !id.is_lossy() && p <= 9 {
        out.extend(indicator_diags(id, p, chunks));
    }
    out
}

fn collect_residuals(id: &ScheduleId, lens: &[usize], ef: &EfState) -> Vec<(usize, Option<Vec<f32>>)> {
    match id {
        ScheduleId::Compressed { codec } if !codec.is_identity() => {
            vec![(0, ef.residual(EF_KEY_BASE).map(|r| r.to_vec()))]
        }
        ScheduleId::FusedBuckets { fusion_bytes, codec } if !codec.is_identity() => {
            fusion_buckets(lens, *fusion_bytes)
                .into_iter()
                .map(|(i, _)| (i, ef.residual(EF_KEY_BASE + i as u64).map(|r| r.to_vec())))
                .collect()
        }
        _ => Vec::new(),
    }
}

/// Deadlocks, panics, leaked requests, unmatched sends — the wait-for
/// graph analysis plus the teardown rules.
fn structural_diags<R>(
    id: &ScheduleId,
    p: usize,
    chunks: usize,
    len: usize,
    run: &TraceRun<R>,
) -> Vec<Diagnostic> {
    let diag = |kind: CheckKind, detail: String| Diagnostic {
        schedule: id.name(),
        p,
        chunks,
        len,
        kind,
        detail,
    };
    let mut out = Vec::new();
    if let Some(edges) = &run.deadlock {
        let shown: Vec<String> = edges
            .iter()
            .take(6)
            .map(|e| format!("rank {} waits on (src={}, tag={:#x})", e.rank, e.from, e.tag))
            .collect();
        let more = edges.len().saturating_sub(6);
        let suffix = if more > 0 { format!(" (+{more} more edges)") } else { String::new() };
        out.push(diag(
            CheckKind::Deadlock,
            format!("unsatisfiable wait-for graph: {}{}", shown.join("; "), suffix),
        ));
    }
    for (rank, msg) in run.panics.iter().take(MAX_DIAGS) {
        out.push(diag(CheckKind::Panic, format!("rank {rank} panicked: {msg}")));
    }
    if run.panics.len() > MAX_DIAGS {
        out.push(diag(
            CheckKind::Panic,
            format!("{} further rank panics suppressed", run.panics.len() - MAX_DIAGS),
        ));
    }
    // Leaked requests are only a finding of their own outside a deadlock:
    // poisoning unwinds every parked rank, dropping its still-armed
    // requests as a side effect of the deadlock already reported.
    if run.deadlock.is_none() {
        for (rank, from, tag) in run.leaked.iter().take(MAX_DIAGS) {
            out.push(diag(
                CheckKind::LeakedRequest,
                format!("rank {rank} dropped an armed receive for (src={from}, tag={tag:#x})"),
            ));
        }
        if run.leaked.len() > MAX_DIAGS {
            out.push(diag(
                CheckKind::LeakedRequest,
                format!("{} further leaked requests suppressed", run.leaked.len() - MAX_DIAGS),
            ));
        }
    }
    for (from, to, tag, mlen) in run.unmatched_sends.iter().take(MAX_DIAGS) {
        out.push(diag(
            CheckKind::UnmatchedSend,
            format!("send {from} -> {to} (tag={tag:#x}, len={mlen}) was never received"),
        ));
    }
    if run.unmatched_sends.len() > MAX_DIAGS {
        out.push(diag(
            CheckKind::UnmatchedSend,
            format!(
                "{} further unmatched sends suppressed",
                run.unmatched_sends.len() - MAX_DIAGS
            ),
        ));
    }
    out
}

/// The tag-window lint: every traced tag must sit inside a declared
/// family window and inside that family's `steps × chunks` budget, and
/// must not set the mpisim collective bit.
fn tag_lint(
    id: &ScheduleId,
    p: usize,
    chunks: usize,
    len: usize,
    events: &[Vec<TraceEvent>],
) -> Vec<Diagnostic> {
    let families = id.tag_families(p, chunks, len);
    let mut offenders: BTreeSet<u64> = BTreeSet::new();
    let mut details: Vec<String> = Vec::new();
    for evs in events {
        for ev in evs {
            let tag = match ev {
                TraceEvent::Send { tag, .. } | TraceEvent::Recv { tag, .. } => *tag,
                TraceEvent::Cancel { .. } => continue,
            };
            if offenders.contains(&tag) {
                continue;
            }
            if tag & COLL_BIT != 0 {
                offenders.insert(tag);
                details.push(format!("tag {tag:#x} sets the mpisim collective bit"));
                continue;
            }
            match families.iter().find(|f| tag >= f.base && tag < f.base + TAG_SPACING) {
                None => {
                    offenders.insert(tag);
                    details.push(format!(
                        "tag {tag:#x} lies outside every declared family window of {}",
                        id.name()
                    ));
                }
                Some(f) if tag - f.base >= f.budget => {
                    offenders.insert(tag);
                    details.push(format!(
                        "tag {:#x} exceeds the {} budget: offset {} >= {}",
                        tag,
                        f.name,
                        tag - f.base,
                        f.budget
                    ));
                }
                Some(_) => {}
            }
        }
    }
    let total = details.len();
    let mut out: Vec<Diagnostic> = details
        .into_iter()
        .take(MAX_DIAGS)
        .map(|detail| Diagnostic {
            schedule: id.name(),
            p,
            chunks,
            len,
            kind: CheckKind::TagWindow,
            detail,
        })
        .collect();
    if total > MAX_DIAGS {
        out.push(Diagnostic {
            schedule: id.name(),
            p,
            chunks,
            len,
            kind: CheckKind::TagWindow,
            detail: format!("{} further tag offenses suppressed", total - MAX_DIAGS),
        });
    }
    out
}

/// Dense conservation: every rank must hold the exact closed-form total
/// of the weighted payloads, with the input length preserved.
fn dense_exact_diags(
    id: &ScheduleId,
    p: usize,
    chunks: usize,
    len: usize,
    lens: &[usize],
    run: &TraceRun<RankOut>,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    'ranks: for (rank, res) in run.results.iter().enumerate() {
        let res = res.as_ref().expect("clean run has results");
        let mut g = 0usize;
        for (b, &l) in lens.iter().enumerate() {
            if res.bufs[b].len() != l {
                out.push(Diagnostic {
                    schedule: id.name(),
                    p,
                    chunks,
                    len,
                    kind: CheckKind::Coverage,
                    detail: format!(
                        "rank {rank} buffer {b}: length {} != input length {l}",
                        res.bufs[b].len()
                    ),
                });
                break 'ranks;
            }
            for (i, &v) in res.bufs[b].iter().enumerate() {
                let want = weighted_total(p, g + i);
                if v != want {
                    out.push(Diagnostic {
                        schedule: id.name(),
                        p,
                        chunks,
                        len,
                        kind: CheckKind::Coverage,
                        detail: format!(
                            "rank {rank} buffer {b} element {i}: got {v}, want exact sum {want} \
                             (some contribution dropped, duplicated, or misrouted)"
                        ),
                    });
                    break 'ranks; // one witness per config is enough
                }
            }
            g += l;
        }
    }
    out
}

/// Lossy conservation: all ranks must agree bitwise (same decoded
/// payloads folded in the same order), and the error-feedback books must
/// balance: `Σ_r input_r = result + Σ_r residual_r` per element.
fn lossy_diags(
    id: &ScheduleId,
    p: usize,
    chunks: usize,
    len: usize,
    lens: &[usize],
    run: &TraceRun<RankOut>,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let diag = |detail: String| Diagnostic {
        schedule: id.name(),
        p,
        chunks,
        len,
        kind: CheckKind::Coverage,
        detail,
    };
    let r0 = run.results[0].as_ref().expect("clean run has results");
    for (rank, res) in run.results.iter().enumerate().skip(1) {
        let res = res.as_ref().expect("clean run has results");
        if res.bufs != r0.bufs {
            out.push(diag(format!(
                "rank {rank} result diverges from rank 0 (lossy decode-reduce must be \
                 bitwise identical across ranks)"
            )));
            return out;
        }
    }
    // Books per element, flattened across buffers: residual vectors are
    // keyed by bucket and cover the bucket's fused span.
    let flat_len: usize = lens.iter().sum();
    let mut result_flat = Vec::with_capacity(flat_len);
    for b in r0.bufs.iter() {
        result_flat.extend_from_slice(b);
    }
    if result_flat.len() != flat_len {
        out.push(diag(format!(
            "result length {} != input length {flat_len}",
            result_flat.len()
        )));
        return out;
    }
    // Per-rank flattened residuals (zero where a bucket has none yet).
    let bucket_spans: Vec<(usize, usize)> = match id {
        ScheduleId::FusedBuckets { fusion_bytes, .. } => fusion_buckets(lens, *fusion_bytes)
            .into_iter()
            .map(|(i, j)| {
                let start: usize = lens[..i].iter().sum();
                let span: usize = lens[i..j].iter().sum();
                (start, span)
            })
            .collect(),
        _ => vec![(0, flat_len)],
    };
    let mut residual_sum = vec![0.0f32; flat_len];
    for (rank, res) in run.results.iter().enumerate() {
        let res = res.as_ref().expect("clean run has results");
        if res.residuals.len() != bucket_spans.len() {
            out.push(diag(format!(
                "rank {rank}: {} EF residual buckets recorded, schedule has {}",
                res.residuals.len(),
                bucket_spans.len()
            )));
            return out;
        }
        for ((_, residual), &(start, span)) in res.residuals.iter().zip(&bucket_spans) {
            match residual {
                None => {
                    out.push(diag(format!(
                        "rank {rank}: no EF residual recorded for the bucket at offset {start} \
                         (the codec never ran over it)"
                    )));
                    return out;
                }
                Some(r) if r.len() != span => {
                    out.push(diag(format!(
                        "rank {rank}: EF residual length {} != bucket span {span}",
                        r.len()
                    )));
                    return out;
                }
                Some(r) => {
                    for (i, &v) in r.iter().enumerate() {
                        residual_sum[start + i] += v;
                    }
                }
            }
        }
    }
    for g in 0..flat_len {
        let inputs: f32 = (0..p).map(|r| weighted(r, g)).sum();
        let books = result_flat[g] + residual_sum[g];
        let err = (inputs - books).abs();
        if err > EF_REL_TOL * inputs.abs().max(1.0) {
            out.push(diag(format!(
                "EF conservation violated at element {g}: inputs sum to {inputs} but \
                 result + residuals = {books} (err {err:.4}) — mass was dropped or duplicated"
            )));
            return out;
        }
    }
    out
}

/// Per-source indicator passes: rank `src` contributes all-ones, every
/// other rank zero. A correct allreduce leaves exactly 1.0 everywhere on
/// every rank; 0 means `src`'s contribution was dropped at that element,
/// 2 means it was folded twice. Run once per source — the columns of the
/// element-provenance matrix.
fn indicator_diags(id: &ScheduleId, p: usize, chunks: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let len = 2 * p + 3;
    let lens = id.buf_lens(len);
    for src in 0..p {
        let run = run_traced(p, |c| {
            let v = if c.rank() == src { 1.0f32 } else { 0.0 };
            let mut bufs: Vec<Vec<f32>> = lens.iter().map(|&l| vec![v; l]).collect();
            let mut ef = EfState::new();
            id.run(c, &mut bufs, chunks, &mut ef);
            bufs
        });
        if !run.clean() || run.results.iter().any(|r| r.is_none()) {
            // Structural findings were already reported by the weighted
            // pass; just note the provenance pass could not complete.
            out.push(Diagnostic {
                schedule: id.name(),
                p,
                chunks,
                len,
                kind: CheckKind::Coverage,
                detail: format!("indicator pass for source rank {src} did not complete cleanly"),
            });
            continue;
        }
        'ranks: for (rank, bufs) in run.results.iter().enumerate() {
            let bufs = bufs.as_ref().expect("checked above");
            for (b, buf) in bufs.iter().enumerate() {
                for (i, &v) in buf.iter().enumerate() {
                    if v != 1.0 {
                        let what = if v == 0.0 {
                            "dropped"
                        } else if v >= 2.0 {
                            "duplicated"
                        } else {
                            "garbled"
                        };
                        out.push(Diagnostic {
                            schedule: id.name(),
                            p,
                            chunks,
                            len,
                            kind: CheckKind::Coverage,
                            detail: format!(
                                "contribution of rank {src} was {what} at rank {rank} \
                                 buffer {b} element {i} (got {v}, want 1)"
                            ),
                        });
                        break 'ranks;
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Sweeps
// ---------------------------------------------------------------------------

/// The schedule-matrix gate: every registered schedule × [`P_SWEEP`] ×
/// [`CHUNK_SWEEP`], all four trace analyses per cell.
pub fn check_schedules() -> Report {
    let mut report = Report::default();
    for id in ScheduleId::registry() {
        for &p in P_SWEEP {
            for &chunks in CHUNK_SWEEP {
                report.configs_checked += 1;
                report.diagnostics.extend(check_config(&id, p, chunks));
            }
        }
    }
    report
}

/// Engine-DAG checks over the kvstore bucket issue plan: the plan must
/// cover every key exactly once (a missed key is a `Pending` var no
/// engine op ever signals), be identical however often it is recomputed
/// (all ranks derive it independently — divergence deadlocks the
/// collective), and issue disjoint buckets back-to-front (the engine's
/// per-bucket ops then form a forest — acyclic by construction).
pub fn check_engine_plans() -> Report {
    let mut report = Report::default();
    let cases: &[&[usize]] = &[
        &[4, 5, 6],
        &[10],
        &[1, 1, 1, 1, 1, 1, 1],
        &[3, 40, 2, 2, 50, 1],
    ];
    for &lens in cases {
        for &fusion_bytes in &[0usize, 16, 64, 1 << 20] {
            report.configs_checked += 1;
            let diag = |kind: CheckKind, detail: String| Diagnostic {
                schedule: "engine-plan".to_string(),
                p: 0,
                chunks: 0,
                len: lens.len(),
                kind,
                detail,
            };
            let plan = crate::kvstore::bucket_issue_plan(lens, fusion_bytes);
            // Determinism: every rank recomputes the plan independently.
            for _ in 0..2 {
                if crate::kvstore::bucket_issue_plan(lens, fusion_bytes) != plan {
                    report.diagnostics.push(diag(
                        CheckKind::EngineDag,
                        format!("issue plan is non-deterministic (fusion_bytes={fusion_bytes})"),
                    ));
                }
            }
            // Coverage: each key in exactly one bucket.
            let mut hits = vec![0usize; lens.len()];
            for &(i, j) in &plan {
                if i >= j || j > lens.len() {
                    report.diagnostics.push(diag(
                        CheckKind::EngineDag,
                        format!("malformed bucket [{i}, {j}) over {} keys", lens.len()),
                    ));
                    continue;
                }
                for h in hits.iter_mut().take(j).skip(i) {
                    *h += 1;
                }
            }
            for (k, &h) in hits.iter().enumerate() {
                if h == 0 {
                    report.diagnostics.push(diag(
                        CheckKind::PendingVar,
                        format!(
                            "key {k} is in no bucket (fusion_bytes={fusion_bytes}): its \
                             Pending var would never be signaled"
                        ),
                    ));
                } else if h > 1 {
                    report.diagnostics.push(diag(
                        CheckKind::EngineDag,
                        format!(
                            "key {k} is in {h} buckets (fusion_bytes={fusion_bytes}): its \
                             engine var would be signaled twice"
                        ),
                    ));
                }
            }
            // Issue order: strictly back-to-front over disjoint ranges,
            // so no issued bucket waits on a later one (acyclicity).
            for w in plan.windows(2) {
                if w[1].1 > w[0].0 {
                    report.diagnostics.push(diag(
                        CheckKind::EngineDag,
                        format!(
                            "issue order not back-to-front: bucket [{}, {}) issued after \
                             [{}, {})",
                            w[1].0, w[1].1, w[0].0, w[0].1
                        ),
                    ));
                }
            }
        }
    }
    report
}

/// Everything `mxnet-mpi commcheck` gates on: the schedule matrix, the
/// engine-plan checks, the exhaustive elastic-epoch model check, and the
/// multi-job cluster-pool check.
pub fn full_report() -> Report {
    let mut report = check_schedules();
    report.merge(check_engine_plans());
    report.merge(elastic::check_elastic());
    report.merge(elastic::check_cluster());
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_family_model_accepts_ring_trace() {
        let id = ScheduleId::Ring { rings: 1 };
        assert!(check_config(&id, 4, 2).is_empty());
    }

    #[test]
    fn two_tier_family_model_accepts_two_tier_trace() {
        // Every device-clique size on a small world, including the
        // degenerate k=1 (all ranks are leaders: pure subset ring) and a
        // ragged last node (p=4, k=3).
        for devices in [1usize, 2, 3, 4] {
            let id = ScheduleId::TwoTier { devices };
            assert!(check_config(&id, 4, 2).is_empty(), "devices={devices}");
        }
    }

    #[test]
    fn weighted_totals_are_exact() {
        // Largest configuration in the sweep: sums must be integers that
        // f32 holds exactly (< 2^24).
        let p = 17;
        let len = 2 * p + 3 + (p - 1) + 3; // fused flat length upper bound
        let worst = 1000 * (p * (p - 1) / 2) + p * len;
        assert!(worst < (1 << 24));
        assert_eq!(weighted_total(3, 5), 3015.0);
    }

    #[test]
    fn engine_plan_checks_pass_on_real_plan() {
        assert!(check_engine_plans().ok());
    }
}
