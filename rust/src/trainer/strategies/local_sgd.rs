//! Local SGD with post-local warmup (Stich, arXiv:1805.09767; Lin et al.,
//! arXiv:1808.07217): plain SGD on each client's replica, with **periodic
//! full model averaging** every `INTERVAL` iterations instead of
//! per-iteration gradient exchange — the communication-avoiding family the
//! paper's §7 conclusion points at beyond Elastic SGD.
//!
//! * Between syncs: intra-client sync SGD (the client's live members
//!   average gradients each iteration, keeping replicas in lockstep — the
//!   same discipline ESGD uses), zero PS traffic.
//! * At a sync: every client pushes its replica pre-scaled so the PS's
//!   `Assign` aggregation stores the *global client average*; everyone
//!   adopts it. Synchronous and deterministic — the cross-plane bitwise
//!   property holds.
//! * Post-local warmup (`cfg.warmup_iters`): the first `warmup_iters`
//!   iterations average every iteration (≈ synchronous SGD's trajectory
//!   early, when replicas diverge fastest), then the lazy `INTERVAL`
//!   schedule takes over.
//!
//! A single file + one registration line — no execution-loop edits — is
//! all it took: the proof of the [`SyncStrategy`] seam.

use super::{
    client_local_step, push_pull_model, round_averaged_model, round_local_steps, AlgoEntry,
    Grouping, LockstepRound, SyncStrategy, WorkerInit, WorkerStep,
};
use crate::config::ExperimentConfig;
use crate::optimizer::Assign;
use crate::ps::SyncMode;
use anyhow::Result;

pub struct LocalSgd;

pub(crate) fn register(reg: &mut Vec<AlgoEntry>) {
    reg.push(AlgoEntry {
        name: "local-sgd".to_string(),
        grouping: Grouping::Mpi,
        strategy: &LocalSgd,
        paper_mode: false,
        sync_pattern: "periodic full model averaging every INTERVAL (+ warmup)",
        comm_per_iter: "full model push+pull / INTERVAL (none between syncs)",
        reference: "arXiv:1805.09767 / 1808.07217; paper §7 outlook",
    });
}

impl SyncStrategy for LocalSgd {
    fn server_mode(&self) -> SyncMode {
        SyncMode::Sync
    }

    fn synchronous(&self) -> bool {
        true
    }

    fn local_model(&self) -> bool {
        true
    }

    fn pushes_model(&self) -> bool {
        // PS pushes carry replica snapshots, not gradients: they bypass
        // the lossy gradient codec (see the trait doc).
        true
    }

    fn local_momentum(&self, cfg: &ExperimentConfig) -> f32 {
        // Local SGD carries momentum locally (it is exact within the
        // client group's lockstep replicas).
        cfg.momentum
    }

    fn aggregated_workers(&self, m_live: usize, _live_workers: usize) -> usize {
        // Intra-client gradient averaging every iteration.
        m_live
    }

    fn sync_every(&self, cfg: &ExperimentConfig) -> u64 {
        cfg.interval.max(1) as u64
    }

    fn sync_due(&self, cfg: &ExperimentConfig, iter: u64) -> bool {
        // Post-local warmup: average every iteration first, then lazily.
        iter < cfg.warmup_iters as u64 || crate::trainer::esgd_sync_due(iter, cfg.interval)
    }

    // --- threaded plane ----------------------------------------------------

    fn init(&self, cfg: &ExperimentConfig, ini: &mut WorkerInit<'_>) -> Result<()> {
        // The averaged global model lives on the PS: serverless (pure-MPI)
        // push/pull has no store for it, so a run without servers would
        // silently never synchronize. Fail loudly instead.
        anyhow::ensure!(
            cfg.servers > 0,
            "local-sgd requires at least one PS server (the averaged \
             global model lives on the PS)"
        );
        // Keys hold the global model; the PS only *aggregates* the
        // pre-scaled replica pushes (Assign), so the stored value after a
        // sync round is exactly the global average.
        for (k, part) in ini.init_parts.iter().enumerate() {
            ini.kv.init(k, part.clone(), ini.is_root);
        }
        if ini.is_root {
            ini.kv.set_optimizer(|| Box::new(Assign));
        }
        Ok(())
    }

    fn step(&self, cfg: &ExperimentConfig, st: &mut WorkerStep<'_>) -> Result<()> {
        // Local step on the client replica (intra-client lockstep), then
        // — on sync iterations — the shared pre-scaled model push/pull:
        // the PS's `Assign` stores the global average, and we adopt it.
        client_local_step(st)?;
        if self.sync_due(cfg, st.iter) {
            push_pull_model(st)?;
        }
        Ok(())
    }

    // --- sim plane ---------------------------------------------------------

    fn lockstep_round(
        &self,
        cfg: &ExperimentConfig,
        round: &mut LockstepRound<'_>,
    ) -> Result<()> {
        anyhow::ensure!(
            round.servers > 0,
            "local-sgd requires at least one PS server (the averaged \
             global model lives on the PS)"
        );
        // Local step per live client, then — on sync rounds — the shared
        // wire-mirroring average; every client adopts it.
        round_local_steps(self, cfg, round)?;
        if round.sync_due {
            let avg = round_averaged_model(round);
            *round.server_w = avg;
            for rc in round.clients.iter_mut() {
                rc.w.clone_from(round.server_w);
            }
        }
        Ok(())
    }
}
