//! Elastic Averaging SGD (paper Fig. 8, §5): the server runs `Elastic1`
//! (eq. 2) on pushed *weights*; every `INTERVAL` iterations the worker
//! pushes its params, pulls the centers and applies `Elastic2` (eq. 3);
//! plain SGD locally in between. The first §7 communication-avoiding
//! algorithm — [`bmuf`](super::bmuf) and [`local_sgd`](super::local_sgd)
//! follow the trail it blazed.

use super::{
    client_local_step, local_hyper, push_pull_scaled, AfterCompute, AlgoEntry, EventStep,
    Grouping, SyncStrategy, WorkerInit, WorkerStep,
};
use crate::config::ExperimentConfig;
use crate::optimizer::Elastic1;
use crate::ps::SyncMode;
use anyhow::Result;

pub struct Esgd;

pub(crate) fn register(reg: &mut Vec<AlgoEntry>) {
    for grouping in [Grouping::Dist, Grouping::Mpi] {
        reg.push(AlgoEntry {
            name: format!("{}-ESGD", grouping.name()),
            grouping,
            strategy: &Esgd,
            paper_mode: true,
            sync_pattern: "async elastic averaging every INTERVAL iterations",
            comm_per_iter: "full model (params out, centers back) / INTERVAL",
            reference: "Fig. 8, Figs 13-14",
        });
    }
}

impl SyncStrategy for Esgd {
    fn server_mode(&self) -> SyncMode {
        SyncMode::Async
    }

    fn synchronous(&self) -> bool {
        false
    }

    fn local_model(&self) -> bool {
        true
    }

    fn pushes_model(&self) -> bool {
        // PS pushes carry replica snapshots, not gradients: they bypass
        // the lossy gradient codec (see the trait doc).
        true
    }

    fn aggregated_workers(&self, m_live: usize, _live_workers: usize) -> usize {
        // Intra-client sync SGD between elastic syncs (§5): the client's
        // live members' gradients are averaged every iteration (dist
        // grouping degenerates to m_live == 1).
        m_live
    }

    fn sync_every(&self, cfg: &ExperimentConfig) -> u64 {
        cfg.interval.max(1) as u64
    }

    fn sync_due(&self, cfg: &ExperimentConfig, iter: u64) -> bool {
        crate::trainer::esgd_sync_due(iter, cfg.interval)
    }

    // --- threaded plane ----------------------------------------------------

    fn init(&self, cfg: &ExperimentConfig, ini: &mut WorkerInit<'_>) -> Result<()> {
        // Keys hold center variables (Fig. 8).
        for (k, part) in ini.init_parts.iter().enumerate() {
            ini.kv.init(k, part.clone(), ini.is_root);
        }
        if ini.is_root {
            let alpha = cfg.alpha;
            ini.kv.set_optimizer(move || Box::new(Elastic1 { alpha }));
        }
        Ok(())
    }

    fn step(&self, cfg: &ExperimentConfig, st: &mut WorkerStep<'_>) -> Result<()> {
        // Fig. 8. MPI clients keep replicas in lockstep by averaging
        // gradients inside the client each iteration (sync SGD within the
        // communicator, §5; the shared framework helper) — dist grouping
        // has single-member clients, so the allreduce is skipped there.
        client_local_step(st)?;
        // Fig. 8's lazy sync schedule (shared helper).
        if self.sync_due(cfg, st.iter) {
            // Push params (Fig. 8 l.10) through the shared wire block. The
            // MPI kvstore's push ring-SUMS across the client; replicas are
            // kept in lockstep, so pre-scale by 1/m to push the client
            // average (= w) rather than m*w. The pull returns the centers.
            let c = push_pull_scaled(st, 1.0 / st.m_live as f32)?;
            st.model.elastic2(st.w, &c, cfg.alpha)?; // Fig. 8 l.12
        }
        Ok(())
    }

    // --- sim plane ---------------------------------------------------------

    fn on_compute(
        &self,
        cfg: &ExperimentConfig,
        st: &mut EventStep<'_>,
    ) -> Result<AfterCompute> {
        // Local SGD step every iteration (Fig. 8 l.13).
        let hyper = local_hyper(self, cfg, &*st);
        let g = st.grad.take().expect("gradient at compute-done");
        st.model.sgd_update(st.w, &g, st.momentum, &hyper)?;
        // Fig. 8's lazy sync schedule (shared helper).
        if self.sync_due(cfg, st.iter) {
            Ok(AfterCompute::Push)
        } else {
            Ok(AfterCompute::Local)
        }
    }

    fn on_push_arrive(&self, cfg: &ExperimentConfig, st: &mut EventStep<'_>) -> Result<()> {
        let alpha = cfg.alpha;
        // Server: Elastic1 on the pushed params (eq. 2).
        let w_c = st.w.clone();
        st.model.elastic1(st.server_w, &w_c, alpha)?;
        // Client pulls the updated center, applies Elastic2 (Fig. 8
        // l.11-12).
        let center = st.server_w.clone();
        st.model.elastic2(st.w, &center, alpha)?;
        Ok(())
    }
}
