//! Asynchronous SGD (paper Fig. 7): `set_optimizer(SGD, rescale)` ships
//! the update rule to the server; workers push gradients and pull
//! *parameters*, with genuine staleness (pushes apply in arrival order).

use super::{
    join_keys, split_keys, AfterCompute, AlgoEntry, EventStep, Grouping, SyncStrategy,
    WorkerInit, WorkerStep,
};
use crate::config::ExperimentConfig;
use crate::optimizer::{Sgd, SgdHyper};
use crate::ps::SyncMode;
use anyhow::Result;

pub struct Asgd;

pub(crate) fn register(reg: &mut Vec<AlgoEntry>) {
    for grouping in [Grouping::Dist, Grouping::Mpi] {
        reg.push(AlgoEntry {
            name: format!("{}-ASGD", grouping.name()),
            grouping,
            strategy: &Asgd,
            paper_mode: true,
            sync_pattern: "async push per iteration, applied in arrival order",
            comm_per_iter: "full model (grads out, params back) every iteration",
            reference: "Fig. 7, Figs 11-12",
        });
    }
}

impl SyncStrategy for Asgd {
    fn server_mode(&self) -> SyncMode {
        SyncMode::Async
    }

    fn synchronous(&self) -> bool {
        false
    }

    fn local_model(&self) -> bool {
        // Workers train on the last *pulled* parameters.
        true
    }

    fn aggregated_workers(&self, _m_live: usize, _live_workers: usize) -> usize {
        // The local plane never runs SGD.Update — the server does — so the
        // local rescale denominator is inert; 1 keeps it honest.
        1
    }

    // --- threaded plane ----------------------------------------------------

    fn init(&self, cfg: &ExperimentConfig, ini: &mut WorkerInit<'_>) -> Result<()> {
        // Keys hold parameters; server runs the shipped SGD (Fig. 7).
        // Each push is one client's aggregate of `workers_per_client`
        // per-batch *mean* gradients, so the server rescales by the
        // worker count it aggregates (§5: 1/mini_batch_size, with our
        // gradients already averaged over the batch dimension).
        for (k, part) in ini.init_parts.iter().enumerate() {
            ini.kv.init(k, part.clone(), ini.is_root);
        }
        if ini.is_root {
            // Fig. 7 ships plain SGD: with several clients updating
            // asynchronously, momentum would compound their (stale)
            // gradients and diverge.
            // lr is divided by the client count so the *aggregate*
            // async step rate matches the synchronous one (standard
            // async-SGD stabilization).
            let hyper = SgdHyper {
                lr: cfg.lr / cfg.clients as f32,
                momentum: 0.0,
                weight_decay: cfg.weight_decay,
                rescale: 1.0 / cfg.workers_per_client() as f32,
            };
            ini.kv.set_optimizer(move || Box::new(Sgd::new(hyper)));
        }
        Ok(())
    }

    fn step(&self, _cfg: &ExperimentConfig, st: &mut WorkerStep<'_>) -> Result<()> {
        // Fig. 7: push grads, pull params.
        let grads = std::mem::take(&mut st.grads);
        let parts = split_keys(st.segs, &grads);
        for (k, part) in parts.into_iter().enumerate() {
            st.kv.push(k, part);
        }
        let pulls: Vec<_> = (0..st.n_keys).map(|k| st.kv.pull(k)).collect();
        let parts: Vec<Vec<f32>> = pulls.into_iter().map(|p| p.wait()).collect();
        join_keys(st.segs, &parts, st.w);
        Ok(())
    }

    // --- sim plane ---------------------------------------------------------

    fn on_compute(
        &self,
        _cfg: &ExperimentConfig,
        st: &mut EventStep<'_>,
    ) -> Result<AfterCompute> {
        // ASGD: the gradient goes to the PS; applied on arrival.
        *st.outbox = st.grad.take();
        Ok(AfterCompute::Push)
    }

    fn on_push_arrive(&self, cfg: &ExperimentConfig, st: &mut EventStep<'_>) -> Result<()> {
        // ASGD server updates: C clients fire independently, so the
        // aggregate step per "wave" is C times one update; scale the
        // server lr so the aggregate matches the synchronous rate
        // (standard async-SGD stabilization; without it the tight
        // synthetic task diverges).
        //
        // Known plane asymmetry, inherited from the pre-refactor trainers
        // and pinned by the Figs 11-12 regenerate-identically requirement:
        // the threaded PS additionally rescales by 1/workers_per_client
        // (see `init` above) while this plane applies the client's summed
        // gradient at rescale 1.0 — for multi-member clients the sim
        // server steps m times larger. ASGD is asynchronous (outside the
        // cross-plane bitwise contract); reconciling the two is a
        // deliberate follow-up, not a silent figure change.
        let server_hyper = SgdHyper {
            lr: cfg.lr / st.n_clients as f32,
            momentum: 0.0,
            weight_decay: cfg.weight_decay,
            rescale: 1.0,
        };
        let g = st.outbox.take().expect("grad in flight");
        st.model
            .sgd_update(st.server_w, &g, st.server_m, &server_hyper)?;
        // The client adopts the pulled parameters wholesale.
        st.w.clone_from(st.server_w);
        Ok(())
    }
}
