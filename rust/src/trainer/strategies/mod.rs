//! The pluggable algorithm layer: a plane-agnostic [`SyncStrategy`] trait
//! plus a string-keyed registry of algorithms.
//!
//! The paper's central API claim (§7) is that embedding MPI groups in the
//! PS task model "allows for novel communication avoiding algorithms that
//! do parameter averaging" — Elastic SGD being only the first instance.
//! This module is that seam made concrete:
//!
//! * [`SyncStrategy`] — one trait per *algorithm family member*, with
//!   hooks for both execution planes: framework wiring (server discipline,
//!   KVStore type, rescale denominators, sync cadence), the threaded
//!   plane's per-iteration body ([`SyncStrategy::init`] /
//!   [`SyncStrategy::step`] against the real KVStore-MPI stack), and the
//!   sim plane's numerics ([`SyncStrategy::lockstep_round`] for
//!   deterministic synchronous strategies, [`SyncStrategy::on_compute`] /
//!   [`SyncStrategy::on_push_arrive`] for event-driven asynchronous ones).
//! * [`CommPlane`] — the narrow view of an execution plane a strategy is
//!   allowed to assume: live group/job/client counts and the PS server
//!   count. Both planes' step contexts implement it, so shared per-update
//!   logic ([`local_hyper`], [`model_push_scale`]) exists exactly once.
//! * [`registry`] — the string-keyed algorithm table. One file per
//!   algorithm; adding an algorithm is one new file plus one registration
//!   line below. `--algo` parsing, usage text, figure sweeps, the CI
//!   smoke matrix and the bench table are all derived from this table, so
//!   none of them can drift from reality.
//!
//! The `dist-`/`mpi-` prefix of the paper's six §7 modes is **framework**
//! state, not algorithm state: a [`Grouping`] on the registry entry. The
//! three paper algorithms (SGD/ASGD/ESGD) each register a dist+mpi pair
//! over one shared strategy object; the communication-avoiding additions
//! ([`bmuf`], [`local_sgd`]) register a single MPI-grouped name.

pub mod asgd;
pub mod bmuf;
pub mod esgd;
pub mod local_sgd;
pub mod sgd;

use crate::config::ExperimentConfig;
use crate::kvstore::{KvType, KvWorker};
use crate::optimizer::SgdHyper;
use crate::ps::SyncMode;
use crate::runtime::service::ModelHandle;
use crate::runtime::Model;
use crate::tensor::SegmentTable;
use anyhow::Result;
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// Plane contexts
// ---------------------------------------------------------------------------

/// The narrow, plane-agnostic view a strategy computes against: who is
/// live, how the workers are grouped, and whether a PS exists. Implemented
/// by the threaded plane's [`WorkerStep`] and the sim plane's
/// [`EventStep`] / [`RoundView`].
pub trait CommPlane {
    /// Live member workers of this worker's MPI client (its group).
    fn group_live(&self) -> usize;
    /// Live workers across the whole job.
    fn job_live(&self) -> usize;
    /// Live MPI clients (the PS push fan-in).
    fn client_count(&self) -> usize;
    /// PS servers (0 = pure MPI).
    fn servers(&self) -> usize;
}

/// §5 local-update hyper-parameters on any plane: the rescale denominator
/// is the number of workers whose per-batch *mean* gradients were
/// aggregated before this update ([`SyncStrategy::aggregated_workers`]).
pub fn local_hyper(
    s: &dyn SyncStrategy,
    cfg: &ExperimentConfig,
    plane: &dyn CommPlane,
) -> SgdHyper {
    local_hyper_counts(s, cfg, plane.group_live(), plane.job_live())
}

/// [`local_hyper`] from raw live counts — the one place the formula
/// exists; the threaded worker loop uses this directly (before/without a
/// step context) so the two planes cannot drift.
pub fn local_hyper_counts(
    s: &dyn SyncStrategy,
    cfg: &ExperimentConfig,
    group_live: usize,
    job_live: usize,
) -> SgdHyper {
    SgdHyper {
        lr: cfg.lr,
        momentum: s.local_momentum(cfg),
        weight_decay: cfg.weight_decay,
        rescale: 1.0 / s.aggregated_workers(group_live, job_live).max(1) as f32,
    }
}

/// Pre-scale for a *model* push that must arrive at the PS as the global
/// client average: the MPI kvstore's push ring-SUMS the client's
/// `group_live` lockstep replicas and the PS sums the `client_count`
/// master pushes, so each replica pushes `w / (m * C)`. Shared by every
/// model-averaging strategy on both planes.
pub fn model_push_scale(plane: &dyn CommPlane) -> f32 {
    1.0 / (plane.group_live().max(1) * plane.client_count().max(1)) as f32
}

/// What the threaded plane hands a strategy at key-init time (before
/// iteration 0; joiners skip this entirely and bootstrap from checkpoint).
pub struct WorkerInit<'a> {
    pub kv: &'a KvWorker,
    pub segs: &'a SegmentTable,
    /// Initial parameters, already split per key.
    pub init_parts: &'a [Vec<f32>],
    /// Whether this worker is PS rank 0 (sets the server optimizer).
    pub is_root: bool,
}

/// One iteration of the threaded plane, after forward/backward produced
/// `grads`: the strategy owns everything between the gradient and the next
/// batch — pushes, pulls, allreduces, local updates.
pub struct WorkerStep<'a> {
    pub kv: &'a KvWorker,
    pub model: &'a ModelHandle,
    pub segs: &'a SegmentTable,
    pub n_keys: usize,
    pub iter: u64,
    /// This worker's replica (strategies update it in place).
    pub w: &'a mut Vec<f32>,
    pub momentum: &'a mut Vec<f32>,
    /// This iteration's per-batch mean gradient (take it).
    pub grads: Vec<f32>,
    /// Current local hyper (rescale renormalized to the live population).
    pub hyper: SgdHyper,
    pub m_live: usize,
    pub live_workers: usize,
    pub live_clients: usize,
    pub servers: usize,
}

impl CommPlane for WorkerStep<'_> {
    fn group_live(&self) -> usize {
        self.m_live
    }
    fn job_live(&self) -> usize {
        self.live_workers
    }
    fn client_count(&self) -> usize {
        self.live_clients
    }
    fn servers(&self) -> usize {
        self.servers
    }
}

/// One live client's slot in a sim-plane lockstep round.
pub struct RoundClient<'a> {
    /// Client index in the launch population.
    pub idx: usize,
    /// Live members (the client's group size).
    pub members: usize,
    /// Sum of the members' per-batch mean gradients (member order).
    pub grad: Vec<f32>,
    pub w: &'a mut Vec<f32>,
    pub momentum: &'a mut Vec<f32>,
}

/// One global round of the sim plane's lockstep flow (synchronous
/// strategies): every live client's gradient is on the table, and the
/// strategy owns the round's numerics — server update, model averaging,
/// local steps.
pub struct LockstepRound<'a> {
    pub model: &'a Model,
    pub iter: u64,
    /// Whether [`SyncStrategy::sync_due`] fired for this round (the
    /// generic loop prices the PS round / barrier only when it did).
    pub sync_due: bool,
    pub live_workers: usize,
    pub live_clients: usize,
    pub servers: usize,
    /// Server value: aggregated grads (SGD) or the global model
    /// (Local SGD / BMUF).
    pub server_w: &'a mut Vec<f32>,
    /// Server-side state buffer (momentum for SGD, block momentum Δ for
    /// BMUF).
    pub server_m: &'a mut Vec<f32>,
    /// Live clients, ascending index.
    pub clients: Vec<RoundClient<'a>>,
}

/// Per-client [`CommPlane`] view of a lockstep round.
#[derive(Clone, Copy)]
pub struct RoundView {
    pub members: usize,
    pub live_workers: usize,
    pub live_clients: usize,
    pub servers: usize,
}

impl LockstepRound<'_> {
    /// The [`CommPlane`] view of client slot `i` (index into
    /// [`LockstepRound::clients`], not the launch population).
    pub fn view(&self, i: usize) -> RoundView {
        RoundView {
            members: self.clients[i].members,
            live_workers: self.live_workers,
            live_clients: self.live_clients,
            servers: self.servers,
        }
    }
}

impl CommPlane for RoundView {
    fn group_live(&self) -> usize {
        self.members
    }
    fn job_live(&self) -> usize {
        self.live_workers
    }
    fn client_count(&self) -> usize {
        self.live_clients
    }
    fn servers(&self) -> usize {
        self.servers
    }
}

/// One event of the sim plane's event-driven flow (asynchronous
/// strategies): a single client's compute-done or push-arrival, with the
/// client replica and the server state both in reach.
pub struct EventStep<'a> {
    pub model: &'a Model,
    pub iter: u64,
    /// Client index in the launch population.
    pub client: usize,
    /// Live members of this client.
    pub members: usize,
    /// Launch-time client count (the async server-lr stabilization
    /// denominator — deliberately *not* the live count, so a kill does not
    /// change the server step size).
    pub n_clients: usize,
    pub live_workers: usize,
    pub live_clients: usize,
    pub servers: usize,
    pub w: &'a mut Vec<f32>,
    pub momentum: &'a mut Vec<f32>,
    pub server_w: &'a mut Vec<f32>,
    pub server_m: &'a mut Vec<f32>,
    /// Gradient in flight to the PS (set at compute-done, taken at
    /// push-arrival).
    pub outbox: &'a mut Option<Vec<f32>>,
    /// This iteration's gradient sum (Some at compute-done only).
    pub grad: Option<Vec<f32>>,
}

impl CommPlane for EventStep<'_> {
    fn group_live(&self) -> usize {
        self.members
    }
    fn job_live(&self) -> usize {
        self.live_workers
    }
    fn client_count(&self) -> usize {
        self.live_clients
    }
    fn servers(&self) -> usize {
        self.servers
    }
}

/// What an asynchronous strategy does after a client's local compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AfterCompute {
    /// Communicate: the generic loop prices a PS push and fires
    /// [`SyncStrategy::on_push_arrive`] when it lands.
    Push,
    /// No communication this iteration (lazy-sync strategies between
    /// sync points).
    Local,
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// A distributed-SGD algorithm, plane-agnostic: the same object drives the
/// threaded KVStore/MPI stack and the netsim cost-model plane.
///
/// Wiring hooks (`server_mode`, `aggregated_workers`, `sync_every`, …)
/// describe the algorithm to the framework; `init`/`step` are its threaded
/// execution body; `lockstep_round` or `on_compute`/`on_push_arrive` its
/// sim-plane numerics. Strategies hold **no mutable state** — all state
/// lives in the plane contexts — so one `&'static` instance serves every
/// worker thread.
pub trait SyncStrategy: Send + Sync {
    /// PS server aggregation discipline for this algorithm (§5).
    fn server_mode(&self) -> SyncMode;

    /// Deterministic global-lockstep semantics: with the same seed and
    /// config, both planes produce bitwise-identical weight trajectories
    /// (property-tested for every registered synchronous strategy in
    /// configs whose aggregation fan-ins stay order-independent).
    /// Synchronous strategies run the sim plane's lockstep flow;
    /// asynchronous ones run the event-driven flow.
    fn synchronous(&self) -> bool;

    /// Whether workers train on *local replicas* (pulled/averaged models)
    /// rather than directly against the server value. Decides which
    /// weights the sim plane evaluates and returns.
    fn local_model(&self) -> bool;

    /// Whether this strategy's PS pushes carry *model snapshots* (the
    /// elastic/model-averaging family) rather than gradients. Snapshot
    /// pushes bypass the lossy gradient codec on both planes
    /// ([`crate::kvstore::KvWorker::push_model`]): error feedback is an
    /// unbiased-over-time gradient mechanism, and a sparsified snapshot
    /// adopted wholesale is simply mass loss. The sim plane also prices
    /// these pushes at dense bytes.
    fn pushes_model(&self) -> bool {
        false
    }

    /// Momentum of the *local* SGD update (asynchronous strategies ship
    /// plain SGD: momentum on stale gradients compounds and diverges).
    fn local_momentum(&self, _cfg: &ExperimentConfig) -> f32 {
        0.0
    }

    /// How many workers' per-batch mean gradients are aggregated before
    /// one local update — the §5 `1/mini_batch` rescale denominator, in
    /// worker terms. Recomputed per membership epoch under churn.
    fn aggregated_workers(&self, m_live: usize, live_workers: usize) -> usize;

    /// The algorithm mini-batch in samples (§5).
    fn mini_batch(&self, cfg: &ExperimentConfig) -> usize {
        cfg.workers_per_client() * cfg.batch
    }

    /// Iteration cadence of this strategy's sync boundaries: membership
    /// epochs (elastic reconfiguration) ride these, so the
    /// [`ElasticHub`](crate::launcher::ElasticHub) schedule keys off the
    /// trait rather than off algorithm special cases. `1` = every
    /// iteration is a boundary.
    fn sync_every(&self, _cfg: &ExperimentConfig) -> u64 {
        1
    }

    /// Whether global synchronization fires after iteration `iter`.
    /// Must return `true` on every `sync_every` boundary iteration.
    fn sync_due(&self, _cfg: &ExperimentConfig, _iter: u64) -> bool {
        true
    }

    /// Mean global sync events per iteration at steady state — transient
    /// phases (e.g. Local SGD's warmup, which syncs every iteration) are
    /// excluded. Cost metadata for the bench table:
    /// `virtual_model_bytes * syncs_per_iter` is the steady-state PS-bound
    /// traffic per master per iteration.
    fn syncs_per_iter(&self, cfg: &ExperimentConfig) -> f64 {
        1.0 / self.sync_every(cfg).max(1) as f64
    }

    // --- threaded plane ----------------------------------------------------

    /// Initialize the KVStore keys and (on the root) ship the server
    /// optimizer. Runs once per worker before iteration 0.
    fn init(&self, cfg: &ExperimentConfig, ini: &mut WorkerInit<'_>) -> Result<()>;

    /// One iteration on the threaded plane: everything between this
    /// batch's gradient and the next batch.
    fn step(&self, cfg: &ExperimentConfig, st: &mut WorkerStep<'_>) -> Result<()>;

    // --- sim plane ---------------------------------------------------------

    /// One global lockstep round (synchronous strategies only).
    fn lockstep_round(
        &self,
        cfg: &ExperimentConfig,
        round: &mut LockstepRound<'_>,
    ) -> Result<()> {
        let _ = (cfg, round);
        anyhow::bail!("strategy has no lockstep (synchronous) sim implementation")
    }

    /// Event-driven compute-done numerics (asynchronous strategies only):
    /// local update and the push/no-push decision.
    fn on_compute(
        &self,
        cfg: &ExperimentConfig,
        st: &mut EventStep<'_>,
    ) -> Result<AfterCompute> {
        let _ = (cfg, st);
        anyhow::bail!("strategy has no event-driven sim implementation")
    }

    /// Event-driven push-arrival numerics (asynchronous strategies only):
    /// server merge plus the client's pull merge.
    fn on_push_arrive(&self, cfg: &ExperimentConfig, st: &mut EventStep<'_>) -> Result<()> {
        let _ = (cfg, st);
        anyhow::bail!("strategy has no event-driven sim implementation")
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// How a registered algorithm groups its workers — the `dist-`/`mpi-`
/// prefix of the paper's §7 mode names, factored into the framework.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Grouping {
    /// One worker per client, every worker talks to the PS (§2.3's
    /// hot-spot baseline).
    Dist,
    /// Workers grouped into MPI clients; only masters talk to the PS.
    Mpi,
}

impl Grouping {
    pub fn name(&self) -> &'static str {
        match self {
            Grouping::Dist => "dist",
            Grouping::Mpi => "mpi",
        }
    }
}

/// One registered algorithm: a name, a grouping, the strategy object and
/// the documentation metadata the README table / bench rows are built
/// from.
pub struct AlgoEntry {
    pub name: String,
    pub grouping: Grouping,
    pub strategy: &'static dyn SyncStrategy,
    /// One of the six §7 paper modes (the Fig. 12 sweep — new algorithms
    /// stay out so the paper figures regenerate unchanged).
    pub paper_mode: bool,
    /// Human description of the sync pattern (docs/bench).
    pub sync_pattern: &'static str,
    /// Human description of communication volume per iteration (docs).
    pub comm_per_iter: &'static str,
    /// Paper / figure reference (docs).
    pub reference: &'static str,
}

/// The algorithm registry. One registration call per strategy file —
/// adding an algorithm is a new file in `trainer/strategies/` plus one
/// line here.
pub fn registry() -> &'static [AlgoEntry] {
    static REGISTRY: OnceLock<Vec<AlgoEntry>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut reg = Vec::new();
        sgd::register(&mut reg);
        asgd::register(&mut reg);
        esgd::register(&mut reg);
        bmuf::register(&mut reg);
        local_sgd::register(&mut reg);
        let mut seen = std::collections::HashSet::new();
        for e in &reg {
            assert!(
                seen.insert(e.name.to_ascii_lowercase()),
                "duplicate algorithm registration: {}",
                e.name
            );
        }
        reg
    })
}

/// A registered algorithm handle — the open-world replacement for the old
/// closed `Algo` enum. Copyable, comparable, and resolved by *name*
/// through [`registry`].
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Algo(u16);

impl Algo {
    /// Case-insensitive name lookup.
    pub fn parse(s: &str) -> Option<Algo> {
        registry()
            .iter()
            .position(|e| e.name.eq_ignore_ascii_case(s))
            .map(|i| Algo(i as u16))
    }

    /// Name lookup that panics (with the registered names) on a miss —
    /// for code paths where the name is a compile-time literal.
    pub fn named(s: &str) -> Algo {
        Self::parse(s).unwrap_or_else(|| {
            panic!(
                "unknown algo {s:?} (registered: {})",
                Self::names().join(", ")
            )
        })
    }

    /// Every registered algorithm, registration order.
    pub fn all() -> Vec<Algo> {
        (0..registry().len()).map(|i| Algo(i as u16)).collect()
    }

    /// Every registered name, registration order (usage text, errors).
    pub fn names() -> Vec<&'static str> {
        registry().iter().map(|e| e.name.as_str()).collect()
    }

    /// The six §7 paper modes in the paper's presentation order (the three
    /// dist modes, then the three mpi modes) — the Fig. 12 sweep.
    pub fn paper_modes() -> Vec<Algo> {
        let mut v: Vec<Algo> = Self::all()
            .into_iter()
            .filter(|a| a.entry().paper_mode)
            .collect();
        v.sort_by_key(|a| a.grouping());
        v
    }

    pub fn entry(&self) -> &'static AlgoEntry {
        &registry()[self.0 as usize]
    }

    pub fn name(&self) -> &'static str {
        self.entry().name.as_str()
    }

    pub fn strategy(&self) -> &'static dyn SyncStrategy {
        self.entry().strategy
    }

    pub fn grouping(&self) -> Grouping {
        self.entry().grouping
    }

    pub fn is_mpi(&self) -> bool {
        self.grouping() == Grouping::Mpi
    }

    /// PS server aggregation discipline (delegates to the strategy).
    pub fn server_mode(&self) -> SyncMode {
        self.strategy().server_mode()
    }

    /// KVStore type of §4.2.1 — a pure framework mapping of
    /// (grouping × server discipline), identical for every algorithm.
    pub fn kv_type(&self) -> KvType {
        match (self.grouping(), self.server_mode()) {
            (Grouping::Dist, SyncMode::Sync) => KvType::DistSync,
            (Grouping::Dist, SyncMode::Async) => KvType::DistAsync,
            (Grouping::Mpi, SyncMode::Sync) => KvType::SyncMpi,
            (Grouping::Mpi, SyncMode::Async) => KvType::AsyncMpi,
        }
    }
}

impl std::fmt::Debug for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Shared per-key plumbing (used by strategy `step` bodies on the threaded
// plane; lived in trainer/threaded.rs before the strategy refactor)
// ---------------------------------------------------------------------------

/// Per-key slices of a flat vector, in key order.
pub fn split_keys(segs: &SegmentTable, flat: &[f32]) -> Vec<Vec<f32>> {
    (0..segs.len()).map(|k| segs.slice(flat, k).to_vec()).collect()
}

/// Inverse of [`split_keys`]: write per-key parts back into a flat vector.
pub fn join_keys(segs: &SegmentTable, parts: &[Vec<f32>], flat: &mut [f32]) {
    for (k, part) in parts.iter().enumerate() {
        segs.slice_mut(flat, k).copy_from_slice(part);
    }
}

// ---------------------------------------------------------------------------
// Shared wire-protocol building blocks (the intra-client-synchronous
// family: ESGD / Local SGD / BMUF). One implementation each, so strategy
// files compose them instead of carrying diverging copies.
// ---------------------------------------------------------------------------

/// Threaded plane: the shared local lockstep step — average gradients
/// across the client's live members (ring allreduce; a no-op for
/// single-member clients), then apply the local SGD update to this
/// worker's replica with the step's renormalized hyper.
pub fn client_local_step(st: &mut WorkerStep<'_>) -> Result<()> {
    let mut g = std::mem::take(&mut st.grads);
    if st.m_live > 1 {
        g = st.kv.client_allreduce(g).wait();
    }
    st.model.sgd_update(st.w, &g, st.momentum, &st.hyper)?;
    Ok(())
}

/// Threaded plane: push this worker's replica pre-scaled by `scale` (per
/// key, through the MPI kvstore: the client ring sums the `m` lockstep
/// replicas, masters ZPush), then pull the server's merged per-key values
/// back as one flat vector. The wire block every model-pushing strategy
/// shares — ESGD pulls *centers* to elastic-merge, Local SGD/BMUF pull
/// the averaged/filtered global model to adopt. Pushes go through
/// [`KvWorker::push_model`]: these are model *snapshots* the receivers
/// adopt wholesale, so lossy gradient codecs never touch them (error
/// feedback cannot repair a sparsified snapshot).
pub fn push_pull_scaled(st: &mut WorkerStep<'_>, scale: f32) -> Result<Vec<f32>> {
    let mut w_push = st.w.clone();
    crate::tensor::scale(&mut w_push, scale);
    let parts = split_keys(st.segs, &w_push);
    for (k, part) in parts.into_iter().enumerate() {
        st.kv.push_model(k, part);
    }
    let pulls: Vec<_> = (0..st.n_keys).map(|k| st.kv.pull(k)).collect();
    let parts: Vec<Vec<f32>> = pulls.into_iter().map(|p| p.wait()).collect();
    let mut flat = vec![0.0f32; st.w.len()];
    join_keys(st.segs, &parts, &mut flat);
    Ok(flat)
}

/// Threaded plane: the model-averaging sync (Local SGD / BMUF) —
/// [`push_pull_scaled`] with the [`model_push_scale`] pre-scale (landing
/// the global client average on the server), adopting the merged result
/// wholesale.
pub fn push_pull_model(st: &mut WorkerStep<'_>) -> Result<()> {
    let scale = model_push_scale(&*st);
    let merged = push_pull_scaled(st, scale)?;
    *st.w = merged;
    Ok(())
}

/// Sim plane: the shared per-client local step of a lockstep round.
pub fn round_local_steps(
    s: &dyn SyncStrategy,
    cfg: &ExperimentConfig,
    round: &mut LockstepRound<'_>,
) -> Result<()> {
    let (live_workers, live_clients, servers) =
        (round.live_workers, round.live_clients, round.servers);
    for rc in round.clients.iter_mut() {
        let view = RoundView { members: rc.members, live_workers, live_clients, servers };
        let hyper = local_hyper(s, cfg, &view);
        let g = std::mem::take(&mut rc.grad);
        round.model.sgd_update(rc.w, &g, rc.momentum, &hyper)?;
    }
    Ok(())
}

/// Sim plane: the mirror of [`push_pull_model`]'s aggregation — every
/// live client's replica pre-scaled by [`model_push_scale`] and folded
/// the way the wire folds it (see [`averaged_model`]).
pub fn round_averaged_model(round: &LockstepRound<'_>) -> Vec<f32> {
    let mut contribs = Vec::with_capacity(round.clients.len());
    for (i, rc) in round.clients.iter().enumerate() {
        let scale = model_push_scale(&round.view(i));
        let mut t = rc.w.clone();
        crate::tensor::scale(&mut t, scale);
        contribs.push((rc.members, t));
    }
    averaged_model(contribs)
}

/// The model-averaging fold both planes share: each replica pushes
/// `w * 1/(m*C)`, the client ring sums its `m` lockstep replicas, the PS
/// sums the `C` client pushes. `contribs` is `(members, scaled replica)`
/// per live client, ascending client order. (Bitwise-faithful to the
/// threaded wire for fan-ins of <= 2 summands per fold — the cross-plane
/// property-test domain; beyond that, equal up to f32 fold order.)
pub fn averaged_model(contribs: Vec<(usize, Vec<f32>)>) -> Vec<f32> {
    let mut avg: Vec<f32> = Vec::new();
    for (members, t) in contribs {
        // The intra-client ring sums `members` identical lockstep replicas
        // (single-member clients contribute their vector as-is, no copy).
        let u = if members > 1 {
            let mut u = t.clone();
            for _ in 1..members {
                crate::tensor::add_assign(&mut u, &t);
            }
            u
        } else {
            t
        };
        if avg.is_empty() {
            avg = u;
        } else {
            crate::tensor::add_assign(&mut avg, &u);
        }
    }
    avg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_round_trip_case_insensitive() {
        for a in Algo::all() {
            assert_eq!(Algo::parse(a.name()), Some(a));
            assert_eq!(Algo::parse(&a.name().to_ascii_uppercase()), Some(a));
            assert_eq!(Algo::parse(&a.name().to_ascii_lowercase()), Some(a));
        }
        assert_eq!(Algo::parse("nope"), None);
    }

    #[test]
    fn registry_has_all_eight_algorithms() {
        let names = Algo::names();
        for want in [
            "dist-SGD",
            "dist-ASGD",
            "dist-ESGD",
            "mpi-SGD",
            "mpi-ASGD",
            "mpi-ESGD",
            "bmuf",
            "local-sgd",
        ] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        assert_eq!(names.len(), 8);
    }

    #[test]
    fn paper_modes_keep_the_fig12_order() {
        let modes: Vec<&str> = Algo::paper_modes().iter().map(|a| a.name()).collect();
        assert_eq!(
            modes,
            ["dist-SGD", "dist-ASGD", "dist-ESGD", "mpi-SGD", "mpi-ASGD", "mpi-ESGD"]
        );
    }

    #[test]
    fn kv_types_and_server_modes_match_paper() {
        let m = |n: &str| Algo::named(n);
        assert_eq!(m("dist-SGD").kv_type(), KvType::DistSync);
        assert_eq!(m("dist-ASGD").kv_type(), KvType::DistAsync);
        assert_eq!(m("dist-ESGD").kv_type(), KvType::DistAsync);
        assert_eq!(m("mpi-SGD").kv_type(), KvType::SyncMpi);
        assert_eq!(m("mpi-ASGD").kv_type(), KvType::AsyncMpi);
        assert_eq!(m("mpi-ESGD").kv_type(), KvType::AsyncMpi);
        assert_eq!(m("bmuf").kv_type(), KvType::SyncMpi);
        assert_eq!(m("local-sgd").kv_type(), KvType::SyncMpi);
        assert_eq!(m("dist-SGD").server_mode(), SyncMode::Sync);
        assert_eq!(m("mpi-SGD").server_mode(), SyncMode::Sync);
        for a in ["dist-ASGD", "dist-ESGD", "mpi-ASGD", "mpi-ESGD"] {
            assert_eq!(m(a).server_mode(), SyncMode::Async, "{a}");
        }
    }

    #[test]
    fn sync_boundaries_come_from_the_trait() {
        let cfg = ExperimentConfig::testbed1(Algo::named("mpi-ESGD"));
        assert_eq!(
            Algo::named("mpi-ESGD").strategy().sync_every(&cfg),
            cfg.interval as u64
        );
        assert_eq!(Algo::named("mpi-SGD").strategy().sync_every(&cfg), 1);
        assert_eq!(Algo::named("mpi-ASGD").strategy().sync_every(&cfg), 1);
        assert_eq!(
            Algo::named("local-sgd").strategy().sync_every(&cfg),
            cfg.interval as u64
        );
        assert_eq!(
            Algo::named("bmuf").strategy().sync_every(&cfg),
            cfg.interval as u64
        );
    }

    #[test]
    fn synchronous_flags_split_the_sim_flows() {
        for (name, sync) in [
            ("dist-SGD", true),
            ("mpi-SGD", true),
            ("dist-ASGD", false),
            ("mpi-ASGD", false),
            ("dist-ESGD", false),
            ("mpi-ESGD", false),
            ("bmuf", true),
            ("local-sgd", true),
        ] {
            assert_eq!(Algo::named(name).strategy().synchronous(), sync, "{name}");
        }
    }

    #[test]
    fn local_sgd_warmup_schedules_every_iteration() {
        let mut cfg = ExperimentConfig::testbed1(Algo::named("local-sgd"));
        cfg.interval = 4;
        cfg.warmup_iters = 3;
        let s = Algo::named("local-sgd").strategy();
        // Warmup: every iteration syncs.
        assert!(s.sync_due(&cfg, 0));
        assert!(s.sync_due(&cfg, 2));
        // Post-warmup: only the lazy interval fires.
        assert!(!s.sync_due(&cfg, 4));
        assert!(s.sync_due(&cfg, 3)); // (3+1) % 4 == 0
        assert!(s.sync_due(&cfg, 7));
        assert!(!s.sync_due(&cfg, 8));
    }
}
