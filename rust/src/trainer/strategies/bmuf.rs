//! BMUF — Blockwise Model Update Filtering (Chen & Huo, ICASSP 2016):
//! Local SGD's periodic model averaging, with the *server* treating each
//! block's averaged model delta as a filtered update:
//!
//! ```text
//! w̄    = mean over clients of the block's final replicas
//! Δ_t  = η Δ_{t-1} + (w̄ - G_{t-1})      (block momentum η = cfg.block_momentum)
//! G_t  = G_{t-1} + Δ_t
//! ```
//!
//! Plain averaging (η = 0) discards the optimization momentum a block
//! represents; the filter re-injects it, which is what lets BMUF keep
//! sync intervals long (communication-avoiding) without the convergence
//! penalty. Registered as one MPI-grouped name; a single file + one
//! registration line, no execution-loop edits — the second proof of the
//! [`SyncStrategy`] seam.

use super::{
    client_local_step, push_pull_model, round_averaged_model, round_local_steps, AlgoEntry,
    Grouping, LockstepRound, SyncStrategy, WorkerInit, WorkerStep,
};
use crate::config::ExperimentConfig;
use crate::optimizer::Optimizer;
use crate::ps::SyncMode;
use anyhow::Result;

pub struct Bmuf;

pub(crate) fn register(reg: &mut Vec<AlgoEntry>) {
    reg.push(AlgoEntry {
        name: "bmuf".to_string(),
        grouping: Grouping::Mpi,
        strategy: &Bmuf,
        paper_mode: false,
        sync_pattern: "periodic block-momentum-filtered model averaging",
        comm_per_iter: "full model push+pull / INTERVAL (none between syncs)",
        reference: "Chen & Huo, ICASSP 2016; paper §7 outlook",
    });
}

/// The block-momentum filter, shared verbatim by the PS-side optimizer
/// (threaded plane) and the lockstep hook (sim plane) so the two planes
/// cannot drift: `Δ = η Δ + (w̄ - G); G += Δ`, elementwise.
pub(crate) fn bmuf_apply(g: &mut [f32], delta: &mut [f32], avg: &[f32], eta: f32) {
    for i in 0..g.len() {
        delta[i] = eta * delta[i] + (avg[i] - g[i]);
        g[i] += delta[i];
    }
}

/// Server-side BMUF optimizer: the stored value is the filtered global
/// model `G`; the aggregated push (pre-scaled replicas) is the block
/// average `w̄`. Per-key Δ buffers, like [`crate::optimizer::Sgd`]'s
/// momentum.
pub struct BlockMomentum {
    pub eta: f32,
    delta: std::collections::HashMap<usize, Vec<f32>>,
}

impl BlockMomentum {
    pub fn new(eta: f32) -> Self {
        Self { eta, delta: Default::default() }
    }
}

impl Optimizer for BlockMomentum {
    fn update(&mut self, key: usize, stored: &mut [f32], avg: &[f32]) {
        let d = self
            .delta
            .entry(key)
            .or_insert_with(|| vec![0.0; stored.len()]);
        assert_eq!(d.len(), stored.len());
        bmuf_apply(stored, d, avg, self.eta);
    }

    fn name(&self) -> &'static str {
        "block-momentum"
    }
}

impl SyncStrategy for Bmuf {
    fn server_mode(&self) -> SyncMode {
        SyncMode::Sync
    }

    fn synchronous(&self) -> bool {
        true
    }

    fn local_model(&self) -> bool {
        true
    }

    fn pushes_model(&self) -> bool {
        // PS pushes carry replica snapshots, not gradients: they bypass
        // the lossy gradient codec (see the trait doc).
        true
    }

    fn local_momentum(&self, cfg: &ExperimentConfig) -> f32 {
        cfg.momentum
    }

    fn aggregated_workers(&self, m_live: usize, _live_workers: usize) -> usize {
        m_live
    }

    fn sync_every(&self, cfg: &ExperimentConfig) -> u64 {
        cfg.interval.max(1) as u64
    }

    fn sync_due(&self, cfg: &ExperimentConfig, iter: u64) -> bool {
        crate::trainer::esgd_sync_due(iter, cfg.interval)
    }

    // --- threaded plane ----------------------------------------------------

    fn init(&self, cfg: &ExperimentConfig, ini: &mut WorkerInit<'_>) -> Result<()> {
        // The filtered global model and its Δ buffer live on the PS:
        // serverless push/pull has no store for them.
        anyhow::ensure!(
            cfg.servers > 0,
            "bmuf requires at least one PS server (the block-momentum \
             filter runs on the PS)"
        );
        // Keys hold the filtered global model G (init = the shared init
        // params); the PS runs the block-momentum filter on each block's
        // aggregated average.
        for (k, part) in ini.init_parts.iter().enumerate() {
            ini.kv.init(k, part.clone(), ini.is_root);
        }
        if ini.is_root {
            let eta = cfg.block_momentum;
            ini.kv
                .set_optimizer(move || Box::new(BlockMomentum::new(eta)));
        }
        Ok(())
    }

    fn step(&self, cfg: &ExperimentConfig, st: &mut WorkerStep<'_>) -> Result<()> {
        // Identical wire protocol to local-sgd (the shared framework
        // helpers): only the server-side filter differs, and that was
        // shipped at init.
        client_local_step(st)?;
        if self.sync_due(cfg, st.iter) {
            push_pull_model(st)?;
        }
        Ok(())
    }

    // --- sim plane ---------------------------------------------------------

    fn lockstep_round(
        &self,
        cfg: &ExperimentConfig,
        round: &mut LockstepRound<'_>,
    ) -> Result<()> {
        anyhow::ensure!(
            round.servers > 0,
            "bmuf requires at least one PS server (the block-momentum \
             filter runs on the PS)"
        );
        round_local_steps(self, cfg, round)?;
        if round.sync_due {
            let avg = round_averaged_model(round);
            // G lives in server_w, Δ in server_m — the same filter the
            // threaded PS runs (`bmuf_apply`), bit for bit.
            bmuf_apply(round.server_w, round.server_m, &avg, cfg.block_momentum);
            for rc in round.clients.iter_mut() {
                rc.w.clone_from(round.server_w);
            }
        }
        Ok(())
    }
}
