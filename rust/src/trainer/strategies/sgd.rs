//! Synchronous SGD (paper Fig. 6): push per-key gradients, pull the
//! *aggregated gradient* back (the server runs `Assign`), `SGD.Update`
//! locally with `rescale = 1/mini_batch_size`. MPI grouping pre-aggregates
//! inside the client ring and only masters talk to the PS; with
//! `#servers == 0` PushPull degrades to the pure-MPI allreduce (§4.2.4).

use super::{
    join_keys, local_hyper_counts, split_keys, AlgoEntry, Grouping, LockstepRound,
    SyncStrategy, WorkerInit, WorkerStep,
};
use crate::config::ExperimentConfig;
use crate::optimizer::Assign;
use crate::ps::SyncMode;
use anyhow::Result;

pub struct Sgd;

pub(crate) fn register(reg: &mut Vec<AlgoEntry>) {
    for grouping in [Grouping::Dist, Grouping::Mpi] {
        reg.push(AlgoEntry {
            name: format!("{}-SGD", grouping.name()),
            grouping,
            strategy: &Sgd,
            paper_mode: true,
            sync_pattern: "global gradient aggregation every iteration",
            comm_per_iter: "full model (gradients) push+pull per sync round",
            reference: "Fig. 6, Figs 11-12",
        });
    }
}

impl SyncStrategy for Sgd {
    fn server_mode(&self) -> SyncMode {
        SyncMode::Sync
    }

    fn synchronous(&self) -> bool {
        true
    }

    fn local_model(&self) -> bool {
        // Every worker applies the identical aggregated update, so the
        // replica IS the server trajectory.
        false
    }

    fn local_momentum(&self, cfg: &ExperimentConfig) -> f32 {
        // Fig. 6's local SGD.Update runs on the exact aggregated gradient,
        // so momentum is safe here (and only here among the §5 modes).
        cfg.momentum
    }

    fn aggregated_workers(&self, _m_live: usize, live_workers: usize) -> usize {
        live_workers
    }

    fn mini_batch(&self, cfg: &ExperimentConfig) -> usize {
        cfg.workers * cfg.batch
    }

    // --- threaded plane ----------------------------------------------------

    fn init(&self, _cfg: &ExperimentConfig, ini: &mut WorkerInit<'_>) -> Result<()> {
        // Keys hold aggregated gradients (Fig. 6): init zeros.
        for k in 0..ini.init_parts.len() {
            ini.kv
                .init(k, vec![0.0; ini.segs.segments[k].size], ini.is_root);
        }
        if ini.is_root {
            ini.kv.set_optimizer(|| Box::new(Assign));
        }
        Ok(())
    }

    fn step(&self, _cfg: &ExperimentConfig, st: &mut WorkerStep<'_>) -> Result<()> {
        // Fig. 6: push grads per key, pull aggregated grads. With no
        // servers, PushPull degrades to the pure-MPI allreduce (§4.2.4),
        // issued as one nonblocking engine op *per fusion bucket* in
        // backward (reverse-key) order — the order backprop emits
        // gradients — so bucket i's SGD.Update overlaps bucket i+1's
        // allreduce (DAG-embedded collectives, arXiv:1802.06949).
        let grads = std::mem::take(&mut st.grads);
        let parts = split_keys(st.segs, &grads);
        if st.servers == 0 {
            let keyed: Vec<(usize, Vec<f32>)> = parts.into_iter().enumerate().collect();
            for ((i, j), pending) in st.kv.pushpull_buckets(keyed) {
                let agg = pending.wait();
                let lo = st.segs.segments[i].offset;
                let hi = st.segs.segments[j - 1].offset + st.segs.segments[j - 1].size;
                let mut g_seg = Vec::with_capacity(hi - lo);
                for part in &agg {
                    g_seg.extend_from_slice(part);
                }
                let mut w_seg = st.w[lo..hi].to_vec();
                let mut m_seg = st.momentum[lo..hi].to_vec();
                st.model.sgd_update(&mut w_seg, &g_seg, &mut m_seg, &st.hyper)?;
                st.w[lo..hi].copy_from_slice(&w_seg);
                st.momentum[lo..hi].copy_from_slice(&m_seg);
            }
        } else {
            for (k, part) in parts.into_iter().enumerate() {
                st.kv.push(k, part);
            }
            let pulls: Vec<_> = (0..st.n_keys).map(|k| st.kv.pull(k)).collect();
            let agg: Vec<Vec<f32>> = pulls.into_iter().map(|p| p.wait()).collect();
            let mut g_sum = vec![0.0f32; st.w.len()];
            join_keys(st.segs, &agg, &mut g_sum);
            st.model.sgd_update(st.w, &g_sum, st.momentum, &st.hyper)?;
        }
        Ok(())
    }

    // --- sim plane ---------------------------------------------------------

    fn lockstep_round(
        &self,
        cfg: &ExperimentConfig,
        round: &mut LockstepRound<'_>,
    ) -> Result<()> {
        // Renormalized to the live population (survivors' averages span
        // the live set, §5's 1/mini_batch in sample terms) — through the
        // one shared hyper formula; aggregated_workers for SGD is the
        // global live count, so the group size is irrelevant here.
        let group_live = round.clients.first().map_or(1, |rc| rc.members);
        let hyper = local_hyper_counts(self, cfg, group_live, round.live_workers);
        // Global gradient = sum over live clients' member sums, in client
        // order (the same fold the pre-refactor trainer used).
        let mut total_g: Vec<f32> = Vec::new();
        for rc in &round.clients {
            if total_g.is_empty() {
                total_g = rc.grad.clone();
            } else {
                crate::tensor::add_assign(&mut total_g, &rc.grad);
            }
        }
        round
            .model
            .sgd_update(round.server_w, &total_g, round.server_m, &hyper)?;
        Ok(())
    }
}
