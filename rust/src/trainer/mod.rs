//! Distributed SGD trainers (paper §5).
//!
//! Two execution planes share the same algorithm semantics:
//!
//! * [`threaded`] — the *deployable framework*: real PS server threads,
//!   real simulated-MPI clients, the KVStore-MPI API over the dependency
//!   engine, gradients through PJRT. Wall-clock timing. This is what the
//!   quickstart / e2e examples run.
//! * [`sim`] — the *paper-figure plane*: identical algorithm semantics and
//!   identical (real) gradient numerics, but the time axis is the
//!   [`netsim`](crate::netsim) virtual clock with the paper's testbed
//!   α-β-γ constants, so Figs 11–14/16 regenerate deterministically on
//!   hardware the paper's cluster does not resemble.
//!
//! The algorithms themselves live in neither plane: [`strategies`] holds
//! the plane-agnostic [`SyncStrategy`](strategies::SyncStrategy) objects
//! and the string-keyed registry both planes (and the CLI, figures, bench
//! table and CI smoke matrix) dispatch through. Each plane runs **one**
//! strategy execution loop; for every registered *synchronous* strategy
//! the two loops produce bitwise-identical weight trajectories from the
//! same seed/config (property-tested in `tests/strategies.rs`).

pub mod sim;
pub mod strategies;
pub mod threaded;

use crate::runtime::XData;
use anyhow::Result;

/// First sample index of the held-out validation shard. Training shards
/// draw from [0, samples_per_epoch); validation draws from here up — same
/// generative distribution, guaranteed-disjoint samples.
pub const EVAL_OFFSET: u64 = 1 << 40;

/// Whether a lazy-interval sync fires after iteration `iter` (Fig. 8):
/// every `interval` iterations *after* local progress — `(iter + 1)`, not
/// `iter`, so iteration 0 makes local progress before any push — with
/// `interval == 0` clamped to sync every iteration rather than dividing
/// by zero. Shared by every lazy-sync strategy (ESGD, Local SGD, BMUF) so
/// the schedule exists exactly once.
pub fn esgd_sync_due(iter: u64, interval: usize) -> bool {
    (iter + 1) % (interval.max(1) as u64) == 0
}

/// Batch provider shared by both trainers: synthetic Gaussian-mixture
/// images (f32 models) or the tiny token corpus (i32 models).
pub enum TrainData {
    Gaussian(crate::data::GaussianMixture),
    Corpus { corpus: crate::data::TinyCorpus, seq: usize },
}

impl TrainData {
    /// Build from a model's metadata + experiment config.
    pub fn for_model(meta: &crate::runtime::ModelMeta, noise: f32, classes: usize, seed: u64) -> Self {
        if meta.x_dtype == "int32" {
            let vocab = meta.config_num("vocab").unwrap_or(64.0) as usize;
            let seq = meta.x_shape[1] as usize;
            TrainData::Corpus { corpus: crate::data::TinyCorpus::new(vocab, seed), seq }
        } else {
            let dim = meta.x_shape[1] as usize;
            TrainData::Gaussian(crate::data::GaussianMixture::new(dim, classes, noise, seed))
        }
    }

    /// Materialize the batch starting at sample index `start`.
    pub fn batch(&self, start: u64, batch: usize) -> (XData, Vec<i32>) {
        match self {
            TrainData::Gaussian(g) => {
                let b = g.batch(start, batch);
                (XData::F32(b.x), b.y)
            }
            TrainData::Corpus { corpus, seq } => {
                let (x, y) = corpus.batch_tokens(start, batch, *seq);
                (XData::I32(x), y)
            }
        }
    }
}

/// The compute half of the device tier, shared by both planes so their
/// shard math cannot drift: split one worker batch of `batch` rows into
/// `devices` contiguous per-device shards (device d gets the
/// [`chunk_bounds`](crate::collectives::chunk_bounds) rows, same
/// partition as every other k-way split in the repo) and run `grad` on
/// each shard. `grad` receives `(x, y, rows)` with `rows` ≤ `batch`.
///
/// Returns the per-device row-mean gradients in device order plus the
/// mean of the per-device losses — [`device_local_merge`] then averages
/// the gradients into the leader buffer, reconstructing the same
/// estimator as one full-`batch` step. `devices == 1` takes the exact
/// legacy path: one full batch, one grad call, buffers untouched.
///
/// [`device_local_merge`]: crate::kvstore::device_local_merge
pub fn device_grad_shards(
    data: &TrainData,
    start: u64,
    batch: usize,
    devices: usize,
    mut grad: impl FnMut(XData, Vec<i32>, usize) -> Result<(f32, Vec<f32>)>,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let k = devices.max(1).min(batch.max(1));
    if k == 1 {
        let (x, y) = data.batch(start, batch);
        let (loss, g) = grad(x, y, batch)?;
        return Ok((loss, vec![g]));
    }
    let mut bufs = Vec::with_capacity(k);
    let mut loss = 0.0f32;
    for d in 0..k {
        let (s, e) = crate::collectives::chunk_bounds(batch, k, d);
        let rows = e - s;
        let (x, y) = data.batch(start + s as u64, rows);
        let (l, g) = grad(x, y, rows)?;
        loss += l;
        bufs.push(g);
    }
    Ok((loss / k as f32, bufs))
}

/// Validation loss/accuracy over `eval_samples` held-out samples — the
/// one shared implementation both execution planes call (they used to
/// carry separate copies; a drift here would silently skew every figure).
///
/// Same distribution as training (same mixture centers / successor
/// table), disjoint sample indices: the held-out shard lives past
/// [`EVAL_OFFSET`]. `eval_step` abstracts over the plane's model access
/// ([`crate::runtime::Model`] in-process vs the threaded plane's
/// [`crate::runtime::service::ModelHandle`]).
pub fn evaluate(
    data: &TrainData,
    eval_samples: u64,
    batch: usize,
    w: &[f32],
    mut eval_step: impl FnMut(&[f32], XData, Vec<i32>) -> Result<(f32, i32)>,
) -> Result<(f64, f64)> {
    let n_batches = (eval_samples as usize / batch).max(1);
    let per = match data {
        TrainData::Gaussian(_) => 1i64,
        TrainData::Corpus { seq, .. } => *seq as i64,
    };
    let mut loss = 0.0f64;
    let mut correct = 0i64;
    let mut total = 0i64;
    for b in 0..n_batches {
        let start = EVAL_OFFSET + (b * batch) as u64;
        let (x, y) = data.batch(start, batch);
        let (l, c) = eval_step(w, x, y)?;
        loss += l as f64;
        correct += c as i64;
        total += batch as i64 * per;
    }
    Ok((loss / n_batches as f64, correct as f64 / total as f64))
}
