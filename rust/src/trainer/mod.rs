//! Distributed SGD trainers (paper §5).
//!
//! Two execution planes share the same algorithm semantics:
//!
//! * [`threaded`] — the *deployable framework*: real PS server threads,
//!   real simulated-MPI clients, the KVStore-MPI API over the dependency
//!   engine, gradients through PJRT. Wall-clock timing. This is what the
//!   quickstart / e2e examples run.
//! * [`sim`] — the *paper-figure plane*: identical algorithm semantics and
//!   identical (real) gradient numerics, but the time axis is the
//!   [`netsim`](crate::netsim) virtual clock with the paper's testbed
//!   α-β-γ constants, so Figs 11–14/16 regenerate deterministically on
//!   hardware the paper's cluster does not resemble.

pub mod sim;
pub mod threaded;

use crate::runtime::XData;

/// First sample index of the held-out validation shard. Training shards
/// draw from [0, samples_per_epoch); validation draws from here up — same
/// generative distribution, guaranteed-disjoint samples.
pub const EVAL_OFFSET: u64 = 1 << 40;

/// Whether ESGD's elastic sync fires after iteration `iter` (Fig. 8):
/// every `interval` iterations *after* local progress — `(iter + 1)`, not
/// `iter`, so iteration 0 makes local progress before any push — with
/// `interval == 0` clamped to sync every iteration rather than dividing
/// by zero. Shared by both execution planes so the lazy-sync schedule
/// exists exactly once.
pub fn esgd_sync_due(iter: u64, interval: usize) -> bool {
    (iter + 1) % (interval.max(1) as u64) == 0
}

/// Batch provider shared by both trainers: synthetic Gaussian-mixture
/// images (f32 models) or the tiny token corpus (i32 models).
pub enum TrainData {
    Gaussian(crate::data::GaussianMixture),
    Corpus { corpus: crate::data::TinyCorpus, seq: usize },
}

impl TrainData {
    /// Build from a model's metadata + experiment config.
    pub fn for_model(meta: &crate::runtime::ModelMeta, noise: f32, classes: usize, seed: u64) -> Self {
        if meta.x_dtype == "int32" {
            let vocab = meta.config_num("vocab").unwrap_or(64.0) as usize;
            let seq = meta.x_shape[1] as usize;
            TrainData::Corpus { corpus: crate::data::TinyCorpus::new(vocab, seed), seq }
        } else {
            let dim = meta.x_shape[1] as usize;
            TrainData::Gaussian(crate::data::GaussianMixture::new(dim, classes, noise, seed))
        }
    }

    /// Materialize the batch starting at sample index `start`.
    pub fn batch(&self, start: u64, batch: usize) -> (XData, Vec<i32>) {
        match self {
            TrainData::Gaussian(g) => {
                let b = g.batch(start, batch);
                (XData::F32(b.x), b.y)
            }
            TrainData::Corpus { corpus, seq } => {
                let (x, y) = corpus.batch_tokens(start, batch, *seq);
                (XData::I32(x), y)
            }
        }
    }
}
