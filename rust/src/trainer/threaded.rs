//! The deployable threaded trainer: §5's three algorithms over the real
//! KVStore-MPI stack (launcher -> scheduler/servers/MPI clients -> engine
//! -> PJRT).
//!
//! Faithful to the paper's pseudo-code:
//!
//! * **SGD** (Fig. 6): push per-key gradients, pull the *aggregated
//!   gradient* back (server runs `Assign`), `SGD.Update` locally with
//!   `rescale = 1/mini_batch_size`. MPI modes pre-aggregate inside the
//!   client ring, and only masters talk to the PS.
//! * **ASGD** (Fig. 7): `set_optimizer(SGD, rescale)` ships the update to
//!   the server; workers push gradients and pull *parameters*.
//! * **ESGD** (Fig. 8): server runs `Elastic1` on pushed *weights*; every
//!   `INTERVAL` iterations the worker pushes params, pulls centers and
//!   applies `Elastic2`; plain SGD locally in between.
//!
//! **Elasticity** (the PS-task half of the paper's §1–§2 thesis): with a
//! [`FaultPlan`](crate::ps::FaultPlan) in the config, workers run through
//! membership-epoch boundaries — dying ranks checkpoint-and-leave at the
//! boundary (fail-stop, the cloud-preemption model), survivors swap in the
//! rebuilt client world and renormalize their gradient averages to the
//! live worker count, and joiners bootstrap from the PS checkpoint blob
//! (or by peer broadcast when `#servers == 0`), bitwise-identically to a
//! never-left rank.

use crate::config::{Algo, ExperimentConfig};
use crate::launcher::{launch, ElasticHub, EpochView, JobSpec, WorkerCtx};
use crate::metrics::{EpochRecord, RunResult};
use crate::optimizer::{Assign, Elastic1, Sgd, SgdHyper};
use crate::runtime::service::{ModelHandle, ModelService};
use crate::tensor::SegmentTable;
use crate::trainer::TrainData;
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Checkpoint blob key for a client's replica: params at `which == 0`,
/// momentum at `which == 1`. Per-client because ESGD replicas differ
/// across clients (sync replicas are identical, so per-client is merely
/// redundant there).
fn ckpt_key(client: usize, which: usize) -> usize {
    client * 2 + which
}

/// Simulated slowdown per iteration per unit of straggle factor (threaded
/// plane only; the sim plane prices straggle on the virtual clock).
const STRAGGLE_BASE: std::time::Duration = std::time::Duration::from_millis(1);

/// Train with the given config on the threaded stack; returns per-epoch
/// records (wall-clock time axis) as measured on worker 0.
pub fn train(cfg: &ExperimentConfig, artifacts_dir: PathBuf) -> Result<RunResult> {
    let service = ModelService::spawn(artifacts_dir, &cfg.variant)?;
    let mut spec = JobSpec::from_config(cfg);
    spec.fault = cfg.fault_plan()?;
    let cfg = Arc::new(cfg.clone());
    let handle = service.handle();
    if let Some(last) = spec.fault.last_iter() {
        // Surface a semantically invalid plan (dead rank, emptied client
        // 0, …) as a clean error here rather than a panic inside launch.
        ElasticHub::new(&spec, crate::ps::Scheduler::new(0, 0), None)
            .context("invalid fault plan for this job")?;
        // A joiner whose admission boundary lies past the final iteration
        // would park forever and hang the job on shutdown.
        let shard = crate::data::Shard {
            worker: 0,
            n_workers: cfg.workers,
            total: cfg.samples_per_epoch,
            batch: handle.meta.batch_size(),
            epoch: 0,
        };
        let total_iters = cfg.epochs as u64 * shard.batches_per_epoch().max(1);
        ensure!(
            last < total_iters,
            "fault plan event at iteration {last} never fires: the run has \
             only {total_iters} iterations"
        );
    }

    let cfg2 = cfg.clone();
    let results = launch(&spec, move |ctx| {
        worker_loop(&cfg2, handle.clone(), ctx)
    });

    // Worker 0 carries the validation records.
    let records = results.into_iter().next().unwrap()?;
    Ok(RunResult::finish(cfg.algo.name(), records))
}

/// Per-key slices of a flat vector, in key order.
fn split_keys(segs: &SegmentTable, flat: &[f32]) -> Vec<Vec<f32>> {
    (0..segs.len()).map(|k| segs.slice(flat, k).to_vec()).collect()
}

fn join_keys(segs: &SegmentTable, parts: &[Vec<f32>], flat: &mut [f32]) {
    for (k, part) in parts.iter().enumerate() {
        segs.slice_mut(flat, k).copy_from_slice(part);
    }
}

fn worker_loop(
    cfg: &ExperimentConfig,
    model: ModelHandle,
    ctx: WorkerCtx,
) -> Result<Vec<EpochRecord>> {
    let meta = model.meta.clone();
    let segs = meta.segments.clone();
    let n_keys = segs.len();
    let data = TrainData::for_model(&meta, cfg.noise, cfg.classes, cfg.seed);
    let batch = meta.batch_size();

    // --- Init: PS rank 0 initializes every key; pure MPI broadcasts.
    // Joiners skip the whole section: every key was initialized at launch,
    // and the serverless init path is a *collective* bcast the survivors
    // would never re-enter — a joiner's replica comes from the bootstrap
    // below instead.
    let mut w = meta.init_params()?;
    let is_root = ctx.ps_rank == 0;
    let init_parts = split_keys(&segs, &w);
    match cfg.algo {
        _ if ctx.join_view.is_some() => {}
        Algo::DistSgd | Algo::MpiSgd => {
            // Keys hold aggregated gradients (Fig. 6): init zeros.
            for k in 0..n_keys {
                ctx.kv.init(k, vec![0.0; segs.segments[k].size], is_root);
            }
            if is_root {
                ctx.kv.set_optimizer(|| Box::new(Assign));
            }
        }
        Algo::DistAsgd | Algo::MpiAsgd => {
            // Keys hold parameters; server runs the shipped SGD (Fig. 7).
            // Each push is one client's aggregate of `workers_per_client`
            // per-batch *mean* gradients, so the server rescales by the
            // worker count it aggregates (§5: 1/mini_batch_size, with our
            // gradients already averaged over the batch dimension).
            for (k, part) in init_parts.iter().enumerate() {
                ctx.kv.init(k, part.clone(), is_root);
            }
            if is_root {
                // Fig. 7 ships plain SGD: with several clients updating
                // asynchronously, momentum would compound their (stale)
                // gradients and diverge.
                // lr is divided by the client count so the *aggregate*
                // async step rate matches the synchronous one (standard
                // async-SGD stabilization).
                let hyper = SgdHyper {
                    lr: cfg.lr / cfg.clients as f32,
                    momentum: 0.0,
                    weight_decay: cfg.weight_decay,
                    rescale: 1.0 / cfg.workers_per_client() as f32,
                };
                ctx.kv.set_optimizer(move || Box::new(Sgd::new(hyper)));
            }
        }
        Algo::DistEsgd | Algo::MpiEsgd => {
            // Keys hold center variables (Fig. 8).
            for (k, part) in init_parts.iter().enumerate() {
                ctx.kv.init(k, part.clone(), is_root);
            }
            if is_root {
                let alpha = cfg.alpha;
                ctx.kv.set_optimizer(move || Box::new(Elastic1 { alpha }));
            }
        }
    }

    // Iteration schedule: fixed by the launch population (membership
    // changes re-map shard *contents*, never the boundary schedule, so
    // every rank agrees on boundary iterations).
    let batches = (crate::data::Shard {
        worker: ctx.ps_rank.min(ctx.n_workers - 1),
        n_workers: ctx.n_workers,
        total: cfg.samples_per_epoch,
        batch,
        epoch: 0,
    })
    .batches_per_epoch()
    .max(1) as usize;
    // Momentum is used only by the synchronous modes (Fig. 6's local
    // SGD.Update on the exact aggregated gradient); ESGD's local updates
    // follow Fig. 8's plain SGD.
    let local_momentum = match cfg.algo {
        Algo::DistSgd | Algo::MpiSgd => cfg.momentum,
        _ => 0.0,
    };
    // Our gradients are per-batch *means*, so the local rescale divides by
    // the number of workers whose gradients were aggregated before the
    // update (§5's 1/mini_batch_size in sample terms). Recomputed per
    // membership epoch: survivors renormalize to the live population.
    let aggregated_workers = |m_live: usize, live_workers: usize| match cfg.algo {
        Algo::DistSgd | Algo::MpiSgd => live_workers,
        Algo::MpiEsgd => m_live,
        _ => 1,
    };

    // Live-membership state, advanced at each epoch boundary.
    let mut m_live = ctx.workers_per_client;
    let mut live_workers = ctx.n_workers;
    let mut shard_worker = ctx.ps_rank;
    let mut epochs_done: u64 = 0;
    let mut straggle = 1.0f64;
    let start_iter = match &ctx.join_view {
        Some(view) => {
            m_live = view.workers_per_client;
            live_workers = view.live_workers;
            shard_worker = view.shard_index;
            epochs_done = view.epoch;
            straggle = view.straggle;
            view.boundary_iter + 1
        }
        None => 0,
    };
    let mut local_hyper = SgdHyper {
        lr: cfg.lr,
        momentum: local_momentum,
        weight_decay: cfg.weight_decay,
        rescale: 1.0 / aggregated_workers(m_live, live_workers) as f32,
    };
    let mut momentum = vec![0.0f32; meta.params];

    // Joiner bootstrap: adopt the client replica before the first step —
    // from the PS checkpoint blob, or by peer broadcast when #servers == 0
    // (handled by bootstrap_bcast below, which every member runs).
    if let Some(view) = &ctx.join_view {
        if cfg.servers > 0 {
            w = ctx.kv.ckpt_load(ckpt_key(ctx.client_id, 0)).unwrap_or_else(|| {
                panic!(
                    "joiner rank {} found no checkpoint for client {}: a \
                     fresh client needs a PS checkpoint to bootstrap from",
                    ctx.ps_rank, ctx.client_id
                )
            });
            if local_momentum != 0.0 {
                momentum = ctx
                    .kv
                    .ckpt_load(ckpt_key(ctx.client_id, 1))
                    .unwrap_or_else(|| vec![0.0f32; meta.params]);
            }
        }
        bootstrap_bcast(cfg, &ctx, view, &mut w, &mut momentum, local_momentum);
    }

    let mut records = Vec::new();
    let start = Instant::now();
    let total_iters = cfg.epochs * batches;
    let mut iter = start_iter as usize;
    let mut train_loss_sum = 0.0f64;

    while iter < total_iters {
        let epoch = iter / batches;
        let b = iter % batches;
        if b == 0 {
            train_loss_sum = 0.0;
        }
        let shard = crate::data::Shard {
            worker: shard_worker,
            n_workers: live_workers,
            total: cfg.samples_per_epoch,
            batch,
            epoch: epoch as u64,
        };
        if straggle > 1.0 {
            // Injected slowdown (FaultPlan straggle): the threaded plane's
            // stand-in for a slow host.
            std::thread::sleep(STRAGGLE_BASE.mul_f64(straggle - 1.0));
        }
        {
            let (x, y) = data.batch(shard.batch_start(b as u64), batch);
            let (loss, grads) = model.grad_step(&w, x, y)?;
            train_loss_sum += loss as f64;

            match cfg.algo {
                Algo::DistSgd | Algo::MpiSgd => {
                    // Fig. 6: push grads per key, pull aggregated grads.
                    // With no servers, PushPull degrades to the pure-MPI
                    // allreduce (§4.2.4), issued as one nonblocking engine
                    // op *per fusion bucket* in backward (reverse-key)
                    // order — the order backprop emits gradients — so
                    // bucket i's SGD.Update overlaps bucket i+1's
                    // allreduce (DAG-embedded collectives,
                    // arXiv:1802.06949). Results are bitwise identical to
                    // the old fused-then-update path: the same bucketed
                    // sums feed the same elementwise update.
                    let parts = split_keys(&segs, &grads);
                    if cfg.servers == 0 {
                        let keyed: Vec<(usize, Vec<f32>)> =
                            parts.into_iter().enumerate().collect();
                        for ((i, j), pending) in ctx.kv.pushpull_buckets(keyed) {
                            let agg = pending.wait();
                            let lo = segs.segments[i].offset;
                            let hi = segs.segments[j - 1].offset + segs.segments[j - 1].size;
                            let mut g_seg = Vec::with_capacity(hi - lo);
                            for part in &agg {
                                g_seg.extend_from_slice(part);
                            }
                            let mut w_seg = w[lo..hi].to_vec();
                            let mut m_seg = momentum[lo..hi].to_vec();
                            model.sgd_update(&mut w_seg, &g_seg, &mut m_seg, &local_hyper)?;
                            w[lo..hi].copy_from_slice(&w_seg);
                            momentum[lo..hi].copy_from_slice(&m_seg);
                        }
                    } else {
                        for (k, part) in parts.into_iter().enumerate() {
                            ctx.kv.push(k, part);
                        }
                        let pulls: Vec<_> = (0..n_keys).map(|k| ctx.kv.pull(k)).collect();
                        let agg: Vec<Vec<f32>> =
                            pulls.into_iter().map(|p| p.wait()).collect();
                        let mut g_sum = vec![0.0f32; meta.params];
                        join_keys(&segs, &agg, &mut g_sum);
                        model.sgd_update(&mut w, &g_sum, &mut momentum, &local_hyper)?;
                    }
                }
                Algo::DistAsgd | Algo::MpiAsgd => {
                    // Fig. 7: push grads, pull params.
                    let parts = split_keys(&segs, &grads);
                    for (k, part) in parts.into_iter().enumerate() {
                        ctx.kv.push(k, part);
                    }
                    let pulls: Vec<_> = (0..n_keys).map(|k| ctx.kv.pull(k)).collect();
                    let parts: Vec<Vec<f32>> = pulls.into_iter().map(|p| p.wait()).collect();
                    join_keys(&segs, &parts, &mut w);
                }
                Algo::DistEsgd | Algo::MpiEsgd => {
                    // Fig. 8. For MPI clients, keep replicas in lockstep by
                    // averaging gradients inside the client each iteration
                    // (sync SGD within the communicator, §5) — pushpull on
                    // a pure-MPI kvstore is the allreduce; with servers we
                    // reuse pushpull composition only at INTERVALs, so the
                    // intra-client allreduce here goes through the comm.
                    let mut g = grads;
                    if cfg.algo == Algo::MpiEsgd && m_live > 1 {
                        // Aggregate inside the client (ring allreduce).
                        g = ctx.kv.client_allreduce(g).wait();
                    }
                    model.sgd_update(&mut w, &g, &mut momentum, &local_hyper)?;
                    // Fig. 8's lazy sync schedule (shared helper).
                    if crate::trainer::esgd_sync_due(iter as u64, cfg.interval) {
                        // Push params (Fig. 8 l.10). The MPI kvstore's push
                        // ring-SUMS across the client; replicas are kept in
                        // lockstep, so pre-scale by 1/m to push the client
                        // average (= w) rather than m*w.
                        let scale = 1.0 / m_live as f32;
                        let mut w_avg = w.clone();
                        crate::tensor::scale(&mut w_avg, scale);
                        let parts = split_keys(&segs, &w_avg);
                        for (k, part) in parts.into_iter().enumerate() {
                            ctx.kv.push(k, part);
                        }
                        let pulls: Vec<_> = (0..n_keys).map(|k| ctx.kv.pull(k)).collect();
                        let centers: Vec<Vec<f32>> =
                            pulls.into_iter().map(|p| p.wait()).collect();
                        let mut c = vec![0.0f32; meta.params];
                        join_keys(&segs, &centers, &mut c);
                        model.elastic2(&mut w, &c, cfg.alpha)?; // Fig. 8 l.12
                    }
                }
            }
        }

        // --- membership-epoch boundary (elastic jobs only) ---------------
        if let Some(hub) = &ctx.hub {
            if hub.boundary_iter(epochs_done) == Some(iter as u64) {
                // Quiesce: every comm op of this epoch must complete
                // before the world is torn down or swapped.
                ctx.kv.wait_all();
                // The lowest surviving member of each client persists the
                // client replica through the PS *before* the barrier, so
                // joiners and restarted ranks bootstrap from this exact
                // boundary's state.
                if cfg.servers > 0
                    && hub.ckpt_master(epochs_done, ctx.client_id) == Some(ctx.ps_rank)
                {
                    ctx.kv.ckpt_save(ckpt_key(ctx.client_id, 0), w.clone());
                    if local_momentum != 0.0 {
                        ctx.kv.ckpt_save(ckpt_key(ctx.client_id, 1), momentum.clone());
                    }
                }
                if hub.dying_at(epochs_done).contains(&ctx.ps_rank) {
                    // Fail-stop at the boundary (cooperative preemption):
                    // no hub call — the barrier never waits on the dead.
                    return Ok(records);
                }
                let handout = hub.reconfigure(ctx.ps_rank);
                let view = handout.view;
                if let Some(comm) = handout.comm {
                    drop(ctx.kv.replace_comm(comm));
                }
                // Survivors renormalize: averages span the live set now.
                m_live = view.workers_per_client;
                live_workers = view.live_workers;
                shard_worker = view.shard_index;
                straggle = view.straggle;
                epochs_done = view.epoch;
                local_hyper.rescale =
                    1.0 / aggregated_workers(m_live, live_workers) as f32;
                bootstrap_bcast(cfg, &ctx, &view, &mut w, &mut momentum, local_momentum);
            }
        }

        // Validation on worker 0 (paper: after every epoch).
        if b == batches - 1 && ctx.ps_rank == 0 {
            let (vl, va) = evaluate(cfg, &model, &data, &w)?;
            records.push(EpochRecord {
                epoch,
                vtime: start.elapsed().as_secs_f64(),
                train_loss: train_loss_sum / batches as f64,
                val_loss: vl,
                val_acc: va,
            });
        }
        iter += 1;
    }
    ctx.kv.wait_all();
    Ok(records)
}

/// Peer-bootstrap broadcast for serverless clients: when a client gained
/// joiners at this boundary and there is no PS checkpoint to pull, every
/// member broadcasts-in the lowest *survivor*'s replica (joiners receive
/// it bitwise; survivors pass theirs through unchanged). No-op when the
/// client has no joiners or a PS exists.
fn bootstrap_bcast(
    cfg: &ExperimentConfig,
    ctx: &WorkerCtx,
    view: &EpochView,
    w: &mut Vec<f32>,
    momentum: &mut Vec<f32>,
    local_momentum: f32,
) {
    if cfg.servers > 0 || !view.members.iter().any(|r| view.joined.contains(r)) {
        return;
    }
    let root = view
        .members
        .iter()
        .position(|r| !view.joined.contains(r))
        .expect("a client of only joiners needs a PS checkpoint to bootstrap");
    *w = ctx.kv.client_bcast(root, std::mem::take(w)).wait();
    if local_momentum != 0.0 {
        *momentum = ctx.kv.client_bcast(root, std::mem::take(momentum)).wait();
    }
}

/// Validation loss/accuracy over `cfg.eval_samples` held-out samples.
///
/// Same distribution as training (same mixture centers / successor
/// table), disjoint sample indices: the held-out shard lives past
/// [`crate::trainer::EVAL_OFFSET`].
pub fn evaluate(
    cfg: &ExperimentConfig,
    model: &ModelHandle,
    data: &TrainData,
    w: &[f32],
) -> Result<(f64, f64)> {
    let batch = model.meta.batch_size();
    let n_batches = (cfg.eval_samples as usize / batch).max(1);
    let mut loss = 0.0f64;
    let mut correct = 0i64;
    let mut total = 0i64;
    let per = match data {
        TrainData::Gaussian(_) => 1,
        TrainData::Corpus { seq, .. } => *seq as i64,
    };
    for b in 0..n_batches {
        let start = crate::trainer::EVAL_OFFSET + (b * batch) as u64;
        let (x, y) = data.batch(start, batch);
        let (l, c) = model.eval_step(w, x, y)?;
        loss += l as f64;
        correct += c as i64;
        total += batch as i64 * per;
    }
    Ok((loss / n_batches as f64, correct as f64 / total as f64))
}
