//! The deployable threaded trainer: one strategy execution loop over the
//! real KVStore-MPI stack (launcher -> scheduler/servers/MPI clients ->
//! engine -> PJRT).
//!
//! The per-algorithm behaviour — what the keys hold, which optimizer the
//! PS runs, what moves on the wire each iteration — lives entirely in
//! [`SyncStrategy`](crate::trainer::strategies::SyncStrategy) objects
//! resolved from the algorithm registry; this file only owns what every
//! algorithm shares: the batch schedule, gradient computation, the
//! membership-epoch (elasticity) protocol and validation.
//!
//! **Elasticity** (the PS-task half of the paper's §1–§2 thesis): with a
//! [`FaultPlan`](crate::ps::FaultPlan) in the config, workers run through
//! membership-epoch boundaries — dying ranks checkpoint-and-leave at the
//! boundary (fail-stop, the cloud-preemption model), survivors swap in the
//! rebuilt client world and renormalize their gradient averages to the
//! live worker count, and joiners bootstrap from the PS checkpoint blob
//! (or by peer broadcast when `#servers == 0`), bitwise-identically to a
//! never-left rank. Boundaries ride the strategy's declared sync cadence
//! ([`SyncStrategy::sync_every`](crate::trainer::strategies::SyncStrategy::sync_every)),
//! so elastic scheduling needs no per-algorithm special cases.

use crate::config::ExperimentConfig;
use crate::launcher::{launch, ElasticHub, EpochView, JobSpec, WorkerCtx};
use crate::metrics::{EpochRecord, RunResult};
use crate::runtime::service::{ModelHandle, ModelService};
use crate::trainer::strategies::{local_hyper_counts, split_keys, WorkerInit, WorkerStep};
use crate::trainer::TrainData;
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Checkpoint blob key for a client's replica: params at `which == 0`,
/// momentum at `which == 1`. Per-client because lazy-sync replicas differ
/// across clients (sync replicas are identical, so per-client is merely
/// redundant there).
fn ckpt_key(client: usize, which: usize) -> usize {
    client * 2 + which
}

/// Simulated slowdown per iteration per unit of straggle factor (threaded
/// plane only; the sim plane prices straggle on the virtual clock).
const STRAGGLE_BASE: std::time::Duration = std::time::Duration::from_millis(1);

/// Train with the given config on the threaded stack; returns per-epoch
/// records (wall-clock time axis) as measured on worker 0.
pub fn train(cfg: &ExperimentConfig, artifacts_dir: PathBuf) -> Result<RunResult> {
    Ok(train_with_weights(cfg, artifacts_dir)?.0)
}

/// [`train`], additionally returning worker 0's final parameters — the
/// cross-plane bitwise equivalence property is asserted against these.
pub fn train_with_weights(
    cfg: &ExperimentConfig,
    artifacts_dir: PathBuf,
) -> Result<(RunResult, Vec<f32>)> {
    // Kernel results are bitwise independent of the thread count, so
    // applying the knob here cannot perturb the cross-plane properties.
    crate::runtime::par::set_threads(cfg.threads);
    let service = ModelService::spawn(artifacts_dir, &cfg.variant)?;
    let mut spec = JobSpec::from_config(cfg);
    spec.fault = cfg.fault_plan()?;
    let cfg = Arc::new(cfg.clone());
    let handle = service.handle();
    if let Some(last) = spec.fault.last_iter() {
        // Surface a semantically invalid plan (dead rank, emptied client
        // 0, …) as a clean error here rather than a panic inside launch.
        ElasticHub::new(&spec, crate::ps::Scheduler::new(0, 0), None)
            .context("invalid fault plan for this job")?;
        // A joiner whose admission boundary lies past the final iteration
        // would park forever and hang the job on shutdown.
        let shard = crate::data::Shard {
            worker: 0,
            n_workers: cfg.workers,
            total: cfg.samples_per_epoch,
            batch: handle.meta.batch_size(),
            epoch: 0,
        };
        let total_iters = cfg.epochs as u64 * shard.batches_per_epoch().max(1);
        ensure!(
            last < total_iters,
            "fault plan event at iteration {last} never fires: the run has \
             only {total_iters} iterations"
        );
    }

    let cfg2 = cfg.clone();
    let results = launch(&spec, move |ctx| {
        worker_loop(&cfg2, handle.clone(), ctx)
    })?;

    // Worker 0 carries the validation records.
    let (records, w) = results.into_iter().next().unwrap()?;
    Ok((RunResult::finish(cfg.algo.name(), records), w))
}

fn worker_loop(
    cfg: &ExperimentConfig,
    model: ModelHandle,
    ctx: WorkerCtx,
) -> Result<(Vec<EpochRecord>, Vec<f32>)> {
    let strategy = cfg.algo.strategy();
    let meta = model.meta.clone();
    let segs = meta.segments.clone();
    let n_keys = segs.len();
    let data = TrainData::for_model(&meta, cfg.noise, cfg.classes, cfg.seed);
    let batch = meta.batch_size();

    // --- Init: the strategy decides what the keys hold and which
    // optimizer the PS runs. Joiners skip the whole section: every key was
    // initialized at launch, and serverless init paths are *collective*
    // the survivors would never re-enter — a joiner's replica comes from
    // the bootstrap below instead.
    let mut w = meta.init_params()?;
    let is_root = ctx.ps_rank == 0;
    if ctx.join_view.is_none() {
        let init_parts = split_keys(&segs, &w);
        strategy.init(
            cfg,
            &mut WorkerInit {
                kv: &ctx.kv,
                segs: &segs,
                init_parts: &init_parts,
                is_root,
            },
        )?;
    }

    // Iteration schedule: fixed by the launch population (membership
    // changes re-map shard *contents*, never the boundary schedule, so
    // every rank agrees on boundary iterations).
    let batches = (crate::data::Shard {
        worker: ctx.ps_rank.min(ctx.n_workers - 1),
        n_workers: ctx.n_workers,
        total: cfg.samples_per_epoch,
        batch,
        epoch: 0,
    })
    .batches_per_epoch()
    .max(1) as usize;
    // Momentum policy and the §5 rescale denominator are strategy
    // declarations; the denominator is recomputed per membership epoch so
    // survivors renormalize to the live population.
    let local_momentum = strategy.local_momentum(cfg);

    // Live-membership state, advanced at each epoch boundary.
    let mut m_live = ctx.workers_per_client;
    let mut live_workers = ctx.n_workers;
    let mut live_clients = ctx.n_clients;
    let mut shard_worker = ctx.ps_rank;
    let mut epochs_done: u64 = 0;
    let mut straggle = 1.0f64;
    let start_iter = match &ctx.join_view {
        Some(view) => {
            m_live = view.workers_per_client;
            live_workers = view.live_workers;
            live_clients = view.live_clients;
            shard_worker = view.shard_index;
            epochs_done = view.epoch;
            straggle = view.straggle;
            view.boundary_iter + 1
        }
        None => 0,
    };
    let mut local_hyper = local_hyper_counts(strategy, cfg, m_live, live_workers);
    let mut momentum = vec![0.0f32; meta.params];

    // Joiner bootstrap: adopt the client replica before the first step —
    // from the PS checkpoint blob, or by peer broadcast when #servers == 0
    // (handled by bootstrap_bcast below, which every member runs).
    if let Some(view) = &ctx.join_view {
        if cfg.servers > 0 {
            w = ctx.kv.ckpt_load(ckpt_key(ctx.client_id, 0)).unwrap_or_else(|| {
                panic!(
                    "joiner rank {} found no checkpoint for client {}: a \
                     fresh client needs a PS checkpoint to bootstrap from",
                    ctx.ps_rank, ctx.client_id
                )
            });
            if local_momentum != 0.0 {
                momentum = ctx
                    .kv
                    .ckpt_load(ckpt_key(ctx.client_id, 1))
                    .unwrap_or_else(|| vec![0.0f32; meta.params]);
            }
        }
        bootstrap_bcast(cfg, &ctx, view, &mut w, &mut momentum, local_momentum);
    }

    let mut records = Vec::new();
    let start = Instant::now();
    let total_iters = cfg.epochs * batches;
    let mut iter = start_iter as usize;
    let mut train_loss_sum = 0.0f64;

    while iter < total_iters {
        let epoch = iter / batches;
        let b = iter % batches;
        if b == 0 {
            train_loss_sum = 0.0;
        }
        let shard = crate::data::Shard {
            worker: shard_worker,
            n_workers: live_workers,
            total: cfg.samples_per_epoch,
            batch,
            epoch: epoch as u64,
        };
        if straggle > 1.0 {
            // Injected slowdown (FaultPlan straggle): the threaded plane's
            // stand-in for a slow host.
            std::thread::sleep(STRAGGLE_BASE.mul_f64(straggle - 1.0));
        }
        {
            // Device tier: the worker batch is split into k shards of b/k
            // rows, one real gradient per device (b/k-row kernels), then
            // the local tier merges them into the one leader buffer the
            // wire schedules see. devices == 1 is the exact legacy path:
            // one full-batch grad_step, merge untouched.
            let (loss, dev_grads) = crate::trainer::device_grad_shards(
                &data,
                shard.batch_start(b as u64),
                batch,
                cfg.devices,
                |x, y, rows| model.grad_step_rows(&w, x, y, rows),
            )?;
            train_loss_sum += loss as f64;
            let grads = ctx.kv.local_merge(dev_grads, shard_worker as u64);

            // The one strategy dispatch of the loop: everything between
            // this gradient and the next batch belongs to the algorithm.
            let mut st = WorkerStep {
                kv: &ctx.kv,
                model: &model,
                segs: &segs,
                n_keys,
                iter: iter as u64,
                w: &mut w,
                momentum: &mut momentum,
                grads,
                hyper: local_hyper,
                m_live,
                live_workers,
                live_clients,
                servers: cfg.servers,
            };
            strategy.step(cfg, &mut st)?;
        }

        // --- membership-epoch boundary (elastic jobs only) ---------------
        if let Some(hub) = &ctx.hub {
            if hub.boundary_iter(epochs_done) == Some(iter as u64) {
                // Quiesce: every comm op of this epoch must complete
                // before the world is torn down or swapped.
                ctx.kv.wait_all();
                // The lowest surviving member of each client persists the
                // client replica through the PS *before* the barrier, so
                // joiners and restarted ranks bootstrap from this exact
                // boundary's state.
                if cfg.servers > 0
                    && hub.ckpt_master(epochs_done, ctx.client_id) == Some(ctx.ps_rank)
                {
                    ctx.kv.ckpt_save(ckpt_key(ctx.client_id, 0), w.clone());
                    if local_momentum != 0.0 {
                        ctx.kv.ckpt_save(ckpt_key(ctx.client_id, 1), momentum.clone());
                    }
                }
                if hub.dying_at(epochs_done).contains(&ctx.ps_rank) {
                    // Fail-stop at the boundary (cooperative preemption):
                    // no hub call — the barrier never waits on the dead.
                    return Ok((records, w));
                }
                let handout = hub.reconfigure(ctx.ps_rank);
                let view = handout.view;
                if let Some(comm) = handout.comm {
                    drop(ctx.kv.replace_comm(comm));
                }
                // Survivors renormalize: averages span the live set now.
                m_live = view.workers_per_client;
                live_workers = view.live_workers;
                live_clients = view.live_clients;
                shard_worker = view.shard_index;
                straggle = view.straggle;
                epochs_done = view.epoch;
                local_hyper = local_hyper_counts(strategy, cfg, m_live, live_workers);
                bootstrap_bcast(cfg, &ctx, &view, &mut w, &mut momentum, local_momentum);
            }
        }

        // Validation on worker 0 (paper: after every epoch), through the
        // shared evaluator in trainer/mod.rs.
        if b == batches - 1 && ctx.ps_rank == 0 {
            let (vl, va) = crate::trainer::evaluate(
                &data,
                cfg.eval_samples,
                batch,
                &w,
                |w, x, y| model.eval_step(w, x, y),
            )?;
            records.push(EpochRecord {
                epoch,
                vtime: start.elapsed().as_secs_f64(),
                train_loss: train_loss_sum / batches as f64,
                val_loss: vl,
                val_acc: va,
            });
        }
        iter += 1;
    }
    ctx.kv.wait_all();
    Ok((records, w))
}

/// Peer-bootstrap broadcast for serverless clients: when a client gained
/// joiners at this boundary and there is no PS checkpoint to pull, every
/// member broadcasts-in the lowest *survivor*'s replica (joiners receive
/// it bitwise; survivors pass theirs through unchanged). No-op when the
/// client has no joiners or a PS exists.
fn bootstrap_bcast(
    cfg: &ExperimentConfig,
    ctx: &WorkerCtx,
    view: &EpochView,
    w: &mut Vec<f32>,
    momentum: &mut Vec<f32>,
    local_momentum: f32,
) {
    if cfg.servers > 0 || !view.members.iter().any(|r| view.joined.contains(r)) {
        return;
    }
    let root = view
        .members
        .iter()
        .position(|r| !view.joined.contains(r))
        .expect("a client of only joiners needs a PS checkpoint to bootstrap");
    *w = ctx.kv.client_bcast(root, std::mem::take(w)).wait();
    if local_momentum != 0.0 {
        *momentum = ctx.kv.client_bcast(root, std::mem::take(momentum)).wait();
    }
}
