//! The deployable threaded trainer: §5's three algorithms over the real
//! KVStore-MPI stack (launcher -> scheduler/servers/MPI clients -> engine
//! -> PJRT).
//!
//! Faithful to the paper's pseudo-code:
//!
//! * **SGD** (Fig. 6): push per-key gradients, pull the *aggregated
//!   gradient* back (server runs `Assign`), `SGD.Update` locally with
//!   `rescale = 1/mini_batch_size`. MPI modes pre-aggregate inside the
//!   client ring, and only masters talk to the PS.
//! * **ASGD** (Fig. 7): `set_optimizer(SGD, rescale)` ships the update to
//!   the server; workers push gradients and pull *parameters*.
//! * **ESGD** (Fig. 8): server runs `Elastic1` on pushed *weights*; every
//!   `INTERVAL` iterations the worker pushes params, pulls centers and
//!   applies `Elastic2`; plain SGD locally in between.

use crate::config::{Algo, ExperimentConfig};
use crate::launcher::{launch, JobSpec, WorkerCtx};
use crate::metrics::{EpochRecord, RunResult};
use crate::optimizer::{Assign, Elastic1, Sgd, SgdHyper};
use crate::runtime::service::{ModelHandle, ModelService};
use crate::tensor::SegmentTable;
use crate::trainer::TrainData;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Train with the given config on the threaded stack; returns per-epoch
/// records (wall-clock time axis) as measured on worker 0.
pub fn train(cfg: &ExperimentConfig, artifacts_dir: PathBuf) -> Result<RunResult> {
    let service = ModelService::spawn(artifacts_dir, &cfg.variant)?;
    let spec = JobSpec::from_config(cfg);
    let cfg = Arc::new(cfg.clone());
    let handle = service.handle();

    let cfg2 = cfg.clone();
    let results = launch(&spec, move |ctx| {
        worker_loop(&cfg2, handle.clone(), ctx)
    });

    // Worker 0 carries the validation records.
    let records = results.into_iter().next().unwrap()?;
    Ok(RunResult::finish(cfg.algo.name(), records))
}

/// Per-key slices of a flat vector, in key order.
fn split_keys(segs: &SegmentTable, flat: &[f32]) -> Vec<Vec<f32>> {
    (0..segs.len()).map(|k| segs.slice(flat, k).to_vec()).collect()
}

fn join_keys(segs: &SegmentTable, parts: &[Vec<f32>], flat: &mut [f32]) {
    for (k, part) in parts.iter().enumerate() {
        segs.slice_mut(flat, k).copy_from_slice(part);
    }
}

fn worker_loop(
    cfg: &ExperimentConfig,
    model: ModelHandle,
    ctx: WorkerCtx,
) -> Result<Vec<EpochRecord>> {
    let meta = model.meta.clone();
    let segs = meta.segments.clone();
    let n_keys = segs.len();
    let data = TrainData::for_model(&meta, cfg.noise, cfg.classes, cfg.seed);
    let batch = meta.batch_size();

    // --- Init: PS rank 0 initializes every key; pure MPI broadcasts.
    let mut w = meta.init_params()?;
    let is_root = ctx.ps_rank == 0;
    let init_parts = split_keys(&segs, &w);
    match cfg.algo {
        Algo::DistSgd | Algo::MpiSgd => {
            // Keys hold aggregated gradients (Fig. 6): init zeros.
            for k in 0..n_keys {
                ctx.kv.init(k, vec![0.0; segs.segments[k].size], is_root);
            }
            if is_root {
                ctx.kv.set_optimizer(|| Box::new(Assign));
            }
        }
        Algo::DistAsgd | Algo::MpiAsgd => {
            // Keys hold parameters; server runs the shipped SGD (Fig. 7).
            // Each push is one client's aggregate of `workers_per_client`
            // per-batch *mean* gradients, so the server rescales by the
            // worker count it aggregates (§5: 1/mini_batch_size, with our
            // gradients already averaged over the batch dimension).
            for (k, part) in init_parts.iter().enumerate() {
                ctx.kv.init(k, part.clone(), is_root);
            }
            if is_root {
                // Fig. 7 ships plain SGD: with several clients updating
                // asynchronously, momentum would compound their (stale)
                // gradients and diverge.
                // lr is divided by the client count so the *aggregate*
                // async step rate matches the synchronous one (standard
                // async-SGD stabilization).
                let hyper = SgdHyper {
                    lr: cfg.lr / cfg.clients as f32,
                    momentum: 0.0,
                    weight_decay: cfg.weight_decay,
                    rescale: 1.0 / cfg.workers_per_client() as f32,
                };
                ctx.kv.set_optimizer(move || Box::new(Sgd::new(hyper)));
            }
        }
        Algo::DistEsgd | Algo::MpiEsgd => {
            // Keys hold center variables (Fig. 8).
            for (k, part) in init_parts.iter().enumerate() {
                ctx.kv.init(k, part.clone(), is_root);
            }
            if is_root {
                let alpha = cfg.alpha;
                ctx.kv.set_optimizer(move || Box::new(Elastic1 { alpha }));
            }
        }
    }

    let shard = crate::data::Shard {
        worker: ctx.ps_rank,
        n_workers: ctx.n_workers,
        total: cfg.samples_per_epoch,
        batch,
        epoch: 0,
    };
    let batches = shard.batches_per_epoch().max(1);
    // Our gradients are per-batch *means*, so the local rescale divides by
    // the number of workers whose gradients were aggregated before the
    // update (§5's 1/mini_batch_size in sample terms).
    let aggregated_workers = match cfg.algo {
        Algo::DistSgd | Algo::MpiSgd => cfg.workers,
        Algo::MpiEsgd => cfg.workers_per_client(),
        _ => 1,
    };
    // Momentum is used only by the synchronous modes (Fig. 6's local
    // SGD.Update on the exact aggregated gradient); ESGD's local updates
    // follow Fig. 8's plain SGD.
    let local_momentum = match cfg.algo {
        Algo::DistSgd | Algo::MpiSgd => cfg.momentum,
        _ => 0.0,
    };
    let local_hyper = SgdHyper {
        lr: cfg.lr,
        momentum: local_momentum,
        weight_decay: cfg.weight_decay,
        rescale: 1.0 / aggregated_workers as f32,
    };
    let mut momentum = vec![0.0f32; meta.params];
    let mut records = Vec::new();
    let start = Instant::now();
    let mut iter = 0usize;

    for epoch in 0..cfg.epochs {
        let mut shard = shard.clone();
        shard.epoch = epoch as u64;
        let mut train_loss_sum = 0.0f64;
        for b in 0..batches {
            let (x, y) = data.batch(shard.batch_start(b), batch);
            let (loss, grads) = model.grad_step(&w, x, y)?;
            train_loss_sum += loss as f64;

            match cfg.algo {
                Algo::DistSgd | Algo::MpiSgd => {
                    // Fig. 6: push grads per key, pull aggregated grads.
                    // With no servers, PushPull degrades to the pure-MPI
                    // allreduce (§4.2.4), issued as one nonblocking engine
                    // op *per fusion bucket* in backward (reverse-key)
                    // order — the order backprop emits gradients — so
                    // bucket i's SGD.Update overlaps bucket i+1's
                    // allreduce (DAG-embedded collectives,
                    // arXiv:1802.06949). Results are bitwise identical to
                    // the old fused-then-update path: the same bucketed
                    // sums feed the same elementwise update.
                    let parts = split_keys(&segs, &grads);
                    if cfg.servers == 0 {
                        let keyed: Vec<(usize, Vec<f32>)> =
                            parts.into_iter().enumerate().collect();
                        for ((i, j), pending) in ctx.kv.pushpull_buckets(keyed) {
                            let agg = pending.wait();
                            let lo = segs.segments[i].offset;
                            let hi = segs.segments[j - 1].offset + segs.segments[j - 1].size;
                            let mut g_seg = Vec::with_capacity(hi - lo);
                            for part in &agg {
                                g_seg.extend_from_slice(part);
                            }
                            let mut w_seg = w[lo..hi].to_vec();
                            let mut m_seg = momentum[lo..hi].to_vec();
                            model.sgd_update(&mut w_seg, &g_seg, &mut m_seg, &local_hyper)?;
                            w[lo..hi].copy_from_slice(&w_seg);
                            momentum[lo..hi].copy_from_slice(&m_seg);
                        }
                    } else {
                        for (k, part) in parts.into_iter().enumerate() {
                            ctx.kv.push(k, part);
                        }
                        let pulls: Vec<_> = (0..n_keys).map(|k| ctx.kv.pull(k)).collect();
                        let agg: Vec<Vec<f32>> =
                            pulls.into_iter().map(|p| p.wait()).collect();
                        let mut g_sum = vec![0.0f32; meta.params];
                        join_keys(&segs, &agg, &mut g_sum);
                        model.sgd_update(&mut w, &g_sum, &mut momentum, &local_hyper)?;
                    }
                }
                Algo::DistAsgd | Algo::MpiAsgd => {
                    // Fig. 7: push grads, pull params.
                    let parts = split_keys(&segs, &grads);
                    for (k, part) in parts.into_iter().enumerate() {
                        ctx.kv.push(k, part);
                    }
                    let pulls: Vec<_> = (0..n_keys).map(|k| ctx.kv.pull(k)).collect();
                    let parts: Vec<Vec<f32>> = pulls.into_iter().map(|p| p.wait()).collect();
                    join_keys(&segs, &parts, &mut w);
                }
                Algo::DistEsgd | Algo::MpiEsgd => {
                    // Fig. 8. For MPI clients, keep replicas in lockstep by
                    // averaging gradients inside the client each iteration
                    // (sync SGD within the communicator, §5) — pushpull on
                    // a pure-MPI kvstore is the allreduce; with servers we
                    // reuse pushpull composition only at INTERVALs, so the
                    // intra-client allreduce here goes through the comm.
                    let mut g = grads;
                    if cfg.algo == Algo::MpiEsgd && ctx.workers_per_client > 1 {
                        // Aggregate inside the client (ring allreduce).
                        g = ctx.kv.client_allreduce(g).wait();
                    }
                    model.sgd_update(&mut w, &g, &mut momentum, &local_hyper)?;
                    // Fig. 8's lazy sync schedule (shared helper).
                    if crate::trainer::esgd_sync_due(iter as u64, cfg.interval) {
                        // Push params (Fig. 8 l.10). The MPI kvstore's push
                        // ring-SUMS across the client; replicas are kept in
                        // lockstep, so pre-scale by 1/m to push the client
                        // average (= w) rather than m*w.
                        let scale = 1.0 / ctx.workers_per_client as f32;
                        let mut w_avg = w.clone();
                        crate::tensor::scale(&mut w_avg, scale);
                        let parts = split_keys(&segs, &w_avg);
                        for (k, part) in parts.into_iter().enumerate() {
                            ctx.kv.push(k, part);
                        }
                        let pulls: Vec<_> = (0..n_keys).map(|k| ctx.kv.pull(k)).collect();
                        let centers: Vec<Vec<f32>> =
                            pulls.into_iter().map(|p| p.wait()).collect();
                        let mut c = vec![0.0f32; meta.params];
                        join_keys(&segs, &centers, &mut c);
                        model.elastic2(&mut w, &c, cfg.alpha)?; // Fig. 8 l.12
                    }
                }
            }
            iter += 1;
        }

        // Validation on worker 0 (paper: after every epoch).
        if ctx.ps_rank == 0 {
            let (vl, va) = evaluate(cfg, &model, &data, &w)?;
            records.push(EpochRecord {
                epoch,
                vtime: start.elapsed().as_secs_f64(),
                train_loss: train_loss_sum / batches as f64,
                val_loss: vl,
                val_acc: va,
            });
        }
    }
    ctx.kv.wait_all();
    Ok(records)
}

/// Validation loss/accuracy over `cfg.eval_samples` held-out samples.
///
/// Same distribution as training (same mixture centers / successor
/// table), disjoint sample indices: the held-out shard lives past
/// [`crate::trainer::EVAL_OFFSET`].
pub fn evaluate(
    cfg: &ExperimentConfig,
    model: &ModelHandle,
    data: &TrainData,
    w: &[f32],
) -> Result<(f64, f64)> {
    let batch = model.meta.batch_size();
    let n_batches = (cfg.eval_samples as usize / batch).max(1);
    let mut loss = 0.0f64;
    let mut correct = 0i64;
    let mut total = 0i64;
    let per = match data {
        TrainData::Gaussian(_) => 1,
        TrainData::Corpus { seq, .. } => *seq as i64,
    };
    for b in 0..n_batches {
        let start = crate::trainer::EVAL_OFFSET + (b * batch) as u64;
        let (x, y) = data.batch(start, batch);
        let (l, c) = model.eval_step(w, x, y)?;
        loss += l as f64;
        correct += c as i64;
        total += batch as i64 * per;
    }
    Ok((loss / n_batches as f64, correct as f64 / total as f64))
}
