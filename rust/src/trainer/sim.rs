//! Virtual-time trainer: real SGD numerics on the netsim clock.
//!
//! Regenerates the paper's convergence/epoch-time figures (11–14, 16)
//! deterministically: gradients, optimizer updates and validation accuracy
//! are *real* (the compiled PJRT model), while compute and communication
//! *durations* come from the α-β-γ cost model with paper-testbed constants
//! — 0.35 s per batch of ResNet-50 fwd+bwd, 102 MB of parameters on the
//! wire, IB CX-4 links, a shared PS ingress (DESIGN.md §2).
//!
//! The plane runs one of two generic strategy loops, chosen by
//! [`SyncStrategy::synchronous`]:
//!
//! * **lockstep** — global rounds for deterministic synchronous strategies
//!   (SGD, Local SGD, BMUF): every live client's gradient is computed,
//!   the strategy's [`lockstep_round`](SyncStrategy::lockstep_round) does
//!   the round's numerics, and a PS round is priced only when the
//!   strategy's sync schedule fired (communication avoidance is visible
//!   on the clock).
//! * **event-driven** — genuine asynchrony for ASGD/ESGD: client events
//!   (compute-done, push-arrive) interleave on the virtual clock with
//!   per-worker compute jitter, so staleness and lazy synchronisation
//!   emerge rather than being scripted, through the strategy's
//!   [`on_compute`](SyncStrategy::on_compute) /
//!   [`on_push_arrive`](SyncStrategy::on_push_arrive) hooks.
//!
//! **Compression** (the [`crate::compress`] plane): with a lossy codec
//! configured, every client gradient that crosses a wire (a multi-member
//! client's intra-client exchange, or the PS hop on sync iterations)
//! passes the codec's error-feedback round-trip before the strategy's
//! numerics — the sim-plane mirror of the threaded stack's compressed
//! gradient exchange, so convergence curves feel the quantization — and
//! the virtual clock prices the codec's **wire bytes** through the PS
//! fabric plus a codec γ per compressed hop. The identity codec (default)
//! leaves every code path bitwise on the pre-compression implementation.
//!
//! **Churn** rides the same schedule as the threaded plane (the
//! [`ElasticHub`]'s precomputed membership epochs): kills shrink a
//! client's member set at the next boundary, joins grow it (pricing the
//! checkpoint bootstrap), straggles slow a member. Lockstep strategies
//! stall *every* client at a membership epoch (the world rebuild is
//! global — pure MPI's weakness); event-driven ones stall only the touched
//! client while the rest keep training against the PS — the paper's §2
//! graceful-degradation argument, now measurable.

use crate::compress::{self, Compressor, EfState};
use crate::config::ExperimentConfig;
use crate::launcher::{ElasticHub, JobSpec};
use crate::metrics::{EpochRecord, RunResult};
use crate::netsim::{CostParams, EventQueue, PsFabric, VTime};
use crate::ps::Scheduler;
use crate::runtime::{Model, ModelMeta, Runtime};
use crate::trainer::strategies::{
    AfterCompute, EventStep, LockstepRound, RoundClient, SyncStrategy,
};
use crate::trainer::TrainData;
use crate::util::Rng;
use anyhow::Result;
use std::path::Path;

/// Per-client replica state.
struct Client {
    /// Local parameters (ASGD: last pulled; lazy-sync modes: local model).
    w: Vec<f32>,
    momentum: Vec<f32>,
    now: VTime,
    /// Iterations completed (drives epoch boundaries + lazy INTERVALs).
    iter: u64,
    /// Static duration of one lockstep batch round (max over the client's
    /// live member workers, each with seeded speed jitter x straggle).
    compute_s: f64,
    /// *Exposed* intra-client allreduce seconds per iteration: with the
    /// DAG-embedded per-bucket collectives (cfg.overlap) only the
    /// communication that cannot hide under this client's backward
    /// compute; with overlap off, the full blocking allreduce.
    comm_s: f64,
    /// Gradient in flight to the PS (ASGD).
    grad_outbox: Option<Vec<f32>>,
    train_loss_accum: f64,
    /// Membership epochs this client has applied (all clients pass every
    /// boundary, affected or not, so epoch indices stay aligned).
    epochs_done: u64,
    /// Live member worker-ids (empty = the whole client left the job).
    members: Vec<usize>,
}

struct Sim<'a> {
    cfg: &'a ExperimentConfig,
    model: Model,
    data: TrainData,
    clients: Vec<Client>,
    /// Master fan-out seconds after a pull.
    bcast_s: f64,
    fabric: PsFabric,
    /// Server value: aggregated grads (SGD), params (ASGD), centers
    /// (ESGD), the global model (Local SGD / BMUF).
    server_w: Vec<f32>,
    /// Server-side state buffer (momentum / BMUF's block momentum Δ).
    server_m: Vec<f32>,
    iters_per_epoch: u64,
    records: Vec<EpochRecord>,
    params: CostParams,
    /// Elastic schedule shared with the threaded plane (None = static).
    hub: Option<ElasticHub>,
    /// Per-worker speed factor: seeded jitter x cumulative straggle.
    jitter: Vec<f64>,
    rng: Rng,
    /// Gradient codec (identity = every path bitwise pre-compression).
    codec: Box<dyn Compressor>,
    /// Error-feedback residuals, one per client.
    ef: EfState,
    /// Bytes one full-model PS push moves on the wire under the codec.
    push_wire_bytes: usize,
    /// Codec compute seconds per compressed PS hop (encode + decode).
    codec_push_s: f64,
}

impl Sim<'_> {
    /// EF round-trip a client's gradient through the codec — the
    /// sim-plane mirror of the compressed gradient exchange, so lossy
    /// codecs shape the convergence curves, not just the clock. Applied
    /// only when this iteration's *gradient* actually crosses a wire
    /// (matching the threaded plane): a multi-member client exchanges
    /// gradients intra-client every iteration, and `grad_push` marks an
    /// iteration whose PS hop carries this gradient. A single-member
    /// client's wireless local step stays uncompressed, as do the
    /// model-snapshot syncs of the averaging family (their pushes are
    /// dense on the threaded plane too — `SyncStrategy::pushes_model`).
    fn codec_roundtrip(&mut self, c: usize, grad_push: bool, g: Vec<f32>) -> Vec<f32> {
        if self.codec.is_identity() || (self.clients[c].members.len() <= 1 && !grad_push) {
            g
        } else {
            compress::ef_roundtrip(&*self.codec, c as u64, &g, &mut self.ef)
        }
    }

    /// (bytes, codec seconds) of one PS push under the strategy's payload
    /// kind: gradient pushes move the codec's wire bytes and pay its γ;
    /// model-snapshot pushes are always dense.
    fn push_cost(&self, strategy: &dyn SyncStrategy) -> (usize, f64) {
        if strategy.pushes_model() {
            (self.cfg.virtual_model_bytes, 0.0)
        } else {
            (self.push_wire_bytes, self.codec_push_s)
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Client finished local compute (+ intra-client allreduce).
    ComputeDone { c: usize, iter: u64 },
    /// Client's push arrived at the PS.
    PushArrive { c: usize, iter: u64 },
}

/// Compute + exposed-communication seconds for a client whose live
/// members have the given speed factors. The intra-client allreduce is
/// priced under the configured codec
/// ([`crate::collectives::sim::compressed_tensor_allreduce_seconds`] —
/// identity delegates to the dense model, bitwise).
fn client_costs(
    cfg: &ExperimentConfig,
    params: &CostParams,
    codec: &dyn Compressor,
    factors: &[f64],
) -> (f64, f64) {
    let mc = factors.len();
    let worst = factors.iter().fold(1.0f64, |a, &b| a.max(b));
    let compute_s = cfg.compute_s_per_batch * worst;
    let allreduce_s = if mc > 1 {
        crate::collectives::sim::compressed_tensor_allreduce_seconds(
            cfg.collective_kind(),
            mc,
            cfg.virtual_model_bytes,
            cfg.rings,
            codec,
            params,
        )
    } else {
        0.0
    };
    (
        compute_s,
        exposed_comm_seconds(cfg, mc, params, codec, allreduce_s, compute_s),
    )
}

impl<'a> Sim<'a> {
    /// Base speed factor of worker `id` (seeded jitter, straggle excluded).
    fn base_jitter(&self, id: usize) -> f64 {
        let mut r = self.rng.fork(id as u64 + 1);
        1.0 + self.cfg.jitter * r.uniform()
    }

    /// Recompute client `c`'s cost constants from its live members.
    fn refresh_costs(&mut self, c: usize) {
        let factors: Vec<f64> = self.clients[c]
            .members
            .iter()
            .map(|&id| self.jitter[id])
            .collect();
        if factors.is_empty() {
            return; // dead client: never scheduled again
        }
        let (compute_s, comm_s) = client_costs(self.cfg, &self.params, &*self.codec, &factors);
        self.clients[c].compute_s = compute_s;
        self.clients[c].comm_s = comm_s;
    }

    /// Total live workers across clients.
    fn live_workers(&self) -> usize {
        self.clients.iter().map(|cl| cl.members.len()).sum()
    }

    /// Apply membership epoch `k` to client `c`'s tables; returns the
    /// reconfiguration stall this client pays (0 when untouched).
    fn apply_epoch(&mut self, k: u64, c: usize) -> f64 {
        // Copy the plan slices out first: the hub borrow must end before
        // the membership tables are mutated.
        let (new_members, any_join, factors) = {
            let Some(hub) = &self.hub else { return 0.0 };
            let nm: Vec<usize> = hub
                .members_after(k)
                .iter()
                .filter(|&&(_, client)| client == c)
                .map(|&(r, _)| r)
                .collect();
            let any_join = hub.joins_at(k).iter().any(|r| nm.contains(r));
            let f: Vec<f64> = nm.iter().map(|&id| hub.straggle_after(k, id)).collect();
            (nm, any_join, f)
        };
        let mut touched = false;
        for (&id, &straggle) in new_members.iter().zip(&factors) {
            if self.jitter.len() <= id {
                self.jitter.resize(id + 1, 1.0);
            }
            let f = self.base_jitter(id) * straggle;
            if (self.jitter[id] - f).abs() > 1e-12 && self.clients[c].members.contains(&id) {
                touched = true; // straggle change on an existing member
            }
            self.jitter[id] = f;
        }
        if new_members != self.clients[c].members {
            touched = true;
        }
        self.clients[c].members = new_members;
        self.clients[c].epochs_done = k + 1;
        if !touched {
            return 0.0;
        }
        self.refresh_costs(c);
        let bootstrap = if any_join {
            self.cfg.virtual_model_bytes
        } else {
            0
        };
        self.params.reconfig_seconds(
            self.clients[c].members.len().max(1),
            bootstrap,
            self.cfg.servers,
        )
    }

    /// Sum of the live member workers' per-batch mean gradients (sync
    /// inside the client, §5). Real PJRT math.
    ///
    /// Shards are indexed by each member's position in the *global live*
    /// worker list (the threaded plane's `shard_index` resharding): with
    /// the launch population this is the identity mapping, and after
    /// churn a joiner gets its own shard instead of aliasing worker 0's
    /// through `Shard::batch_start`'s modulo wrap.
    ///
    /// With `devices = k > 1` each member's batch is split into k shards
    /// of b/k rows through the shared [`device_grad_shards`] helper and
    /// merged by [`device_local_merge`] against this plane's EF state —
    /// the same shard math and fold order as the threaded worker loop, so
    /// the cross-plane bitwise property extends to the device tier.
    /// `&mut self` only for the per-device EF residuals; `devices == 1`
    /// is the exact legacy path (full-batch grad, merge untouched).
    ///
    /// [`device_grad_shards`]: crate::trainer::device_grad_shards
    /// [`device_local_merge`]: crate::kvstore::device_local_merge
    fn client_grad(&mut self, c: usize, iter: u64, w: &[f32]) -> Result<(f32, Vec<f32>)> {
        let batch = self.model.meta.batch_size();
        let devices = self.cfg.devices.max(1);
        let epoch = iter / self.iters_per_epoch;
        let b_in_epoch = iter % self.iters_per_epoch;
        let mut all_live: Vec<usize> = self
            .clients
            .iter()
            .flat_map(|cl| cl.members.iter().copied())
            .collect();
        all_live.sort_unstable();
        let members = self.clients[c].members.clone();
        let mut sum: Vec<f32> = Vec::new();
        let mut loss_sum = 0.0f32;
        for &worker in &members {
            let shard_index = all_live
                .iter()
                .position(|&id| id == worker)
                .expect("member is live");
            let shard = crate::data::Shard {
                worker: shard_index,
                n_workers: all_live.len(),
                total: self.cfg.samples_per_epoch,
                batch,
                epoch,
            };
            let model = &self.model;
            let (loss, dev_grads) = crate::trainer::device_grad_shards(
                &self.data,
                shard.batch_start(b_in_epoch),
                batch,
                devices,
                |x, y, rows| model.grad_step_rows(w, &x, &y, rows),
            )?;
            let g = crate::kvstore::device_local_merge(
                dev_grads,
                &*self.codec,
                &mut self.ef,
                crate::kvstore::device_ef_base(shard_index as u64),
            );
            loss_sum += loss;
            if sum.is_empty() {
                sum = g;
            } else {
                crate::tensor::add_assign(&mut sum, &g);
            }
        }
        Ok((loss_sum / members.len().max(1) as f32, sum))
    }

    /// Validation through the shared evaluator in trainer/mod.rs (one
    /// implementation for both planes).
    fn evaluate(&self, w: &[f32]) -> Result<(f64, f64)> {
        let batch = self.model.meta.batch_size();
        crate::trainer::evaluate(&self.data, self.cfg.eval_samples, batch, w, |w, x, y| {
            self.model.eval_step(w, &x, &y)
        })
    }

    fn record_epoch(&mut self, epoch: u64, vtime: f64, w: &[f32], train_loss: f64) -> Result<()> {
        let (val_loss, val_acc) = self.evaluate(w)?;
        self.records.push(EpochRecord {
            epoch: epoch as usize,
            vtime,
            train_loss,
            val_loss,
            val_acc,
        });
        Ok(())
    }
}

/// Per-iteration *exposed* intra-client communication seconds.
///
/// With `cfg.overlap` (the DAG-embedded collective path), the model's
/// gradients move as fusion buckets issued while backward compute is still
/// running, so only the communication that exceeds the overlap window is
/// exposed ([`csim::overlapped_step_seconds`]); never worse than the
/// blocking allreduce. With overlap off (or a single-worker client) the
/// full blocking cost is exposed.
fn exposed_comm_seconds(
    cfg: &ExperimentConfig,
    m: usize,
    params: &crate::netsim::CostParams,
    codec: &dyn Compressor,
    blocking_s: f64,
    compute_s: f64,
) -> f64 {
    use crate::collectives::sim as csim;
    if !cfg.overlap || m <= 1 {
        return blocking_s;
    }
    // ResNet-50-analog message count: ~100 per-tensor messages without
    // fusion, or the bucket count under the fusion cap (§2.1, Fig. 15).
    let buckets = if cfg.fusion_bytes > 0 {
        (cfg.virtual_model_bytes + cfg.fusion_bytes - 1) / cfg.fusion_bytes
    } else {
        100
    }
    .clamp(1, 100);
    let per_msg = (cfg.virtual_model_bytes / buckets).max(1);
    let comm = buckets as f64
        * csim::compressed_tensor_allreduce_seconds(
            cfg.collective_kind(),
            m,
            per_msg,
            cfg.rings,
            codec,
            params,
        );
    let step = csim::overlapped_step_seconds(compute_s, comm, buckets);
    (step - compute_s).clamp(0.0, blocking_s)
}

/// Run a virtual-time training experiment; `vtime` in the returned records
/// is netsim seconds.
pub fn simulate(cfg: &ExperimentConfig, artifacts_dir: &Path) -> Result<RunResult> {
    Ok(simulate_with_weights(cfg, artifacts_dir)?.0)
}

/// [`simulate`], additionally returning the final evaluated parameters
/// (client 0's replica for local-model strategies, the server value
/// otherwise) — the cross-plane bitwise equivalence property is asserted
/// against these.
pub fn simulate_with_weights(
    cfg: &ExperimentConfig,
    artifacts_dir: &Path,
) -> Result<(RunResult, Vec<f32>)> {
    // Same knob as the threaded plane; bitwise-invisible by the kernel
    // determinism contract.
    crate::runtime::par::set_threads(cfg.threads);
    let rt = Runtime::cpu()?;
    let model = Model::load(&rt, artifacts_dir, &cfg.variant)?;
    let meta: ModelMeta = model.meta.clone();
    let n = meta.params;
    let m = cfg.workers_per_client();
    let params = cfg.cost_params();
    let bytes = cfg.virtual_model_bytes;

    let bcast_s = if m > 1 {
        bytes as f64 * params.beta_net + bytes as f64 * params.beta_gpu_bcast
    } else {
        0.0
    };

    // Elastic schedule (shared with the threaded plane so both planes see
    // identical membership epochs for identical configs).
    let plan = cfg.fault_plan()?;
    let hub = if plan.is_empty() {
        None
    } else {
        let mut spec = JobSpec::from_config(cfg);
        spec.fault = plan;
        Some(ElasticHub::new(&spec, Scheduler::new(0, 0), None)?)
    };

    let rng = Rng::new(cfg.seed);
    let w0 = meta.init_params()?;
    let mut jitter: Vec<f64> = Vec::new();
    for id in 0..cfg.workers {
        let mut r = rng.fork(id as u64 + 1);
        jitter.push(1.0 + cfg.jitter * r.uniform());
    }
    // The compression plane: lossy codecs shrink the PS wire bytes (and
    // pay a codec γ per hop); identity keeps all pricing and numerics
    // bitwise on the pre-compression paths.
    let codec = cfg.build_compressor();
    let push_wire_bytes = if codec.is_identity() {
        cfg.virtual_model_bytes
    } else {
        codec.wire_bytes(cfg.virtual_model_bytes / 4)
    };
    let codec_push_s = compress::codec_seconds(&*codec, cfg.virtual_model_bytes, &params);
    let clients: Vec<Client> = (0..cfg.clients)
        .map(|c| {
            let members: Vec<usize> = (0..m).map(|j| c * m + j).collect();
            let factors: Vec<f64> = members.iter().map(|&id| jitter[id]).collect();
            let (compute_s, comm_s) = client_costs(cfg, &params, &*codec, &factors);
            Client {
                w: w0.clone(),
                momentum: vec![0.0; n],
                now: 0.0,
                iter: 0,
                compute_s,
                comm_s,
                grad_outbox: None,
                train_loss_accum: 0.0,
                epochs_done: 0,
                members,
            }
        })
        .collect();

    let iters_per_epoch =
        (cfg.samples_per_epoch / (cfg.workers as u64 * meta.batch_size() as u64)).max(1);
    if let Some(hub) = &hub {
        let last_idx = hub.n_epochs().saturating_sub(1) as u64;
        if let Some(last) = hub.boundary_iter(last_idx) {
            anyhow::ensure!(
                last < iters_per_epoch * cfg.epochs as u64,
                "fault plan boundary at iteration {last} never fires: the \
                 run has only {} iterations",
                iters_per_epoch * cfg.epochs as u64
            );
        }
    }

    let mut sim = Sim {
        cfg,
        data: TrainData::for_model(&meta, cfg.noise, cfg.classes, cfg.seed),
        model,
        clients,
        bcast_s,
        fabric: PsFabric::new(cfg.servers.max(1), cfg.clients, params.clone()),
        server_w: w0,
        server_m: vec![0.0; n],
        iters_per_epoch,
        records: Vec::new(),
        params,
        hub,
        jitter,
        rng,
        codec,
        ef: EfState::new(),
        push_wire_bytes,
        codec_push_s,
    };

    // The one strategy dispatch of the plane: the registry object picks
    // its flow, the flows never inspect the algorithm again.
    let strategy = cfg.algo.strategy();
    if strategy.synchronous() {
        run_lockstep(&mut sim, strategy)?;
    } else {
        run_event(&mut sim, strategy)?;
    }

    let w_final = if strategy.local_model() {
        // First live client (client 0 in practice: the hub refuses plans
        // that empty it), never a dead client's frozen replica.
        let c0 = sim
            .clients
            .iter()
            .position(|c| !c.members.is_empty())
            .unwrap_or(0);
        sim.clients[c0].w.clone()
    } else {
        sim.server_w.clone()
    };
    Ok((RunResult::finish(cfg.algo.name(), sim.records), w_final))
}

/// Lockstep flow for synchronous strategies (Fig. 6 semantics, plus the
/// communication-avoiding periodic-averaging family).
///
/// Membership epochs are **global barriers** here — pure MPI and sync-PS
/// jobs rebuild every world at the boundary, so every live client pays the
/// reconfiguration stall (this is exactly why the paper keeps the loosely
/// coupled PS around for elasticity).
fn run_lockstep(sim: &mut Sim<'_>, strategy: &dyn SyncStrategy) -> Result<()> {
    let cfg = sim.cfg;
    let n_iters = sim.iters_per_epoch * cfg.epochs as u64;
    let bytes = cfg.virtual_model_bytes;
    for iter in 0..n_iters {
        let live: Vec<usize> = (0..sim.clients.len())
            .filter(|&c| !sim.clients[c].members.is_empty())
            .collect();
        let live_workers = sim.live_workers();

        // 1. Real math: every live client's gradient sum, against the
        // strategy's model choice (one global server value, or the
        // client's own replica).
        let sync = strategy.sync_due(cfg, iter);
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(live.len());
        let mut loss_sum = 0.0f64;
        for &c in &live {
            let w = if strategy.local_model() {
                sim.clients[c].w.clone()
            } else {
                sim.server_w.clone()
            };
            let (loss, g) = sim.client_grad(c, iter, &w)?;
            loss_sum += loss as f64;
            // The compressed gradient exchange: what the round's numerics
            // see is the codec's EF round-trip (no-op for identity, a
            // wireless single-member local step, a model-snapshot sync
            // whose PS push is dense, or a serverless job with no PS hop
            // at all).
            let grad_push = sync && !strategy.pushes_model() && cfg.servers > 0;
            grads.push(sim.codec_roundtrip(c, grad_push, g));
        }

        // 2. Strategy numerics on the assembled round (split borrows: the
        // round holds the server state and every live client's replica).
        {
            let Sim { model, clients, server_w, server_m, .. } = &mut *sim;
            let mut grads_iter = grads.into_iter();
            let mut round_clients: Vec<RoundClient<'_>> = Vec::with_capacity(live.len());
            for (c, cl) in clients.iter_mut().enumerate() {
                if cl.members.is_empty() {
                    continue;
                }
                let g = grads_iter.next().expect("one gradient per live client");
                round_clients.push(RoundClient {
                    idx: c,
                    members: cl.members.len(),
                    grad: g,
                    w: &mut cl.w,
                    momentum: &mut cl.momentum,
                });
            }
            let mut round = LockstepRound {
                model,
                iter,
                sync_due: sync,
                live_workers,
                live_clients: live.len(),
                servers: cfg.servers,
                server_w,
                server_m,
                clients: round_clients,
            };
            strategy.lockstep_round(cfg, &mut round)?;
        }

        // 3. Virtual time: compute -> intra-client allreduce; on sync
        // rounds additionally masters push (fabric contention) -> sync
        // server round -> pulls -> bcast.
        let mut arrivals: Vec<(usize, VTime)> = live
            .iter()
            .map(|&c| {
                let cl = &sim.clients[c];
                (c, cl.now + cl.compute_s + cl.comm_s)
            })
            .collect();
        arrivals.sort_by(|a, b| a.1.total_cmp(&b.1));
        let loss_avg = loss_sum / live.len().max(1) as f64;
        if !sync || cfg.servers == 0 {
            // No PS round: a communication-avoided iteration (lazy
            // strategies between syncs), or pure MPI (#servers = 0,
            // §4.2.4) where PushPull *is* the allreduce already priced in
            // comm_s. (Single client: allreduce_s covers comm.)
            for &(c, at) in &arrivals {
                sim.clients[c].now = at;
                sim.clients[c].iter += 1;
                sim.clients[c].train_loss_accum += loss_avg;
            }
        } else {
            // Masters push the codec's wire bytes (+ its encode/decode γ)
            // for gradient payloads, dense bytes for model snapshots;
            // pulls come back dense (the server answers with full values).
            let (push_bytes, push_codec_s) = sim.push_cost(strategy);
            let mut server_done: VTime = 0.0;
            for &(c, at) in &arrivals {
                server_done =
                    server_done.max(sim.fabric.push(at + push_codec_s, c, push_bytes));
            }
            for &(c, _) in &arrivals {
                let pulled = sim.fabric.pull(server_done, c, bytes);
                sim.clients[c].now = pulled + sim.bcast_s;
                sim.clients[c].iter += 1;
                sim.clients[c].train_loss_accum += loss_avg;
            }
        }

        // 4. Membership epoch: a global barrier for lockstep strategies —
        // every live client stalls for the rebuild (the slowest survivor
        // gates everyone, plus the reconfiguration itself).
        let boundary = sim
            .hub
            .as_ref()
            .and_then(|h| h.boundary_iter(sim.clients[live[0]].epochs_done));
        if boundary == Some(iter) {
            let k = sim.clients[live[0]].epochs_done;
            let barrier_at = live
                .iter()
                .map(|&c| sim.clients[c].now)
                .fold(0.0f64, f64::max);
            let mut stall = 0.0f64;
            for c in 0..sim.clients.len() {
                stall = stall.max(sim.apply_epoch(k, c));
            }
            for cl in sim.clients.iter_mut() {
                if !cl.members.is_empty() {
                    cl.now = barrier_at + stall;
                }
            }
        }

        if (iter + 1) % sim.iters_per_epoch == 0 {
            let epoch = iter / sim.iters_per_epoch;
            // The lockstep round (epoch) completes when the *slowest*
            // live client has its result — epoch time is a barrier
            // quantity.
            let vtime = sim
                .clients
                .iter()
                .filter(|c| !c.members.is_empty())
                .map(|c| c.now)
                .fold(0.0f64, f64::max);
            let tl = sim.clients[0].train_loss_accum / sim.iters_per_epoch as f64;
            sim.clients[0].train_loss_accum = 0.0;
            // First *live* client's replica (defensive: the ElasticHub
            // rejects plans that empty client 0, so this is client 0 in
            // practice — but a frozen dead replica must never be what the
            // validation curve evaluates).
            let w = if strategy.local_model() {
                sim.clients[live[0]].w.clone()
            } else {
                sim.server_w.clone()
            };
            sim.record_epoch(epoch, vtime, &w, tl)?;
        }
    }
    Ok(())
}

/// Advance a client past iteration `iter`; apply any membership boundary,
/// schedule its next compute and record epoch boundaries on client 0.
fn finish_iteration(
    sim: &mut Sim<'_>,
    q: &mut EventQueue<Ev>,
    c: usize,
    iter: u64,
    now: VTime,
) -> Result<()> {
    let n_iters = sim.iters_per_epoch * sim.cfg.epochs as u64;
    let mut now = now;
    // Membership epochs: each client crosses every boundary at its own
    // pace; only touched clients stall (the others keep training against
    // the PS — the lazy-sync family's graceful degradation under churn).
    while sim
        .hub
        .as_ref()
        .and_then(|h| h.boundary_iter(sim.clients[c].epochs_done))
        == Some(iter)
    {
        let k = sim.clients[c].epochs_done;
        now += sim.apply_epoch(k, c);
    }
    sim.clients[c].now = now;
    sim.clients[c].iter = iter + 1;
    if c == 0 && (iter + 1) % sim.iters_per_epoch == 0 {
        let epoch = iter / sim.iters_per_epoch;
        let tl = sim.clients[0].train_loss_accum / sim.iters_per_epoch as f64;
        sim.clients[0].train_loss_accum = 0.0;
        let w = sim.clients[0].w.clone();
        sim.record_epoch(epoch, now, &w, tl)?;
    }
    if iter + 1 < n_iters && !sim.clients[c].members.is_empty() {
        let t = now + sim.clients[c].compute_s + sim.clients[c].comm_s;
        q.push(t, Ev::ComputeDone { c, iter: iter + 1 });
    }
    Ok(())
}

/// Assemble the event-driven strategy context for client `c` (the split
/// borrows of the sim state both event arms share); `grad` is `Some` at
/// compute-done, `None` at push-arrival.
fn event_step<'a>(
    sim: &'a mut Sim<'_>,
    c: usize,
    iter: u64,
    n_clients: usize,
    grad: Option<Vec<f32>>,
) -> EventStep<'a> {
    let live_workers = sim.live_workers();
    let live_clients = sim.clients.iter().filter(|cl| !cl.members.is_empty()).count();
    let servers = sim.cfg.servers;
    let Sim { model, clients, server_w, server_m, .. } = &mut *sim;
    let cl = &mut clients[c];
    EventStep {
        model,
        iter,
        client: c,
        members: cl.members.len(),
        n_clients,
        live_workers,
        live_clients,
        servers,
        w: &mut cl.w,
        momentum: &mut cl.momentum,
        server_w,
        server_m,
        outbox: &mut cl.grad_outbox,
        grad,
    }
}

/// Event-driven flow for asynchronous strategies (ASGD Fig. 7, ESGD
/// Fig. 8) on the event queue.
fn run_event(sim: &mut Sim<'_>, strategy: &dyn SyncStrategy) -> Result<()> {
    let cfg = sim.cfg;
    let bytes = cfg.virtual_model_bytes;
    // Launch-time client count: the async server-lr stabilization
    // denominator stays fixed through churn.
    let n_clients = sim.clients.len();

    let mut q: EventQueue<Ev> = EventQueue::new();
    for c in 0..sim.clients.len() {
        let t = sim.clients[c].now + sim.clients[c].compute_s + sim.clients[c].comm_s;
        q.push(t, Ev::ComputeDone { c, iter: 0 });
    }

    while let Some((at, ev)) = q.pop() {
        match ev {
            Ev::ComputeDone { c, iter } => {
                let w_snapshot = sim.clients[c].w.clone();
                let (loss, g) = sim.client_grad(c, iter, &w_snapshot)?;
                sim.clients[c].train_loss_accum += loss as f64;
                // Compressed gradient exchange (no-op for identity, a
                // wireless single-member local step between syncs, a
                // strategy whose PS pushes carry model snapshots, or a
                // serverless job with no PS hop).
                let grad_push = strategy.sync_due(cfg, iter)
                    && !strategy.pushes_model()
                    && cfg.servers > 0;
                let g = sim.codec_roundtrip(c, grad_push, g);
                let action = {
                    let mut st = event_step(sim, c, iter, n_clients, Some(g));
                    strategy.on_compute(cfg, &mut st)?
                };
                match action {
                    AfterCompute::Push => {
                        let (push_bytes, push_codec_s) = sim.push_cost(strategy);
                        let arrive = sim.fabric.push(at + push_codec_s, c, push_bytes);
                        q.push(arrive, Ev::PushArrive { c, iter });
                    }
                    AfterCompute::Local => finish_iteration(sim, &mut q, c, iter, at)?,
                }
            }
            Ev::PushArrive { c, iter } => {
                // Timing first (the fabric never reads weights), then the
                // strategy's server-merge + pull-merge numerics.
                let pulled_at = sim.fabric.pull(at, c, bytes) + sim.bcast_s;
                {
                    let mut st = event_step(sim, c, iter, n_clients, None);
                    strategy.on_push_arrive(cfg, &mut st)?;
                }
                finish_iteration(sim, &mut q, c, iter, pulled_at)?;
            }
        }
    }
    Ok(())
}
