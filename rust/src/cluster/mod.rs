//! Cluster authority — multi-tenant job queue, gang scheduling, and
//! elastic autoscaling over a shared node pool (ROADMAP item 3).
//!
//! The paper's pitch (§1–§2) is that the loosely coupled PS *task* model
//! makes MPI-style training practical in a shared cloud — but every layer
//! below this one runs exactly one job per process. This module promotes
//! [`crate::ps::Scheduler`] + [`crate::launcher::ElasticHub`] to a cluster
//! authority:
//!
//! * an **admission queue** of heterogeneous jobs (strategy, codec and
//!   device count per job, scripted by the [`ArrivalPlan`] grammar),
//! * a bounded **node pool** with **gang placement** — a job's ranks are
//!   placed all-or-nothing, never a partial world,
//! * an **elastic policy** that grows jobs into idle capacity and shrinks
//!   them back to their gang width under contention, by *synthesizing*
//!   `join`/`kill` [`FaultEvent`]s at epoch boundaries — the PR 3 churn
//!   machinery is the mechanism, this is only the policy layer on top.
//!
//! Two planes, same split as everywhere else in the repo:
//! [`simulate`] runs the authority on virtual time (epochs priced by the
//! α-β-γ model with [`contended_allreduce_seconds`] tenancy pricing) and
//! emits each job's synthesized [`FaultPlan`]; [`execute`] then replays
//! those plans for real — every job launched through
//! [`crate::launcher::launch_with`] against a per-job quorum on one
//! [`ClusterScheduler`], so a cluster running exactly one job takes the
//! identical code path (and produces bitwise-identical results) to a plain
//! [`crate::launcher::launch`].

use crate::collectives::sim::contended_allreduce_seconds;
use crate::collectives::AlgoKind;
use crate::compress::Codec;
use crate::config::{Algo, ExperimentConfig};
use crate::launcher::{launch_with, JobSpec, WorkerCtx};
use crate::netsim::CostParams;
use crate::ps::{ClusterScheduler, FaultEvent, FaultKind, FaultPlan};
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, VecDeque};

/// Job index into [`ArrivalPlan::jobs`]; also the authority's job id.
pub type JobId = usize;

/// `topk` keep-ratio used for cluster jobs that pick the top-k codec.
pub const CLUSTER_TOPK_RATIO: f64 = 0.05;

// ---------------------------------------------------------------------------
// ArrivalPlan — the `--arrivals` grammar
// ---------------------------------------------------------------------------

/// One job submission in an arrival plan: which strategy/codec/device
/// shape it wants, its gang width in nodes (one worker per node), how many
/// epochs of work it brings, and when it arrives on the cluster clock.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRequest {
    pub algo: Algo,
    pub codec: Codec,
    /// Devices per worker (the PR 8 two-tier k).
    pub devices: usize,
    /// Gang width: nodes requested, placed all-or-nothing.
    pub workers: usize,
    /// Epochs of work *at the gang width* (total samples scale with it).
    pub epochs: u64,
    /// Arrival time on the cluster clock, seconds.
    pub arrival_s: f64,
}

/// A scripted job-arrival schedule, the cluster-level analogue of the
/// [`FaultPlan`] grammar. Comma-separated events:
///
/// ```text
/// ALGO[.CODEC[.DEVICES]]:WxE@T
/// ```
///
/// `ALGO` is a registered MPI strategy, `CODEC` a registered compressor
/// (default `identity`), `DEVICES` the per-worker device count (default
/// 1); `W` nodes arrive wanting `E` epochs of work at second `T`. E.g.
/// `mpi-SGD:4x6@0,mpi-ESGD.int8:2x6@120,mpi-SGD.topk.2:2x4@240`. Jobs are
/// kept sorted by arrival time (stable for ties).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArrivalPlan {
    pub jobs: Vec<JobRequest>,
}

impl ArrivalPlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Parse the `--arrivals` grammar; empty string = no jobs.
    pub fn parse(s: &str) -> Result<Self> {
        let mut jobs = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            jobs.push(Self::parse_job(part).with_context(|| {
                format!("bad arrival event {part:?} (grammar: ALGO[.CODEC[.DEVICES]]:WxE@T)")
            })?);
        }
        jobs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        Ok(Self { jobs })
    }

    fn parse_job(part: &str) -> Result<JobRequest> {
        let (head, at) = part.split_once('@').context("missing '@arrival-seconds'")?;
        let arrival_s: f64 = at.trim().parse().context("arrival seconds")?;
        ensure!(
            arrival_s.is_finite() && arrival_s >= 0.0,
            "arrival must be a finite non-negative time, got {arrival_s}"
        );
        let (desc, shape) = head.rsplit_once(':').context("missing ':WxE' job shape")?;
        let (w, e) = shape.split_once('x').context("job shape must be 'WxE' (workers x epochs)")?;
        let workers: usize = w.trim().parse().context("workers")?;
        let epochs: u64 = e.trim().parse().context("epochs")?;
        ensure!(workers >= 1, "job needs at least 1 worker");
        ensure!(epochs >= 1, "job needs at least 1 epoch of work");
        let mut fields = desc.split('.');
        let algo_name = fields.next().unwrap_or_default().trim();
        let algo = Algo::parse(algo_name).with_context(|| {
            format!("unknown algorithm {algo_name:?} (registered: {})", Algo::names().join(", "))
        })?;
        ensure!(
            algo.is_mpi(),
            "cluster jobs must use an MPI strategy (got {:?}): elastic grow/shrink \
             rebuilds client worlds, which dist modes do not have",
            algo.name()
        );
        let codec = match fields.next() {
            Some(c) => Codec::parse(c.trim()).with_context(|| {
                format!("unknown codec {:?} (registered: {})", c.trim(), Codec::names().join(", "))
            })?,
            None => Codec::identity(),
        };
        let devices = match fields.next() {
            Some(d) => {
                let k: usize = d.trim().parse().context("devices")?;
                ensure!(k >= 1, "devices must be >= 1, got {k}");
                k
            }
            None => 1,
        };
        ensure!(
            fields.next().is_none(),
            "too many '.'-separated fields (grammar: ALGO[.CODEC[.DEVICES]])"
        );
        Ok(JobRequest { algo, codec, devices, workers, epochs, arrival_s })
    }

    /// Canonical string form; [`ArrivalPlan::parse`] round-trips it.
    pub fn render(&self) -> String {
        self.jobs
            .iter()
            .map(|j| {
                format!(
                    "{}.{}.{}:{}x{}@{}",
                    j.algo.name(),
                    j.codec.name(),
                    j.devices,
                    j.workers,
                    j.epochs,
                    j.arrival_s
                )
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

// ---------------------------------------------------------------------------
// ClusterSpec — the authority's knobs
// ---------------------------------------------------------------------------

/// Node-allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Jobs hold exactly their gang width from admission to completion —
    /// the one-job-per-partition cloud baseline.
    Static,
    /// At its own epoch boundaries a job grows into idle nodes (queue
    /// empty) and shrinks back to its gang width under contention (queue
    /// non-empty), via synthesized join/kill events.
    Elastic,
}

impl AllocPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "static" => Some(Self::Static),
            "elastic" => Some(Self::Elastic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::Elastic => "elastic",
        }
    }
}

/// The shared cluster: a bounded node pool, an allocation policy, the
/// scripted arrivals, and the workload/cost constants every job's epochs
/// are priced with on the virtual-time plane.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Node-pool size; one worker rank per node.
    pub nodes: usize,
    pub policy: AllocPolicy,
    pub plan: ArrivalPlan,
    /// Iterations per membership epoch (every job's `reconfig_every`):
    /// grow/shrink/admission decisions land only on these boundaries.
    pub iters_per_epoch: u64,
    /// Samples one worker processes per iteration.
    pub batch: usize,
    /// Compute seconds per iteration per worker.
    pub compute_s: f64,
    /// Dense gradient payload per sync, bytes.
    pub bytes: usize,
    pub cost: CostParams,
}

impl ClusterSpec {
    /// A spec with the repo's default workload constants (testbed1 cost
    /// model, 8-iteration epochs, 4 MB gradients) — the CLI entry point.
    pub fn with_defaults(nodes: usize, policy: AllocPolicy, plan: ArrivalPlan) -> Self {
        Self {
            nodes,
            policy,
            plan,
            iters_per_epoch: 8,
            batch: 32,
            compute_s: 2.0,
            bytes: 4 << 20,
            cost: CostParams::testbed1(),
        }
    }
}

// ---------------------------------------------------------------------------
// Outcomes
// ---------------------------------------------------------------------------

/// One job's completed trajectory through the cluster.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: JobId,
    /// `j{id}` — stable display name.
    pub name: String,
    pub algo: Algo,
    pub codec: Codec,
    pub devices: usize,
    /// The gang width it was admitted at (and never shrunk below).
    pub base_workers: usize,
    pub arrival_s: f64,
    pub admitted_s: f64,
    pub finished_s: f64,
    /// Useful samples credited toward goodput (== the job's target).
    pub samples: u64,
    /// Job-local iterations executed (`widths.len() * iters_per_epoch`).
    pub iters: u64,
    /// Worker count during each membership epoch, in order.
    pub widths: Vec<usize>,
    /// The synthesized churn schedule (empty under [`AllocPolicy::Static`]
    /// or when the job never grew) — valid [`FaultPlan`] grammar, accepted
    /// by [`crate::launcher::ElasticHub::new`].
    pub fault: FaultPlan,
    /// Ready-to-launch spec: gang width, one client, serverless MPI, the
    /// synthesized plan, `reconfig_every = iters_per_epoch`.
    pub spec: JobSpec,
}

/// Integer conservation ledger over every pool mutation: after each event
/// the authority cross-checks its per-job placement lists against the
/// pool's owner ledger. `free + allocated` must equal the pool size at
/// every snapshot (min == max == nodes) and no node may ever be claimed
/// by two jobs or owned without a claimant (`double_booked == 0`).
#[derive(Debug, Clone, Copy)]
pub struct PoolAudit {
    pub snapshots: usize,
    pub alloc_free_min: usize,
    pub alloc_free_max: usize,
    pub double_booked: usize,
}

/// What a full cluster run produced.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub policy: AllocPolicy,
    pub nodes: usize,
    /// Completed jobs, by id.
    pub jobs: Vec<JobOutcome>,
    /// Last completion time on the cluster clock.
    pub makespan_s: f64,
    /// Useful samples across all jobs (fixed by the plan, not the policy).
    pub total_samples: u64,
    pub audit: PoolAudit,
}

impl ClusterOutcome {
    /// Aggregate goodput: useful samples per second of cluster time.
    pub fn goodput(&self) -> f64 {
        self.total_samples as f64 / self.makespan_s.max(f64::MIN_POSITIVE)
    }
}

// ---------------------------------------------------------------------------
// Virtual-time simulation
// ---------------------------------------------------------------------------

/// Build the launchable [`JobSpec`] for a request + synthesized plan.
fn job_spec(cluster: &ClusterSpec, req: &JobRequest, fault: FaultPlan) -> JobSpec {
    let mut spec = JobSpec::from_algo(req.algo, req.workers, 0, 1);
    spec.devices = req.devices;
    spec.codec = req.codec;
    spec.topk_ratio = CLUSTER_TOPK_RATIO;
    let mut cost = cluster.cost.clone();
    cost.devices = req.devices;
    spec.cost = cost;
    spec.collective = if req.devices >= 2 { AlgoKind::TwoTier } else { AlgoKind::Ring };
    spec.fault = fault;
    spec.reconfig_every = cluster.iters_per_epoch;
    spec
}

/// Wall seconds of one membership epoch for a job running `width` ranks
/// co-located with `tenants` jobs: compute per iteration, plus one
/// contention-priced allreduce per strategy sync boundary. The payload is
/// the job's codec's wire size (heterogeneous codecs pay heterogeneous
/// wire bytes, exactly like the single-job planes).
fn epoch_seconds(
    spec: &ClusterSpec,
    req: &JobRequest,
    sync_every: u64,
    width: usize,
    tenants: usize,
) -> f64 {
    let payload = if req.codec.is_identity() {
        spec.bytes
    } else {
        req.codec.build(CLUSTER_TOPK_RATIO).wire_bytes((spec.bytes / 4).max(1))
    };
    let kind = if req.devices >= 2 { AlgoKind::TwoTier } else { AlgoKind::Ring };
    let mut cost = spec.cost.clone();
    cost.devices = req.devices;
    let comm = contended_allreduce_seconds(kind, width, payload, tenants, &cost);
    let syncs = spec.iters_per_epoch.div_ceil(sync_every.max(1));
    spec.iters_per_epoch as f64 * spec.compute_s + syncs as f64 * comm
}

/// A job currently holding nodes.
struct Running {
    id: JobId,
    sync_every: u64,
    /// Owned node ids (the job's side of the conservation ledger).
    nodes: Vec<usize>,
    /// Live ps_ranks ascending; mirrors [`crate::launcher::ElasticHub`]'s
    /// replay of the synthesized plan (joins allocate from `workers` up,
    /// shrinks kill the highest live ranks).
    live_ranks: Vec<usize>,
    next_join_rank: usize,
    iters_done: u64,
    samples_done: u64,
    target: u64,
    epoch_end_s: f64,
    admitted_s: f64,
    widths: Vec<usize>,
    events: Vec<FaultEvent>,
}

struct Sim<'a> {
    spec: &'a ClusterSpec,
    /// Pool ledger: node -> owning job.
    owner: Vec<Option<JobId>>,
    queue: VecDeque<JobId>,
    running: BTreeMap<JobId, Running>,
    finished: BTreeMap<JobId, JobOutcome>,
    clock: f64,
    audit: PoolAudit,
}

impl Sim<'_> {
    fn free_count(&self) -> usize {
        self.owner.iter().filter(|o| o.is_none()).count()
    }

    /// Gang-allocate `n` nodes to `id`, all-or-nothing, lowest ids first.
    fn alloc(&mut self, id: JobId, n: usize) -> Option<Vec<usize>> {
        let free: Vec<usize> = (0..self.owner.len()).filter(|&i| self.owner[i].is_none()).collect();
        if free.len() < n {
            return None;
        }
        let taken = free[..n].to_vec();
        for &node in &taken {
            self.owner[node] = Some(id);
        }
        Some(taken)
    }

    fn release(&mut self, nodes: &[usize]) {
        for &node in nodes {
            self.owner[node] = None;
        }
    }

    /// Admit queued jobs FIFO while the head's gang fits. Head-of-line
    /// blocking is deliberate: admission order is part of the contract,
    /// and both policies pay it identically.
    fn try_admit(&mut self) {
        while let Some(&id) = self.queue.front() {
            let req = &self.spec.plan.jobs[id];
            if self.free_count() < req.workers {
                break;
            }
            self.queue.pop_front();
            let workers = req.workers;
            let nodes = self
                .alloc(id, workers)
                .unwrap_or_else(|| panic!("job {id}: gang of {workers} no longer fits after the free-count check"));
            let sync_every =
                req.algo.strategy().sync_every(&ExperimentConfig::testbed1(req.algo)).max(1);
            let tenants = self.running.len() + 1;
            let dur = epoch_seconds(self.spec, req, sync_every, req.workers, tenants);
            let target =
                req.epochs * self.spec.iters_per_epoch * req.workers as u64 * self.spec.batch as u64;
            self.running.insert(
                id,
                Running {
                    id,
                    sync_every,
                    nodes,
                    live_ranks: (0..req.workers).collect(),
                    next_join_rank: req.workers,
                    iters_done: 0,
                    samples_done: 0,
                    target,
                    epoch_end_s: self.clock + dur,
                    admitted_s: self.clock,
                    widths: vec![req.workers],
                    events: Vec::new(),
                },
            );
        }
    }

    fn arrival(&mut self, id: JobId) {
        self.queue.push_back(id);
        self.try_admit();
    }

    /// One job's epoch boundary: credit the finished epoch, complete or
    /// apply the elastic policy, re-admit, and price the next epoch.
    fn boundary(&mut self, id: JobId) {
        let mut r = self
            .running
            .remove(&id)
            .unwrap_or_else(|| panic!("epoch boundary fired for job {id}, which is not running"));
        let req = &self.spec.plan.jobs[id];
        let width = r.live_ranks.len();
        r.iters_done += self.spec.iters_per_epoch;
        let epoch_samples = self.spec.iters_per_epoch * width as u64 * self.spec.batch as u64;
        r.samples_done = (r.samples_done + epoch_samples).min(r.target);

        if r.samples_done >= r.target {
            let nodes = std::mem::take(&mut r.nodes);
            self.release(&nodes);
            let outcome = self.outcome_of(r);
            self.finished.insert(id, outcome);
            self.try_admit();
            return;
        }

        if self.spec.policy == AllocPolicy::Elastic {
            let at_iter = r.iters_done - 1; // this boundary's iteration
            if !self.queue.is_empty() && width > req.workers {
                // Contention: fail-stop the grown ranks at this boundary
                // and hand their nodes back (highest ranks die, matching
                // the hub's replay of the synthesized kills).
                let give = width - req.workers;
                let mut released = Vec::with_capacity(give);
                for _ in 0..give {
                    let rank = r
                        .live_ranks
                        .pop()
                        .unwrap_or_else(|| panic!("job {id}: elastic shrink emptied the gang"));
                    r.events.push(FaultEvent { at_iter, kind: FaultKind::Kill { rank } });
                    released.push(
                        r.nodes
                            .pop()
                            .unwrap_or_else(|| panic!("job {id}: rank {rank} had no backing node")),
                    );
                }
                self.release(&released);
            } else if self.queue.is_empty() {
                // Idle capacity: grow into every free node.
                let free = self.free_count();
                if free > 0 {
                    let grown = self
                        .alloc(id, free)
                        .unwrap_or_else(|| panic!("job {id}: {free} free nodes vanished before the grow"));
                    for node in grown {
                        r.events.push(FaultEvent { at_iter, kind: FaultKind::Join { client: None } });
                        r.live_ranks.push(r.next_join_rank);
                        r.next_join_rank += 1;
                        r.nodes.push(node);
                    }
                }
            }
        }

        let new_width = r.live_ranks.len();
        r.widths.push(new_width);
        r.epoch_end_s = f64::INFINITY; // repriced below, after admissions
        let sync_every = r.sync_every;
        self.running.insert(id, r);
        self.try_admit();
        let tenants = self.running.len();
        let dur = epoch_seconds(self.spec, req, sync_every, new_width, tenants);
        let r = self
            .running
            .get_mut(&id)
            .unwrap_or_else(|| panic!("job {id} vanished between reinsertion and epoch repricing"));
        r.epoch_end_s = self.clock + dur;
    }

    fn outcome_of(&self, r: Running) -> JobOutcome {
        let req = &self.spec.plan.jobs[r.id];
        let fault = FaultPlan { events: r.events };
        let spec = job_spec(self.spec, req, fault.clone());
        JobOutcome {
            id: r.id,
            name: format!("j{}", r.id),
            algo: req.algo,
            codec: req.codec,
            devices: req.devices,
            base_workers: req.workers,
            arrival_s: req.arrival_s,
            admitted_s: r.admitted_s,
            finished_s: self.clock,
            samples: r.target,
            iters: r.iters_done,
            widths: r.widths,
            fault,
            spec,
        }
    }

    /// Cross-check the per-job placement lists against the owner ledger
    /// and fold the result into the integer conservation audit.
    fn audit_snapshot(&mut self) {
        let mut claimed: Vec<Option<JobId>> = vec![None; self.owner.len()];
        let mut booked = 0usize;
        let mut bad = 0usize;
        for r in self.running.values() {
            for &node in &r.nodes {
                if claimed[node].is_some() {
                    bad += 1; // node claimed by two jobs
                }
                claimed[node] = Some(r.id);
                if self.owner[node] != Some(r.id) {
                    bad += 1; // ledger disagrees with the job's claim
                }
                booked += 1;
            }
        }
        for (node, owner) in self.owner.iter().enumerate() {
            if owner.is_some() && claimed[node] != *owner {
                bad += 1; // owned node nobody claims (leak)
            }
        }
        let total = self.free_count() + booked;
        self.audit.snapshots += 1;
        self.audit.alloc_free_min = self.audit.alloc_free_min.min(total);
        self.audit.alloc_free_max = self.audit.alloc_free_max.max(total);
        self.audit.double_booked += bad;
    }
}

/// Run the cluster authority on virtual time: admit the arrival plan's
/// jobs onto the node pool, price every epoch with the contention-aware
/// α-β-γ model, apply the allocation policy at epoch boundaries, and
/// return each job's trajectory with its synthesized churn plan.
pub fn simulate(spec: &ClusterSpec) -> Result<ClusterOutcome> {
    ensure!(spec.nodes >= 1, "cluster needs at least 1 node, got {}", spec.nodes);
    ensure!(spec.iters_per_epoch >= 1, "iters_per_epoch must be >= 1");
    ensure!(spec.batch >= 1, "batch must be >= 1");
    ensure!(
        spec.compute_s.is_finite() && spec.compute_s > 0.0,
        "compute seconds per iteration must be finite and positive, got {}",
        spec.compute_s
    );
    ensure!(!spec.plan.is_empty(), "arrival plan is empty: nothing to schedule");
    for (id, req) in spec.plan.jobs.iter().enumerate() {
        ensure!(
            req.workers <= spec.nodes,
            "job j{id} wants a gang of {} nodes but the pool has only {} — \
             it could never be placed",
            req.workers,
            spec.nodes
        );
    }

    let mut order: Vec<JobId> = (0..spec.plan.jobs.len()).collect();
    order.sort_by(|&a, &b| {
        spec.plan.jobs[a].arrival_s.total_cmp(&spec.plan.jobs[b].arrival_s).then(a.cmp(&b))
    });

    let mut sim = Sim {
        spec,
        owner: vec![None; spec.nodes],
        queue: VecDeque::new(),
        running: BTreeMap::new(),
        finished: BTreeMap::new(),
        clock: 0.0,
        audit: PoolAudit {
            snapshots: 0,
            alloc_free_min: usize::MAX,
            alloc_free_max: 0,
            double_booked: 0,
        },
    };
    sim.audit_snapshot();

    let mut next = 0usize;
    while next < order.len() || !sim.running.is_empty() {
        let arrival = order.get(next).map(|&id| (spec.plan.jobs[id].arrival_s, id));
        let boundary = sim
            .running
            .values()
            .map(|r| (r.epoch_end_s, r.id))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        match (arrival, boundary) {
            // Arrivals first on ties: a boundary's policy decision must
            // see every job already submitted at that instant.
            (Some((ta, id)), Some((tb, _))) if ta <= tb => {
                sim.clock = ta;
                sim.arrival(id);
                next += 1;
            }
            (_, Some((tb, id))) => {
                sim.clock = tb;
                sim.boundary(id);
            }
            (Some((ta, id)), None) => {
                sim.clock = ta;
                sim.arrival(id);
                next += 1;
            }
            (None, None) => unreachable!("loop condition"),
        }
        sim.audit_snapshot();
    }
    ensure!(sim.queue.is_empty(), "internal: queued jobs left unplaced");

    let jobs: Vec<JobOutcome> = sim.finished.into_values().collect();
    let makespan_s = jobs.iter().map(|j| j.finished_s).fold(0.0, f64::max);
    let total_samples = jobs.iter().map(|j| j.samples).sum();
    Ok(ClusterOutcome {
        policy: spec.policy,
        nodes: spec.nodes,
        jobs,
        makespan_s,
        total_samples,
        audit: sim.audit,
    })
}

// ---------------------------------------------------------------------------
// Threaded execution — replay the synthesized plans for real
// ---------------------------------------------------------------------------

/// What a worker thread knows about the cluster job it runs inside.
#[derive(Debug, Clone)]
pub struct JobTicket {
    pub id: JobId,
    pub name: String,
    /// Total job-local iterations (from the virtual-time trajectory).
    pub iters: u64,
}

/// Run the cluster for real: [`simulate`] first, then launch every job's
/// synthesized [`JobSpec`] concurrently through
/// [`crate::launcher::launch_with`], each against its own quorum on one
/// shared [`ClusterScheduler`]. Returns the virtual-time outcome plus each
/// job's per-worker results (outcome order).
pub fn execute<F, R>(spec: &ClusterSpec, worker_fn: F) -> Result<(ClusterOutcome, Vec<Vec<R>>)>
where
    F: Fn(&JobTicket, WorkerCtx) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    let outcome = simulate(spec)?;
    let registry = ClusterScheduler::new();
    let mut handles = Vec::with_capacity(outcome.jobs.len());
    for job in &outcome.jobs {
        let sched = registry.register_job(job.id as u64, job.spec.workers, job.spec.servers)?;
        let ticket = JobTicket { id: job.id, name: job.name.clone(), iters: job.iters };
        let jspec = job.spec.clone();
        let f = worker_fn.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("cluster-{}", job.name))
                .spawn(move || launch_with(&jspec, move |ctx| f(&ticket, ctx), sched))
                .unwrap_or_else(|e| panic!("spawn thread for cluster job {}: {e}", job.name)),
        );
    }
    let mut results = Vec::with_capacity(handles.len());
    for (handle, job) in handles.into_iter().zip(&outcome.jobs) {
        let job_result = handle
            .join()
            .unwrap_or_else(|_| panic!("cluster job {} (id {}) panicked", job.name, job.id))
            .with_context(|| format!("cluster job {} failed to launch", job.name))?;
        registry.finish_job(job.id as u64);
        results.push(job_result);
    }
    Ok((outcome, results))
}

/// Reference cluster worker: one allreduce per iteration, following the
/// synthesized membership boundaries exactly like the single-job elastic
/// protocol. Returns (iterations run, final allreduce sum).
pub fn allreduce_probe(ticket: &JobTicket, ctx: WorkerCtx) -> (u64, f32) {
    let total = ticket.iters;
    let Some(hub) = ctx.hub.clone() else {
        // Static trajectory: the plain launch path, no boundaries.
        let mut last = 0.0;
        for _ in 0..total {
            last = ctx.kv.pushpull(0, vec![1.0]).wait()[0];
        }
        return (total, last);
    };
    let mut epochs_done = ctx.join_view.as_ref().map_or(0, |v| v.epoch);
    let mut iter = ctx.join_view.as_ref().map_or(0, |v| v.boundary_iter + 1);
    let mut ran = 0;
    let mut last = 0.0;
    while iter < total {
        last = ctx.kv.pushpull(0, vec![1.0]).wait()[0];
        ran += 1;
        if hub.boundary_iter(epochs_done) == Some(iter) {
            ctx.kv.wait_all();
            if hub.dying_at(epochs_done).contains(&ctx.ps_rank) {
                return (ran, last);
            }
            let handout = hub.reconfigure(ctx.ps_rank);
            epochs_done = handout.view.epoch;
            if let Some(comm) = handout.comm {
                drop(ctx.kv.replace_comm(comm));
            }
        }
        iter += 1;
    }
    (ran, last)
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::launcher::{launch, ElasticHub};
    use crate::ps::Scheduler;

    fn plan(s: &str) -> ArrivalPlan {
        ArrivalPlan::parse(s).unwrap()
    }

    /// Small, fast spec: pure-arithmetic epochs on the virtual plane.
    fn spec(nodes: usize, policy: AllocPolicy, arrivals: &str) -> ClusterSpec {
        let mut s = ClusterSpec::with_defaults(nodes, policy, plan(arrivals));
        s.iters_per_epoch = 4;
        s.batch = 8;
        s.compute_s = 1.0;
        s.bytes = 1 << 20;
        s
    }

    #[test]
    fn arrival_plan_parses_and_round_trips() {
        let p = plan("mpi-SGD:4x6@0, mpi-ESGD.int8:2x6@120,mpi-SGD.topk.2:2x4@60");
        assert_eq!(p.jobs.len(), 3);
        // Sorted by arrival: the topk job moved to the middle.
        assert_eq!(p.jobs[1].codec, Codec::named("topk"));
        assert_eq!(p.jobs[1].devices, 2);
        assert_eq!(p.jobs[1].arrival_s, 60.0);
        assert_eq!(p.jobs[2].codec, Codec::named("int8"));
        assert_eq!(p.jobs[0].workers, 4);
        assert_eq!(p.jobs[0].epochs, 6);
        assert_eq!(p.jobs[0].codec, Codec::identity());
        assert_eq!(p.jobs[0].devices, 1);
        assert_eq!(ArrivalPlan::parse(&p.render()).unwrap(), p);
        assert!(ArrivalPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn arrival_plan_rejects_garbage() {
        for bad in [
            "mpi-SGD:4x6",          // missing @arrival
            "mpi-SGD:4@0",          // missing epochs
            "mpi-SGD:0x6@0",        // zero workers
            "mpi-SGD:4x0@0",        // zero epochs
            "nosuch-algo:4x6@0",    // unregistered strategy
            "dist-SGD:4x6@0",       // dist mode: no client worlds to rebuild
            "mpi-SGD.nosuch:4x6@0", // unregistered codec
            "mpi-SGD.int8.0:4x6@0", // zero devices
            "mpi-SGD:4x6@-5",       // negative arrival
            "mpi-SGD.int8.2.9:4x6@0", // too many fields
        ] {
            assert!(ArrivalPlan::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn gang_placement_is_all_or_nothing() {
        // Pool of 4; two 3-wide gangs arriving together: the second must
        // wait for the first to finish — never a partial 1-node world.
        let out = simulate(&spec(4, AllocPolicy::Static, "mpi-SGD:3x2@0,mpi-SGD:3x2@0")).unwrap();
        let (a, b) = (&out.jobs[0], &out.jobs[1]);
        assert_eq!(a.admitted_s, 0.0);
        assert_eq!(b.admitted_s, a.finished_s, "gang waits for a full 3-node hole");
        assert!(a.widths.iter().all(|&w| w == 3));
        assert!(b.widths.iter().all(|&w| w == 3));
        assert_eq!(out.audit.double_booked, 0);
        assert_eq!(out.audit.alloc_free_min, 4);
        assert_eq!(out.audit.alloc_free_max, 4);
    }

    #[test]
    fn pool_conserved_across_admit_complete_and_shrink() {
        // Elastic churn on a contended pool: grow, shrink, complete — the
        // integer ledger must balance after every event.
        let out = simulate(&spec(
            8,
            AllocPolicy::Elastic,
            "mpi-SGD:2x4@0,mpi-SGD:4x3@30,mpi-ESGD.int8:2x4@45",
        ))
        .unwrap();
        assert!(out.audit.snapshots > 0);
        assert_eq!(out.audit.double_booked, 0, "a node was double-booked");
        assert_eq!(out.audit.alloc_free_min, 8, "nodes leaked from the pool");
        assert_eq!(out.audit.alloc_free_max, 8, "nodes conjured into the pool");
        assert_eq!(out.jobs.len(), 3);
    }

    #[test]
    fn static_policy_never_synthesizes_churn() {
        let out =
            simulate(&spec(8, AllocPolicy::Static, "mpi-SGD:2x3@0,mpi-SGD:2x3@10")).unwrap();
        for j in &out.jobs {
            assert!(j.fault.is_empty(), "{} got churn under static allocation", j.name);
            assert!(j.widths.iter().all(|&w| w == j.base_workers));
        }
    }

    #[test]
    fn elastic_grows_into_idle_nodes_and_shrinks_under_contention() {
        // j0 alone on 6 nodes grows past its gang of 2; when j1's arrival
        // queues behind the grown allocation, j0 must shrink back to its
        // gang width at its next boundary so j1's gang fits.
        let out =
            simulate(&spec(6, AllocPolicy::Elastic, "mpi-SGD:2x8@0,mpi-SGD:6x2@9")).unwrap();
        let j0 = &out.jobs[0];
        assert!(j0.widths.iter().any(|&w| w > 2), "j0 never grew: {:?}", j0.widths);
        let joins = j0.fault.n_joins();
        let kills = j0.fault.events.len() - joins;
        assert!(joins > 0, "no synthesized joins: {}", j0.fault.render());
        assert!(kills > 0, "no synthesized kills: {}", j0.fault.render());
        // Post-shrink the gang width is restored, never undercut.
        assert!(j0.widths.iter().all(|&w| w >= 2));
        let j1 = &out.jobs[1];
        assert_eq!(j1.widths, vec![6; j1.widths.len()]);
        // Faster than static on the same plan: that's the whole point.
        let st = simulate(&spec(6, AllocPolicy::Static, "mpi-SGD:2x8@0,mpi-SGD:6x2@9")).unwrap();
        assert!(out.makespan_s < st.makespan_s, "{} vs {}", out.makespan_s, st.makespan_s);
    }

    #[test]
    fn synthesized_plans_are_valid_elastic_hub_schedules() {
        // The policy layer reuses the PR 3 machinery: every synthesized
        // plan must be accepted by ElasticHub::new, and the hub's epoch
        // tables must reproduce the authority's recorded widths.
        let out = simulate(&spec(
            8,
            AllocPolicy::Elastic,
            "mpi-SGD:2x5@0,mpi-SGD:4x3@20,mpi-SGD.topk:2x4@40",
        ))
        .unwrap();
        let ipe = 4u64;
        for j in &out.jobs {
            let hub = ElasticHub::new(&j.spec, Scheduler::new(0, 0), None)
                .unwrap_or_else(|e| panic!("{}: plan {:?} rejected: {e}", j.name, j.fault.render()));
            for e in 0..hub.n_epochs() as u64 {
                let b = hub.boundary_iter(e).unwrap();
                assert_eq!((b + 1) % ipe, 0, "boundary off the epoch grid");
                let epoch_idx = ((b + 1) / ipe) as usize;
                assert_eq!(
                    hub.members_after(e).len(),
                    j.widths[epoch_idx],
                    "{}: hub width diverges from the authority at epoch {epoch_idx}",
                    j.name
                );
            }
        }
    }

    #[test]
    fn single_job_cluster_is_bitwise_identical_to_plain_launch() {
        // Pool == gang width: no growth possible, the synthesized plan is
        // empty, and the cluster path must be bit-for-bit the plain
        // single-job launch.
        let cspec = spec(3, AllocPolicy::Elastic, "mpi-SGD:3x2@0");
        let (outcome, results) = execute(&cspec, allreduce_probe).unwrap();
        assert_eq!(outcome.jobs.len(), 1);
        let job = &outcome.jobs[0];
        assert!(job.fault.is_empty(), "alone at full pool: nothing to synthesize");
        let direct = launch(&job.spec, {
            let ticket = JobTicket { id: 0, name: "j0".into(), iters: job.iters };
            move |ctx| allreduce_probe(&ticket, ctx)
        })
        .unwrap();
        assert_eq!(results[0], direct, "cluster path diverged from plain launch");
        // And the payload is the expected full-world allreduce sum.
        for &(ran, last) in &results[0] {
            assert_eq!(ran, job.iters);
            assert_eq!(last, 3.0);
        }
    }

    #[test]
    fn execute_runs_concurrent_jobs_with_synthesized_churn() {
        // Two jobs on 4 nodes: j0 grows to 4 while alone, then shrinks
        // back to its gang when j1 queues; both replay their synthesized
        // plans on real threads against per-job quorums on one
        // ClusterScheduler.
        let cspec = spec(4, AllocPolicy::Elastic, "mpi-SGD:2x6@0,mpi-SGD:4x2@9");
        let (outcome, results) = execute(&cspec, allreduce_probe).unwrap();
        assert_eq!(results.len(), 2);
        let j0 = &outcome.jobs[0];
        let joins = j0.fault.n_joins();
        let kills = j0.fault.events.len() - joins;
        assert!(joins > 0 && kills > 0, "j0 should have grown and shrunk");
        // One result per launched rank: gang + synthesized joiners.
        assert_eq!(results[0].len(), j0.base_workers + joins);
        // Ranks that survive to the end run every planned iteration, and
        // their final allreduce sums the last epoch's world.
        let (ran0, last0) = results[0][0];
        assert_eq!(ran0, j0.iters);
        assert_eq!(last0, j0.widths.last().map(|&w| w as f32).unwrap());
        let j1 = &outcome.jobs[1];
        assert!(j1.fault.is_empty(), "j1 fills the pool: nothing to synthesize");
        for &(ran, last) in &results[1] {
            assert_eq!(ran, j1.iters);
            assert_eq!(last, 4.0);
        }
    }
}
