//! Simulated MPI: communicators, point-to-point and collectives.
//!
//! A from-scratch MPI subset over lossless ordered in-process channels —
//! the substitution for OpenMPI (DESIGN.md §2). Each MPI *client* in the
//! paper's hybrid model is an independent `MPI_COMM_WORLD` (§4.2.1); here
//! the launcher creates one [`World`] per client and hands each worker
//! thread its [`Comm`].
//!
//! Semantics mirrored from MPI. The core is **nonblocking**: `isend` /
//! `irecv` return [`Request`] handles with `wait` / `wait_any` / `test`
//! semantics over a posted-receive queue with (source, tag) matching and
//! out-of-order buffering — receives are matched in posting order, exactly
//! MPI's rule. The blocking `send`/`recv`/`sendrecv` calls are thin
//! wrappers over the request layer. On top sit a dissemination `barrier`,
//! binomial `bcast`, and a naive `allreduce` (the bandwidth-optimal
//! chunk-pipelined algorithms live in [`crate::collectives`] and are built
//! *on top of* these request primitives, exactly like OpenMPI's tuned
//! layer).

use crate::util::sync::{channel_named, Condvar, Mutex, Receiver, Sender, TryRecvError};
use std::sync::Arc;

/// A tagged message. `data` is the payload; collectives reserve the high
/// tag bit and a per-collective sequence number so user traffic can never
/// be confused with internal rounds.
#[derive(Debug)]
struct Msg {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

pub(crate) const COLL_BIT: u64 = 1 << 63;

/// A posted (not yet matched) or matched-but-not-waited receive.
#[derive(Debug)]
struct Posted {
    from: usize,
    tag: u64,
    /// `Some` once a message has been matched to this receive.
    data: Option<Vec<f32>>,
    /// Posting order — MPI matches arriving messages against posted
    /// receives in the order they were posted.
    seq: u64,
}

/// Handle to an in-flight nonblocking operation (MPI_Request).
///
/// Send requests complete immediately (buffered eager sends, like
/// `MPI_Send` under the eager threshold); receive requests complete when a
/// matching message arrives. Consume with [`Comm::wait`], or just drop it:
/// dropping an unconsumed *receive* request takes the `MPI_Cancel` path —
/// the slot is pushed onto the communicator's cancel list and reclaimed at
/// the next progress call (an already-matched payload is discarded with
/// the request, exactly like cancelling a matched receive). Dropped send
/// requests cost nothing.
#[derive(Debug)]
pub struct Request {
    kind: ReqKind,
    /// Cancel list shared with the owning communicator; `Some` only while
    /// an unconsumed receive is outstanding (the drop path pushes the slot
    /// there; consuming the request disarms it).
    cancel: Option<Arc<Mutex<Vec<usize>>>>,
}

impl Request {
    fn send() -> Self {
        Request { kind: ReqKind::Send, cancel: None }
    }

    fn recv(slot: usize, cancel: Arc<Mutex<Vec<usize>>>) -> Self {
        Request { kind: ReqKind::Recv(slot), cancel: Some(cancel) }
    }

    /// Mark the request consumed so its drop no longer cancels the slot.
    fn disarm(&mut self) {
        self.cancel = None;
    }
}

impl Drop for Request {
    fn drop(&mut self) {
        if let (ReqKind::Recv(slot), Some(cancel)) = (self.kind, &self.cancel) {
            cancel.lock().expect("cancel list lock poisoned").push(slot);
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum ReqKind {
    /// Buffered send: already complete.
    Send,
    /// Posted receive: slot index into the communicator's receive slab.
    Recv(usize),
}

/// The point-to-point surface the tuned collective schedules are written
/// against: exactly the subset of [`Comm`] that [`crate::collectives`]
/// uses (nonblocking receive + buffered send + completion waits).
///
/// Two implementors exist: [`Comm`] (the real fabric — messages move) and
/// the tracing communicator of [`crate::analysis`] (messages are recorded
/// as `(src, dst, tag, len)` events and checked, which is how `commcheck`
/// verifies every schedule without touching the production code paths).
/// The schedule functions are generic over this trait and monomorphize to
/// the concrete `Comm` on the training path — zero dispatch cost there.
pub trait CommOps {
    /// Request handle returned by [`CommOps::irecv`] (MPI_Request).
    type Req;

    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    /// Buffered send: completes immediately (MPI_Send under the eager
    /// threshold).
    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>);
    /// Blocking receive with (source, tag) matching.
    fn recv(&mut self, from: usize, tag: u64) -> Vec<f32>;
    /// Nonblocking receive; completes when a matching message arrives.
    fn irecv(&mut self, from: usize, tag: u64) -> Self::Req;
    /// Block until `req` completes; returns its payload.
    fn wait(&mut self, req: Self::Req) -> Vec<f32>;
    /// Block until any request completes; removes it from the vec and
    /// returns `(index_it_was_at, payload)` (MPI_Waitany).
    fn wait_any(&mut self, reqs: &mut Vec<Self::Req>) -> (usize, Vec<f32>);
}

/// One rank's endpoint of a communicator.
pub struct Comm {
    rank: usize,
    size: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Messages received but not yet matched (MPI unexpected-message queue).
    unexpected: Vec<Msg>,
    /// Posted-receive slab; `None` slots are free (recycled).
    posted: Vec<Option<Posted>>,
    free_slots: Vec<usize>,
    post_seq: u64,
    /// Collective sequence number, advanced identically on all ranks.
    coll_seq: u64,
    /// Slots of dropped-without-wait receive requests (the `MPI_Cancel`
    /// path): reclaimed on the next progress/post call.
    cancelled: Arc<Mutex<Vec<usize>>>,
    /// Rendezvous shared by all ranks of this communicator, used by
    /// [`Comm::split`] to build sub-communicators collectively.
    split_hub: Arc<SplitHub>,
}

/// An ordered set of world ranks (MPI_Group): the rank-translation half of
/// communicator construction. Position in the list *is* the group rank, so
/// `Group::new(vec![4, 0, 9])` maps group rank 1 to world rank 0.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Group {
    ranks: Vec<usize>,
}

impl Group {
    /// Build from an ordered rank list (must be duplicate-free).
    pub fn new(ranks: Vec<usize>) -> Self {
        let mut seen = ranks.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), ranks.len(), "group ranks must be unique");
        Self { ranks }
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// World rank of group member `group_rank`.
    pub fn world_rank(&self, group_rank: usize) -> usize {
        self.ranks[group_rank]
    }

    /// Group rank of `world_rank` (None if not a member) — the
    /// MPI_Group_rank translation survivors use after a world rebuild.
    pub fn rank_of(&self, world_rank: usize) -> Option<usize> {
        self.ranks.iter().position(|&r| r == world_rank)
    }

    /// The group minus `dead`, original order preserved — the survivor
    /// group of a membership epoch.
    pub fn exclude(&self, dead: &[usize]) -> Group {
        Group {
            ranks: self
                .ranks
                .iter()
                .copied()
                .filter(|r| !dead.contains(r))
                .collect(),
        }
    }

    /// Translate `rank_in_self` to the corresponding rank in `other`
    /// (MPI_Group_translate_ranks): members are identified by world rank.
    pub fn translate(&self, other: &Group, rank_in_self: usize) -> Option<usize> {
        other.rank_of(self.world_rank(rank_in_self))
    }
}

/// Collective-split rendezvous: every rank of a world deposits its
/// (color, key), the last arrival builds one fresh sub-world per color and
/// distributes the endpoints. Two-phase (collect -> distribute) so the hub
/// can be reused for repeated splits on the same communicator.
struct SplitHub {
    m: Mutex<SplitState>,
    cv: Condvar,
}

struct SplitState {
    /// Per-rank (color, key) entries for the in-flight split round.
    entries: Vec<Option<(i64, usize)>>,
    /// Built sub-communicators awaiting pickup (None for negative colors).
    outbox: Vec<Option<Comm>>,
    arrived: usize,
    collected: usize,
    distributing: bool,
}

impl SplitHub {
    fn new(size: usize) -> Self {
        Self {
            m: Mutex::named(
                SplitState {
                    entries: (0..size).map(|_| None).collect(),
                    outbox: (0..size).map(|_| None).collect(),
                    arrived: 0,
                    collected: 0,
                    distributing: false,
                },
                "mpisim.split",
            ),
            cv: Condvar::named("mpisim.split_cv"),
        }
    }
}

/// Factory for a fully-connected group of `Comm`s (one MPI_COMM_WORLD).
pub struct World;

impl World {
    /// Create a communicator of `size` ranks; element `i` goes to rank `i`'s
    /// thread.
    pub fn create(size: usize) -> Vec<Comm> {
        assert!(size > 0);
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..size).map(|_| channel_named("mpisim.mailbox")).unzip();
        let split_hub = Arc::new(SplitHub::new(size));
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| Comm {
                rank,
                size,
                txs: txs.clone(),
                rx,
                unexpected: Vec::new(),
                posted: Vec::new(),
                free_slots: Vec::new(),
                post_seq: 0,
                coll_seq: 0,
                cancelled: Arc::new(Mutex::named(Vec::new(), "mpisim.cancelled")),
                split_hub: split_hub.clone(),
            })
            .collect()
    }
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// The group underlying this communicator (ranks 0..size in order).
    pub fn group(&self) -> Group {
        Group::new((0..self.size).collect())
    }

    // -- communicator construction ------------------------------------------

    /// Collective split (MPI_Comm_split): every rank of this communicator
    /// must call it. Ranks passing the same non-negative `color` form a
    /// fresh sub-communicator, ordered by `(key, old rank)`; a negative
    /// color (MPI_UNDEFINED) yields `None`. The parent communicator stays
    /// fully usable, and sub-communicators can be split again.
    ///
    /// This is the epoch-scoped world-rebuild primitive: survivors of a
    /// membership epoch split with color 0 (the dying rank passes a
    /// negative color) and get a compacted world whose rank translation is
    /// `old_group.translate(new_group, old_rank)`.
    pub fn split(&mut self, color: i64, key: usize) -> Option<Comm> {
        if self.size == 1 {
            // Single-rank world: no rendezvous needed.
            return if color >= 0 {
                Some(World::create(1).pop().unwrap())
            } else {
                None
            };
        }
        let hub = self.split_hub.clone();
        let mut st = hub.m.lock().expect("split hub lock poisoned");
        // A previous split round may still be distributing: wait it out.
        while st.distributing {
            st = hub.cv.wait(st).expect("split hub lock poisoned mid-round");
        }
        st.entries[self.rank] = Some((color, key));
        st.arrived += 1;
        if st.arrived == self.size {
            // Last arrival builds every color's sub-world.
            let entries: Vec<(usize, i64, usize)> = st
                .entries
                .iter()
                .enumerate()
                .map(|(r, e)| {
                    let (c, k) = (*e).expect("split entry missing");
                    (r, c, k)
                })
                .collect();
            let mut colors: Vec<i64> =
                entries.iter().map(|&(_, c, _)| c).filter(|&c| c >= 0).collect();
            colors.sort_unstable();
            colors.dedup();
            for c in colors {
                let mut members: Vec<(usize, usize)> = entries
                    .iter()
                    .filter(|&&(_, ec, _)| ec == c)
                    .map(|&(r, _, k)| (k, r))
                    .collect();
                members.sort_unstable();
                let comms = World::create(members.len());
                for ((_, rank), comm) in members.into_iter().zip(comms) {
                    st.outbox[rank] = Some(comm);
                }
            }
            st.distributing = true;
            st.collected = 0;
            hub.cv.notify_all();
        } else {
            while !st.distributing {
                st = hub.cv.wait(st).expect("split hub lock poisoned at rendezvous");
            }
        }
        let out = st.outbox[self.rank].take();
        st.entries[self.rank] = None;
        st.collected += 1;
        if st.collected == self.size {
            // Round complete: reopen the hub for the next split.
            st.arrived = 0;
            st.distributing = false;
        }
        hub.cv.notify_all();
        out
    }

    // -- nonblocking core ---------------------------------------------------

    /// Nonblocking send. Completes immediately (buffered, like MPI_Send on
    /// a message that fits the eager threshold); the returned request
    /// exists for API symmetry with `irecv` in `wait_all` loops.
    pub fn isend(&mut self, to: usize, tag: u64, data: Vec<f32>) -> Request {
        assert!(tag & COLL_BIT == 0, "user tags must not set the collective bit");
        self.send_raw(to, tag, data);
        Request::send()
    }

    /// Nonblocking receive with (source, tag) matching: posts the receive
    /// and returns a [`Request`] that completes when a matching message
    /// arrives. Already-buffered unexpected messages match immediately.
    pub fn irecv(&mut self, from: usize, tag: u64) -> Request {
        assert!(tag & COLL_BIT == 0, "user tags must not set the collective bit");
        self.irecv_raw(from, tag)
    }

    fn irecv_raw(&mut self, from: usize, tag: u64) -> Request {
        self.reclaim_cancelled();
        // Unexpected queue first, in arrival order (per-sender FIFO).
        let data = self
            .unexpected
            .iter()
            .position(|m| m.from == from && m.tag == tag)
            .map(|pos| self.unexpected.remove(pos).data);
        let seq = self.post_seq;
        self.post_seq += 1;
        let posted = Posted { from, tag, data, seq };
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.posted[s] = Some(posted);
                s
            }
            None => {
                self.posted.push(Some(posted));
                self.posted.len() - 1
            }
        };
        Request::recv(slot, self.cancelled.clone())
    }

    /// Free the slots of receive requests that were dropped unconsumed
    /// (the `MPI_Cancel` drop path): an unmatched receive is withdrawn
    /// from the posted queue; a matched-but-unwaited payload is discarded
    /// with the request.
    fn reclaim_cancelled(&mut self) {
        let slots: Vec<usize> =
            std::mem::take(&mut *self.cancelled.lock().expect("cancel list lock poisoned"));
        for s in slots {
            if self.posted[s].take().is_some() {
                self.free_slots.push(s);
            }
        }
    }

    /// Match an arriving message against the earliest-posted pending
    /// receive (MPI's matching rule), or buffer it as unexpected.
    fn deliver(&mut self, msg: Msg) {
        let target = self
            .posted
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.as_ref().map(|p| (i, p)))
            .filter(|(_, p)| p.data.is_none() && p.from == msg.from && p.tag == msg.tag)
            .min_by_key(|(_, p)| p.seq)
            .map(|(i, _)| i);
        match target {
            Some(i) => self.posted[i].as_mut().unwrap().data = Some(msg.data),
            None => self.unexpected.push(msg),
        }
    }

    /// Drain every message already sitting in the channel (nonblocking
    /// progress, like MPI's internal progress engine).
    fn progress(&mut self) {
        self.reclaim_cancelled();
        loop {
            match self.rx.try_recv() {
                Ok(msg) => self.deliver(msg),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break,
            }
        }
    }

    fn slot_complete(&self, slot: usize) -> bool {
        self.posted[slot]
            .as_ref()
            .map(|p| p.data.is_some())
            .expect("request slot already consumed")
    }

    fn take_slot(&mut self, slot: usize) -> Vec<f32> {
        let data = self.posted[slot]
            .take()
            .expect("request slot already consumed")
            .data
            .expect("taking incomplete slot");
        self.free_slots.push(slot);
        data
    }

    /// Nonblocking completion test (MPI_Test without the deallocate-on-
    /// success: the request stays valid until waited).
    pub fn test(&mut self, req: &Request) -> bool {
        self.progress();
        match req.kind {
            ReqKind::Send => true,
            ReqKind::Recv(slot) => self.slot_complete(slot),
        }
    }

    /// Block until `req` completes; returns its payload (empty for sends).
    pub fn wait(&mut self, mut req: Request) -> Vec<f32> {
        req.disarm(); // consumed here, not by the cancel-on-drop path
        match req.kind {
            ReqKind::Send => Vec::new(),
            ReqKind::Recv(slot) => {
                self.progress();
                while !self.slot_complete(slot) {
                    let msg = self.rx.recv().expect("world torn down mid-recv");
                    self.deliver(msg);
                }
                self.take_slot(slot)
            }
        }
    }

    /// Block until *any* request in `reqs` completes; removes it from the
    /// vec and returns `(index_it_was_at, payload)` (MPI_Waitany). Panics
    /// on an empty vec.
    pub fn wait_any(&mut self, reqs: &mut Vec<Request>) -> (usize, Vec<f32>) {
        assert!(!reqs.is_empty(), "wait_any on no requests");
        self.progress();
        loop {
            let ready = reqs.iter().position(|r| match r.kind {
                ReqKind::Send => true,
                ReqKind::Recv(slot) => self.slot_complete(slot),
            });
            if let Some(i) = ready {
                let mut req = reqs.remove(i);
                req.disarm();
                let data = match req.kind {
                    ReqKind::Send => Vec::new(),
                    ReqKind::Recv(slot) => self.take_slot(slot),
                };
                return (i, data);
            }
            let msg = self.rx.recv().expect("world torn down mid-recv");
            self.deliver(msg);
        }
    }

    /// Block until every request completes; payloads in request order.
    pub fn wait_all(&mut self, reqs: Vec<Request>) -> Vec<Vec<f32>> {
        reqs.into_iter().map(|r| self.wait(r)).collect()
    }

    // -- blocking wrappers --------------------------------------------------

    /// Blocking send (thin wrapper over [`Comm::isend`]; buffered sends
    /// complete immediately).
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) {
        let _ = self.isend(to, tag, data);
    }

    fn send_raw(&self, to: usize, tag: u64, data: Vec<f32>) {
        self.txs[to]
            .send(Msg { from: self.rank, tag, data })
            .expect("peer hung up");
    }

    /// Blocking receive with (source, tag) matching — `wait(irecv(...))`.
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        assert!(tag & COLL_BIT == 0, "user tags must not set the collective bit");
        self.recv_raw(from, tag)
    }

    fn recv_raw(&mut self, from: usize, tag: u64) -> Vec<f32> {
        let req = self.irecv_raw(from, tag);
        self.wait(req)
    }

    /// Simultaneous send+recv (deadlock-free ring step).
    pub fn sendrecv(
        &mut self,
        to: usize,
        send_tag: u64,
        data: Vec<f32>,
        from: usize,
        recv_tag: u64,
    ) -> Vec<f32> {
        // Buffered sends complete immediately, so send-then-recv is safe.
        self.send_raw(to, send_tag, data);
        self.recv_raw(from, recv_tag)
    }

    fn next_coll_tag(&mut self, round: u64) -> u64 {
        COLL_BIT | (self.coll_seq << 16) | round
    }

    fn finish_collective(&mut self) {
        self.coll_seq += 1;
    }

    /// Dissemination barrier: ceil(log2(p)) rounds.
    pub fn barrier(&mut self) {
        let p = self.size;
        if p > 1 {
            let mut k = 1usize;
            let mut round = 0u64;
            while k < p {
                let tag = self.next_coll_tag(round);
                let to = (self.rank + k) % p;
                let from = (self.rank + p - k) % p;
                self.send_raw(to, tag, Vec::new());
                let _ = self.recv_raw(from, tag);
                k <<= 1;
                round += 1;
            }
        }
        self.finish_collective();
    }

    /// Binomial-tree broadcast from `root` (the MPICH algorithm). Used to
    /// initialize weights when there are no PS servers (§4.2.1) and as the
    /// pull-side fan-out inside an MPI client.
    pub fn bcast(&mut self, root: usize, data: &mut Vec<f32>) {
        let p = self.size;
        if p > 1 {
            let tag = self.next_coll_tag(0);
            let vrank = (self.rank + p - root) % p;
            // Receive phase: wait for the parent (clears our lowest set bit).
            let mut mask = 1usize;
            while mask < p {
                if vrank & mask != 0 {
                    let parent = ((vrank ^ mask) + root) % p;
                    *data = self.recv_raw(parent, tag);
                    break;
                }
                mask <<= 1;
            }
            // Forward phase: send to children at decreasing bit positions.
            mask >>= 1;
            while mask > 0 {
                let child = vrank + mask;
                if vrank & mask == 0 && child < p {
                    self.send_raw((child + root) % p, tag, data.clone());
                }
                mask >>= 1;
            }
        }
        self.finish_collective();
    }

    /// Gather-to-root + reduce + broadcast. The *naive* allreduce the paper
    /// contrasts with bucket rings; also the correctness oracle in tests.
    pub fn allreduce_naive(&mut self, data: &mut Vec<f32>) {
        let p = self.size;
        if p > 1 {
            let tag = self.next_coll_tag(0);
            if self.rank == 0 {
                for r in 1..p {
                    let part = self.recv_raw(r, tag);
                    crate::tensor::add_assign(data, &part);
                }
            } else {
                self.send_raw(0, tag, data.clone());
            }
            self.finish_collective();
            self.bcast(0, data);
        } else {
            self.finish_collective();
        }
    }
}

impl CommOps for Comm {
    type Req = Request;

    fn rank(&self) -> usize {
        Comm::rank(self)
    }

    fn size(&self) -> usize {
        Comm::size(self)
    }

    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) {
        Comm::send(self, to, tag, data)
    }

    fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        Comm::recv(self, from, tag)
    }

    fn irecv(&mut self, from: usize, tag: u64) -> Request {
        Comm::irecv(self, from, tag)
    }

    fn wait(&mut self, req: Request) -> Vec<f32> {
        Comm::wait(self, req)
    }

    fn wait_any(&mut self, reqs: &mut Vec<Request>) -> (usize, Vec<f32>) {
        Comm::wait_any(self, reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F, R>(size: usize, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Clone + Send + 'static,
        R: Send + 'static,
    {
        let comms = World::create(size);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn p2p_send_recv() {
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                c.send(1, 7, vec![1.0, 2.0]);
                vec![]
            } else {
                c.recv(0, 7)
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn recv_matches_tag_out_of_order() {
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                c.send(1, 1, vec![1.0]);
                c.send(1, 2, vec![2.0]);
                vec![]
            } else {
                // Receive tag 2 first even though tag 1 arrived first.
                let b = c.recv(0, 2);
                let a = c.recv(0, 1);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn irecv_wait_round_trip() {
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                let r = c.isend(1, 3, vec![9.0]);
                assert!(c.test(&r)); // buffered sends are instantly done
                c.wait(r)
            } else {
                let r = c.irecv(0, 3);
                c.wait(r)
            }
        });
        assert_eq!(out[1], vec![9.0]);
        assert!(out[0].is_empty()); // send request carries no payload
    }

    #[test]
    fn wait_any_returns_whichever_completes() {
        // Rank 0 sends tags in reverse posting order; rank 1 drains with
        // wait_any and must see every payload exactly once.
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                for tag in (0..4u64).rev() {
                    c.send(1, tag, vec![tag as f32]);
                }
                Vec::new()
            } else {
                let mut reqs: Vec<Request> = (0..4u64).map(|t| c.irecv(0, t)).collect();
                let mut got = Vec::new();
                while !reqs.is_empty() {
                    let (_, data) = c.wait_any(&mut reqs);
                    got.push(data[0]);
                }
                got.sort_by(|a, b| a.total_cmp(b));
                got
            }
        });
        assert_eq!(out[1], vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn test_polls_without_blocking() {
        use std::sync::mpsc::channel as ch;
        let comms = World::create(2);
        let mut it = comms.into_iter();
        let mut c0 = it.next().unwrap();
        let mut c1 = it.next().unwrap();
        let (gate_tx, gate_rx) = ch::<()>();
        let h = thread::spawn(move || {
            gate_rx.recv().unwrap();
            c0.send(1, 5, vec![7.0]);
        });
        let req = c1.irecv(0, 5);
        assert!(!c1.test(&req)); // nothing sent yet: must not block
        gate_tx.send(()).unwrap();
        assert_eq!(c1.wait(req), vec![7.0]);
        h.join().unwrap();
    }

    #[test]
    fn interleaved_irecvs_match_in_posting_order() {
        // Two messages on the same (source, tag): the first-posted irecv
        // gets the first-sent payload (MPI posting-order matching).
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                c.send(1, 9, vec![1.0]);
                c.send(1, 9, vec![2.0]);
                Vec::new()
            } else {
                let r1 = c.irecv(0, 9);
                let r2 = c.irecv(0, 9);
                let second = c.wait(r2);
                let first = c.wait(r1);
                vec![first[0], second[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn barrier_completes_all_sizes() {
        for p in [1, 2, 3, 5, 8] {
            run_world(p, |mut c| {
                for _ in 0..3 {
                    c.barrier();
                }
            });
        }
    }

    #[test]
    fn bcast_all_sizes_all_roots() {
        for p in [1, 2, 3, 4, 7] {
            for root in 0..p {
                let out = run_world(p, move |mut c| {
                    let mut data = if c.rank() == root {
                        vec![3.5, -1.0, root as f32]
                    } else {
                        Vec::new()
                    };
                    c.bcast(root, &mut data);
                    data
                });
                for d in out {
                    assert_eq!(d, vec![3.5, -1.0, root as f32]);
                }
            }
        }
    }

    #[test]
    fn allreduce_naive_sums() {
        for p in [1, 2, 3, 6] {
            let out = run_world(p, move |mut c| {
                let mut data = vec![c.rank() as f32 + 1.0; 5];
                c.allreduce_naive(&mut data);
                data
            });
            let expect = (p * (p + 1) / 2) as f32;
            for d in out {
                assert!(d.iter().all(|&x| x == expect), "{d:?} != {expect}");
            }
        }
    }

    #[test]
    fn consecutive_collectives_do_not_cross_talk() {
        let out = run_world(3, |mut c| {
            let mut a = vec![c.rank() as f32];
            c.allreduce_naive(&mut a);
            let mut b = vec![10.0 * c.rank() as f32];
            c.allreduce_naive(&mut b);
            c.barrier();
            (a[0], b[0])
        });
        for (a, b) in out {
            assert_eq!(a, 3.0);
            assert_eq!(b, 30.0);
        }
    }

    #[test]
    fn sendrecv_ring_rotates() {
        let p = 4;
        let out = run_world(p, move |mut c| {
            let right = (c.rank() + 1) % p;
            let left = (c.rank() + p - 1) % p;
            c.sendrecv(right, 9, vec![c.rank() as f32], left, 9)
        });
        for (r, d) in out.iter().enumerate() {
            assert_eq!(d[0], ((r + p - 1) % p) as f32);
        }
    }

    #[test]
    fn dropped_recv_requests_reclaim_slots() {
        // Regression: dropping an unconsumed Request used to leak its
        // receive-slab slot for the communicator's lifetime. The drop path
        // now cancels the slot and progress reclaims it.
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                // Nothing sent on tag 1: the receives below never match.
                c.send(1, 0, vec![1.0]);
                0
            } else {
                for _ in 0..100 {
                    let req = c.irecv(0, 1);
                    drop(req); // cancelled, never waited
                }
                // The matched path still works after mass cancellation...
                let r = c.irecv(0, 0);
                assert_eq!(c.wait(r), vec![1.0]);
                // ...and the slab stayed bounded (reclaim runs on post).
                c.posted.len()
            }
        });
        assert!(out[1] <= 2, "slab grew to {}", out[1]);
    }

    #[test]
    fn dropped_matched_request_discards_payload() {
        // Cancelling a receive that already matched discards the payload
        // with the request (MPI_Cancel on a matched recv); the slot is
        // still reclaimed and later receives are unaffected.
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                c.send(1, 7, vec![1.0]);
                c.send(1, 8, vec![2.0]);
                Vec::new()
            } else {
                let doomed = c.irecv(0, 7);
                // Force the match before dropping.
                while !c.test(&doomed) {
                    std::thread::yield_now();
                }
                drop(doomed);
                let r = c.irecv(0, 8);
                c.wait(r)
            }
        });
        assert_eq!(out[1], vec![2.0]);
    }

    #[test]
    fn group_translates_ranks_across_rebuilds() {
        let old = Group::new((0..5).collect());
        let survivors = old.exclude(&[1, 3]);
        assert_eq!(survivors.size(), 3);
        // World ranks 0, 2, 4 become new ranks 0, 1, 2.
        assert_eq!(survivors.rank_of(2), Some(1));
        assert_eq!(survivors.rank_of(3), None);
        assert_eq!(survivors.world_rank(2), 4);
        assert_eq!(old.translate(&survivors, 4), Some(2));
        assert_eq!(old.translate(&survivors, 1), None);
    }

    #[test]
    fn split_by_color_forms_independent_subworlds() {
        // 6 ranks, color = rank % 2: two 3-rank sub-worlds whose
        // allreduces never cross-talk, while the parent stays usable.
        let out = run_world(6, |mut c| {
            let color = (c.rank() % 2) as i64;
            let mut sub = c.split(color, c.rank()).expect("non-negative color");
            let mut d = vec![c.rank() as f32];
            sub.allreduce_naive(&mut d);
            let mut parent = vec![1.0f32];
            c.allreduce_naive(&mut parent);
            (c.rank(), sub.rank(), sub.size(), d[0], parent[0])
        });
        for (rank, sub_rank, sub_size, sum, psum) in out {
            assert_eq!(sub_size, 3);
            assert_eq!(sub_rank, rank / 2); // members ordered by old rank
            let expect = if rank % 2 == 0 { 0.0 + 2.0 + 4.0 } else { 1.0 + 3.0 + 5.0 };
            assert_eq!(sum, expect, "rank {rank}");
            assert_eq!(psum, 6.0);
        }
    }

    #[test]
    fn split_orders_by_key_then_negative_color_opts_out() {
        let out = run_world(4, |mut c| {
            if c.rank() == 3 {
                // MPI_UNDEFINED: not a member of any sub-world.
                assert!(c.split(-1, 0).is_none());
                usize::MAX
            } else {
                // Reverse the order via the key: old rank 2 -> new rank 0.
                let sub = c.split(0, 10 - c.rank()).unwrap();
                assert_eq!(sub.size(), 3);
                sub.rank()
            }
        });
        assert_eq!(out[..3], [2, 1, 0]);
    }

    #[test]
    fn split_epoch_scoped_shrink_with_rank_translation() {
        // The membership-epoch pattern: rank 1 "dies" (negative color);
        // survivors rebuild a compacted world and translate ranks via the
        // Group, then allreduce over the new world only.
        let out = run_world(4, |mut c| {
            let old_group = c.group();
            let dead = [1usize];
            let dying = dead.contains(&c.rank());
            let sub = c.split(if dying { -1 } else { 0 }, c.rank());
            match sub {
                None => {
                    assert!(dying);
                    -1.0
                }
                Some(mut sub) => {
                    let survivors = old_group.exclude(&dead);
                    assert_eq!(
                        survivors.rank_of(c.rank()),
                        Some(sub.rank()),
                        "split rank must equal group translation"
                    );
                    let mut d = vec![1.0f32];
                    sub.allreduce_naive(&mut d);
                    d[0]
                }
            }
        });
        assert_eq!(out, vec![3.0, -1.0, 3.0, 3.0]);
    }

    #[test]
    fn split_supports_repeated_rounds() {
        // Two consecutive splits on the same parent reuse the hub.
        run_world(3, |mut c| {
            for round in 0..3i64 {
                let mut sub = c.split(round % 2, c.rank()).unwrap();
                let mut d = vec![1.0f32];
                sub.allreduce_naive(&mut d);
                assert_eq!(d[0], 3.0, "round {round}");
            }
        });
    }

    #[test]
    fn recv_slots_recycle() {
        // Many sequential irecv/wait cycles must not grow the slab.
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                for i in 0..100u64 {
                    c.send(1, i % 4, vec![i as f32]);
                }
                0
            } else {
                for i in 0..100u64 {
                    let r = c.irecv(0, i % 4);
                    assert_eq!(c.wait(r), vec![i as f32]);
                }
                c.posted.len()
            }
        });
        assert!(out[1] <= 2, "slab grew to {}", out[1]);
    }
}
