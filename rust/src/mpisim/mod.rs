//! Simulated MPI: communicators, point-to-point and collectives.
//!
//! A from-scratch MPI subset over lossless ordered in-process channels —
//! the substitution for OpenMPI (DESIGN.md §2). Each MPI *client* in the
//! paper's hybrid model is an independent `MPI_COMM_WORLD` (§4.2.1); here
//! the launcher creates one [`World`] per client and hands each worker
//! thread its [`Comm`].
//!
//! Semantics mirrored from MPI: blocking `send`/`recv` with (source, tag)
//! matching and out-of-order buffering, dissemination `barrier`, binomial
//! `bcast`, and a naive `allreduce` (the bandwidth-optimal bucket/ring
//! algorithms live in [`crate::collectives`] and are built *on top of*
//! these point-to-point primitives, exactly like OpenMPI's tuned layer).

use std::sync::mpsc::{channel, Receiver, Sender};

/// A tagged message. `data` is the payload; collectives reserve the high
/// tag bit and a per-collective sequence number so user traffic can never
/// be confused with internal rounds.
#[derive(Debug)]
struct Msg {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

const COLL_BIT: u64 = 1 << 63;

/// One rank's endpoint of a communicator.
pub struct Comm {
    rank: usize,
    size: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Messages received but not yet matched (MPI unexpected-message queue).
    unexpected: Vec<Msg>,
    /// Collective sequence number, advanced identically on all ranks.
    coll_seq: u64,
}

/// Factory for a fully-connected group of `Comm`s (one MPI_COMM_WORLD).
pub struct World;

impl World {
    /// Create a communicator of `size` ranks; element `i` goes to rank `i`'s
    /// thread.
    pub fn create(size: usize) -> Vec<Comm> {
        assert!(size > 0);
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..size).map(|_| channel()).unzip();
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| Comm {
                rank,
                size,
                txs: txs.clone(),
                rx,
                unexpected: Vec::new(),
                coll_seq: 0,
            })
            .collect()
    }
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Blocking send (buffered: completes immediately, like MPI_Send on a
    /// message that fits the eager threshold).
    pub fn send(&self, to: usize, tag: u64, data: Vec<f32>) {
        assert!(tag & COLL_BIT == 0, "user tags must not set the collective bit");
        self.send_raw(to, tag, data);
    }

    fn send_raw(&self, to: usize, tag: u64, data: Vec<f32>) {
        self.txs[to]
            .send(Msg { from: self.rank, tag, data })
            .expect("peer hung up");
    }

    /// Blocking receive with (source, tag) matching.
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        assert!(tag & COLL_BIT == 0, "user tags must not set the collective bit");
        self.recv_raw(from, tag)
    }

    fn recv_raw(&mut self, from: usize, tag: u64) -> Vec<f32> {
        if let Some(pos) = self
            .unexpected
            .iter()
            .position(|m| m.from == from && m.tag == tag)
        {
            return self.unexpected.remove(pos).data;
        }
        loop {
            let msg = self.rx.recv().expect("world torn down mid-recv");
            if msg.from == from && msg.tag == tag {
                return msg.data;
            }
            self.unexpected.push(msg);
        }
    }

    /// Simultaneous send+recv (deadlock-free ring step).
    pub fn sendrecv(
        &mut self,
        to: usize,
        send_tag: u64,
        data: Vec<f32>,
        from: usize,
        recv_tag: u64,
    ) -> Vec<f32> {
        // Buffered sends complete immediately, so send-then-recv is safe.
        self.send_raw(to, send_tag, data);
        self.recv_raw(from, recv_tag)
    }

    fn next_coll_tag(&mut self, round: u64) -> u64 {
        COLL_BIT | (self.coll_seq << 16) | round
    }

    fn finish_collective(&mut self) {
        self.coll_seq += 1;
    }

    /// Dissemination barrier: ceil(log2(p)) rounds.
    pub fn barrier(&mut self) {
        let p = self.size;
        if p > 1 {
            let mut k = 1usize;
            let mut round = 0u64;
            while k < p {
                let tag = self.next_coll_tag(round);
                let to = (self.rank + k) % p;
                let from = (self.rank + p - k) % p;
                self.send_raw(to, tag, Vec::new());
                let _ = self.recv_raw(from, tag);
                k <<= 1;
                round += 1;
            }
        }
        self.finish_collective();
    }

    /// Binomial-tree broadcast from `root` (the MPICH algorithm). Used to
    /// initialize weights when there are no PS servers (§4.2.1) and as the
    /// pull-side fan-out inside an MPI client.
    pub fn bcast(&mut self, root: usize, data: &mut Vec<f32>) {
        let p = self.size;
        if p > 1 {
            let tag = self.next_coll_tag(0);
            let vrank = (self.rank + p - root) % p;
            // Receive phase: wait for the parent (clears our lowest set bit).
            let mut mask = 1usize;
            while mask < p {
                if vrank & mask != 0 {
                    let parent = ((vrank ^ mask) + root) % p;
                    *data = self.recv_raw(parent, tag);
                    break;
                }
                mask <<= 1;
            }
            // Forward phase: send to children at decreasing bit positions.
            mask >>= 1;
            while mask > 0 {
                let child = vrank + mask;
                if vrank & mask == 0 && child < p {
                    self.send_raw((child + root) % p, tag, data.clone());
                }
                mask >>= 1;
            }
        }
        self.finish_collective();
    }

    /// Gather-to-root + reduce + broadcast. The *naive* allreduce the paper
    /// contrasts with bucket rings; also the correctness oracle in tests.
    pub fn allreduce_naive(&mut self, data: &mut Vec<f32>) {
        let p = self.size;
        if p > 1 {
            let tag = self.next_coll_tag(0);
            if self.rank == 0 {
                for r in 1..p {
                    let part = self.recv_raw(r, tag);
                    crate::tensor::add_assign(data, &part);
                }
            } else {
                self.send_raw(0, tag, data.clone());
            }
            self.finish_collective();
            self.bcast(0, data);
        } else {
            self.finish_collective();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F, R>(size: usize, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Clone + Send + 'static,
        R: Send + 'static,
    {
        let comms = World::create(size);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn p2p_send_recv() {
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                c.send(1, 7, vec![1.0, 2.0]);
                vec![]
            } else {
                c.recv(0, 7)
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn recv_matches_tag_out_of_order() {
        let out = run_world(2, |mut c| {
            if c.rank() == 0 {
                c.send(1, 1, vec![1.0]);
                c.send(1, 2, vec![2.0]);
                vec![]
            } else {
                // Receive tag 2 first even though tag 1 arrived first.
                let b = c.recv(0, 2);
                let a = c.recv(0, 1);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn barrier_completes_all_sizes() {
        for p in [1, 2, 3, 5, 8] {
            run_world(p, |mut c| {
                for _ in 0..3 {
                    c.barrier();
                }
            });
        }
    }

    #[test]
    fn bcast_all_sizes_all_roots() {
        for p in [1, 2, 3, 4, 7] {
            for root in 0..p {
                let out = run_world(p, move |mut c| {
                    let mut data = if c.rank() == root {
                        vec![3.5, -1.0, root as f32]
                    } else {
                        Vec::new()
                    };
                    c.bcast(root, &mut data);
                    data
                });
                for d in out {
                    assert_eq!(d, vec![3.5, -1.0, root as f32]);
                }
            }
        }
    }

    #[test]
    fn allreduce_naive_sums() {
        for p in [1, 2, 3, 6] {
            let out = run_world(p, move |mut c| {
                let mut data = vec![c.rank() as f32 + 1.0; 5];
                c.allreduce_naive(&mut data);
                data
            });
            let expect = (p * (p + 1) / 2) as f32;
            for d in out {
                assert!(d.iter().all(|&x| x == expect), "{d:?} != {expect}");
            }
        }
    }

    #[test]
    fn consecutive_collectives_do_not_cross_talk() {
        let out = run_world(3, |mut c| {
            let mut a = vec![c.rank() as f32];
            c.allreduce_naive(&mut a);
            let mut b = vec![10.0 * c.rank() as f32];
            c.allreduce_naive(&mut b);
            c.barrier();
            (a[0], b[0])
        });
        for (a, b) in out {
            assert_eq!(a, 3.0);
            assert_eq!(b, 30.0);
        }
    }

    #[test]
    fn sendrecv_ring_rotates() {
        let p = 4;
        let out = run_world(p, move |mut c| {
            let right = (c.rank() + 1) % p;
            let left = (c.rank() + p - 1) % p;
            c.sendrecv(right, 9, vec![c.rank() as f32], left, 9)
        });
        for (r, d) in out.iter().enumerate() {
            assert_eq!(d[0], ((r + p - 1) % p) as f32);
        }
    }
}
