//! `mxnet-mpi` CLI: the launcher front end (§4.1.2).
//!
//! Subcommands (hand-rolled parsing: the offline build has no clap):
//!
//!   train   --algo mpi-SGD --workers 12 --servers 2 --clients 2 ...
//!           Run the real threaded framework (wall-clock).
//!   sim     --algo ... [same flags]
//!           Run the virtual-time plane (paper-testbed clock).
//!   figures [--epochs N]
//!           Regenerate every convergence figure CSV (11-14, 16).
//!   collectives
//!           Print the §6 cost-model comparison (Figs 15/17-20 data).
//!   commcheck
//!           Statically verify every registered communication schedule
//!           (deadlock / tag-window / coverage / elastic-epoch / engine
//!           plans) and prove the verifier on the seeded-mutant suite.
//!           Exits non-zero on any finding — the CI gate.
//!   racecheck [--scenario S] [--max-execs N] [--seed SEED]
//!           Dynamically model-check the threaded plane's concurrency
//!           protocols under systematically explored interleavings and
//!           prove the checker on its own seeded-mutant suite. A failure
//!           prints a replayable schedule seed; `--seed` re-runs exactly
//!           that interleaving. Exits non-zero on any finding — the
//!           second CI gate.
//!   cluster --nodes 8 --policy elastic --arrivals mpi-SGD:4x6@0,...
//!           Run the multi-tenant cluster authority on a scripted job
//!           arrival plan and compare static vs elastic goodput.
//!   info
//!           Show artifact metadata and testbed presets.

use anyhow::{bail, Context, Result};
use mxnet_mpi::cluster::{simulate, AllocPolicy, ArrivalPlan, ClusterSpec};
use mxnet_mpi::config::{Algo, ExperimentConfig};
use mxnet_mpi::metrics::Table;
use std::path::PathBuf;

fn usage() -> ! {
    // The algorithm list is derived from the registry, so this text can
    // never drift from the set of runnable strategies.
    eprintln!(
        "usage: mxnet-mpi <train|sim|figures|collectives|commcheck|racecheck|cluster|info> [flags]\n\
         flags for train/sim:\n\
           --algo NAME            one of: {} (case-insensitive)\n\
           --variant NAME         model variant (default mlp)\n\
           --workers N --servers N --clients N\n\
           --epochs N --batch-epochs SAMPLES --lr F --alpha F --interval N\n\
           --block-momentum F     BMUF block momentum eta (default 0.5)\n\
           --warmup-iters N       local-sgd post-local warmup iterations\n\
           --collective ring|halving_doubling|hierarchical|two_tier|auto\n\
           --devices K            devices per worker (>= 1); batches split\n\
                                  into K shards of b/K and two_tier reduces\n\
                                  locally before the inter-node hop\n\
           --fusion-bytes N       gradient-fusion bucket cap (0 = off)\n\
           --overlap on|off       compute/communication overlap (sim plane)\n\
           --pipeline-chunks N    sub-chunks per pipelined collective step\n\
           --threads N            compute-plane kernel threads (0 = auto,\n\
                                  1 = scalar path; results are bitwise\n\
                                  identical at any setting)\n\
           --compression NAME     gradient codec, one of: {}\n\
           --topk-ratio F         fraction the topk codec keeps, in (0, 1]\n\
           --fault PLAN           scripted churn, e.g. kill:3@200,join@300\n\
                                  (kill:R@N | straggle:R@NxF | join[:C]@N)\n\
           --config FILE.json     load an ExperimentConfig (flags override)\n\
           --artifacts DIR        (default ./artifacts)\n\
           --out DIR              results dir (default ./results)\n\
         flags for cluster:\n\
           --nodes N              shared node-pool size (default 8)\n\
           --policy static|elastic  allocation policy (default elastic)\n\
           --arrivals PLAN        scripted job arrivals, comma-separated\n\
                                  ALGO[.CODEC[.DEVICES]]:WxE@T — W nodes\n\
                                  arrive wanting E epochs at second T,\n\
                                  e.g. mpi-SGD:4x6@0,mpi-ESGD.int8:2x6@120\n\
           --epoch-iters N        iterations per membership epoch (default 8)\n\
         flags for racecheck:\n\
           --scenario NAME        check one scenario (default: all)\n\
           --max-execs N          systematic executions per (scenario, world)\n\
           --seed SEED            replay one recorded interleaving\n\
                                  (rc1:<scenario>:w<world>:<tape>)",
        Algo::names().join(", "),
        mxnet_mpi::compress::Codec::names().join(", ")
    );
    std::process::exit(2);
}

struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // Only a `--`-prefixed token is a flag; anything else —
                // including `-`-leading numerics like `--block-momentum
                // -0.5` — is the preceding flag's value.
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                eprintln!("unexpected argument {a:?}");
                usage();
            }
        }
        Self { flags }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    /// Numeric flag value. A present-but-unparseable value — a negative
    /// number fed to a count flag (`--workers -3`), a typo, or a flag left
    /// without a value (recorded as "true") — is a named error here: the
    /// old `parse().ok()` silently dropped it, so the run proceeded on the
    /// default as if the flag were missing, which read like a "missing
    /// value" bug to the user. Config validation then names any field
    /// whose *parsed* value is out of range.
    fn num<T: std::str::FromStr>(&self, k: &str) -> Result<Option<T>> {
        match self.get(k) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                anyhow::anyhow!(
                    "flag --{k}: invalid value {v:?} (expected a {})",
                    std::any::type_name::<T>()
                )
            }),
        }
    }
}

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let algo = match args.get("algo") {
        Some(s) => Algo::parse(s).with_context(|| {
            format!(
                "unknown algo {s:?} (registered: {})",
                Algo::names().join(", ")
            )
        })?,
        None => Algo::named("mpi-SGD"),
    };
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::testbed1(algo),
    };
    if args.get("config").is_some() && args.get("algo").is_some() {
        cfg.algo = algo;
    }
    if let Some(v) = args.get("variant") {
        cfg.variant = v.into();
    }
    if let Some(v) = args.get("collective") {
        anyhow::ensure!(
            mxnet_mpi::collectives::AlgoKind::parse(v).is_some(),
            "unknown collective {v:?} (valid: ring, halving_doubling, hierarchical, two_tier, auto)"
        );
        cfg.collective = v.into();
    }
    if let Some(v) = args.get("compression") {
        anyhow::ensure!(
            mxnet_mpi::compress::Codec::parse(v).is_some(),
            "unknown compression {v:?} (registered: {})",
            mxnet_mpi::compress::Codec::names().join(", ")
        );
        cfg.compression = v.into();
    }
    macro_rules! ovr {
        ($field:ident, $flag:expr, $ty:ty) => {
            if let Some(v) = args.num::<$ty>($flag)? {
                cfg.$field = v;
            }
        };
    }
    ovr!(workers, "workers", usize);
    ovr!(servers, "servers", usize);
    ovr!(clients, "clients", usize);
    ovr!(epochs, "epochs", usize);
    ovr!(samples_per_epoch, "samples-per-epoch", u64);
    ovr!(lr, "lr", f32);
    ovr!(alpha, "alpha", f32);
    ovr!(interval, "interval", usize);
    ovr!(block_momentum, "block-momentum", f32);
    ovr!(warmup_iters, "warmup-iters", usize);
    ovr!(rings, "rings", usize);
    ovr!(devices, "devices", usize);
    ovr!(fusion_bytes, "fusion-bytes", usize);
    ovr!(pipeline_chunks, "pipeline-chunks", usize);
    ovr!(threads, "threads", usize);
    ovr!(topk_ratio, "topk-ratio", f64);
    ovr!(seed, "seed", u64);
    anyhow::ensure!(
        cfg.topk_ratio.is_finite() && cfg.topk_ratio > 0.0 && cfg.topk_ratio <= 1.0,
        "--topk-ratio must be in (0, 1], got {}",
        cfg.topk_ratio
    );
    // Same class of loud rejection as the servers=-1 fix: `--devices -2`
    // already fails in num() (usize parse), so only zero reaches here.
    anyhow::ensure!(
        cfg.devices >= 1,
        "--devices must be >= 1 (a worker has at least one device), got {}",
        cfg.devices
    );
    if let Some(v) = args.get("overlap") {
        cfg.overlap = v != "off" && v != "false" && v != "0";
    }
    if let Some(v) = args.get("fault") {
        cfg.fault = v.to_string();
        cfg.fault_plan()
            .with_context(|| format!("bad --fault {v:?}"))?;
    }
    Ok(cfg)
}

/// Assemble the cluster authority's spec from CLI flags over config
/// defaults (`--config` respected like train/sim).
fn build_cluster_spec(args: &Args) -> Result<ClusterSpec> {
    let cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::testbed1(Algo::named("mpi-SGD")),
    };
    let nodes = args.num::<usize>("nodes")?.unwrap_or(cfg.cluster_nodes);
    let policy_name = args.get("policy").unwrap_or(&cfg.cluster_policy);
    let policy = AllocPolicy::parse(policy_name).with_context(|| {
        format!("unknown --policy {policy_name:?} (valid: static, elastic)")
    })?;
    let arrivals = args.get("arrivals").unwrap_or(&cfg.arrivals);
    let plan = ArrivalPlan::parse(arrivals)
        .with_context(|| format!("bad --arrivals {arrivals:?}"))?;
    anyhow::ensure!(
        !plan.is_empty(),
        "no jobs to schedule: pass --arrivals ALGO[.CODEC[.DEVICES]]:WxE@T,..."
    );
    let mut spec = ClusterSpec::with_defaults(nodes, policy, plan);
    if let Some(n) = args.num::<u64>("epoch-iters")? {
        anyhow::ensure!(n >= 1, "--epoch-iters must be >= 1, got {n}");
        spec.iters_per_epoch = n;
    }
    Ok(spec)
}

fn print_run(run: &mxnet_mpi::metrics::RunResult) {
    let mut t = Table::new(&["epoch", "time_s", "train_loss", "val_loss", "val_acc"]);
    for r in &run.records {
        t.row(vec![
            r.epoch.to_string(),
            format!("{:.2}", r.vtime),
            format!("{:.4}", r.train_loss),
            format!("{:.4}", r.val_loss),
            format!("{:.3}", r.val_acc),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{}: final acc {:.3}, avg epoch time {:.2}s",
        run.label,
        run.final_acc(),
        run.avg_epoch_time
    );
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..]);
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let out = PathBuf::from(args.get("out").unwrap_or("results"));

    match cmd.as_str() {
        "train" => {
            let cfg = build_config(&args)?;
            println!(
                "launching threaded job: {} workers={} servers={} clients={} variant={}",
                cfg.algo.name(),
                cfg.workers,
                cfg.servers,
                cfg.clients,
                cfg.variant
            );
            let run = mxnet_mpi::trainer::threaded::train(&cfg, artifacts)?;
            print_run(&run);
        }
        "sim" => {
            let cfg = build_config(&args)?;
            println!(
                "virtual-time run: {} workers={} servers={} clients={} testbed={}",
                cfg.algo.name(),
                cfg.workers,
                cfg.servers,
                cfg.clients,
                cfg.testbed
            );
            let run = mxnet_mpi::trainer::sim::simulate(&cfg, &artifacts)?;
            print_run(&run);
        }
        "figures" => {
            let epochs = args.num::<usize>("epochs")?.unwrap_or(8);
            let runs = mxnet_mpi::figures::fig11(&artifacts, &out, epochs)?;
            mxnet_mpi::figures::print_acc_vs_time("Fig 11", &runs);
            let bars = mxnet_mpi::figures::fig12(&artifacts, &out, epochs.min(4))?;
            for (l, s) in &bars {
                println!("fig12 {l}: {s:.1}s/epoch");
            }
            let runs = mxnet_mpi::figures::fig13(&artifacts, &out, epochs)?;
            mxnet_mpi::figures::print_acc_vs_time("Fig 13", &runs);
            let runs = mxnet_mpi::figures::fig14(&artifacts, &out, epochs * 2)?;
            mxnet_mpi::figures::print_acc_vs_time("Fig 14", &runs);
            let runs = mxnet_mpi::figures::fig16(&artifacts, &out, epochs * 2)?;
            mxnet_mpi::figures::print_acc_vs_time("Fig 16", &runs);
            let runs = mxnet_mpi::figures::fig_churn(&artifacts, &out, epochs)?;
            mxnet_mpi::figures::print_acc_vs_time("Churn (kill+straggle)", &runs);
            let runs = mxnet_mpi::figures::fig_compress(&artifacts, &out, epochs)?;
            mxnet_mpi::figures::print_acc_vs_time("Compression (acc vs time)", &runs);
            for r in mxnet_mpi::figures::fig_twotier(Some(&out))? {
                println!(
                    "fig_twotier {:<10} {:<8} k={}: flat {:.4}s two-tier {:.4}s (inter {} -> {} B)",
                    r.strategy, r.codec, r.devices, r.flat_epoch_s, r.two_tier_epoch_s,
                    r.flat_inter_bytes, r.two_tier_inter_bytes
                );
            }
        }
        "collectives" => {
            for mb in [4usize, 16, 64] {
                let rows = mxnet_mpi::figures::fig17_19(mb << 20, Some(&out))?;
                println!("-- allreduce @ {mb} MB --");
                for r in rows.iter().filter(|r| r.p == 16) {
                    println!("  {:<18} {:>8.2} GB/s", r.design_label, r.gbps);
                }
            }
            for (mb, i, b, f) in mxnet_mpi::figures::fig20(Some(&out))? {
                println!("fig20 @ {mb:>3} MB: IBM {i:.5}s  Baidu {b:.5}s  ({f:.1}x)");
            }
            for (n, w, s, rw, rs) in mxnet_mpi::figures::fig15(Some(&out))? {
                println!(
                    "fig15 nodes={n:>2}: weak {w:.0}s strong {s:.0}s | reg weak {rw:.0}s strong {rs:.0}s"
                );
            }
        }
        "commcheck" => {
            println!("commcheck: verifying registered schedules, engine plans, elastic epochs...");
            let report = mxnet_mpi::analysis::full_report();
            println!("commcheck: {} configurations checked", report.configs_checked);
            for d in &report.diagnostics {
                println!("  FINDING {d}");
            }
            let outcomes = mxnet_mpi::analysis::mutants::run_mutant_suite();
            let mut escaped = 0usize;
            for o in &outcomes {
                let found: Vec<&str> = o.found.iter().map(|k| k.name()).collect();
                if o.caught {
                    println!("  mutant {:<28} caught ({})", o.label, found.join(", "));
                } else {
                    escaped += 1;
                    let expected: Vec<&str> = o.expected.iter().map(|k| k.name()).collect();
                    println!(
                        "  mutant {:<28} ESCAPED: expected one of [{}], found [{}]",
                        o.label,
                        expected.join(", "),
                        found.join(", ")
                    );
                }
            }
            if !report.ok() || escaped > 0 {
                bail!(
                    "commcheck failed: {} finding(s), {} escaped mutant(s)",
                    report.diagnostics.len(),
                    escaped
                );
            }
            println!(
                "commcheck: OK ({} configurations clean, {}/{} seeded mutants caught)",
                report.configs_checked,
                outcomes.len(),
                outcomes.len()
            );
        }
        "racecheck" => {
            use mxnet_mpi::analysis::racecheck;
            let mut budget = racecheck::Budget::default();
            if let Some(n) = args.num::<usize>("max-execs")? {
                anyhow::ensure!(n > 0, "flag --max-execs: must be >= 1");
                budget.dfs = n;
                budget.random = (n / 6).max(1);
            }
            if let Some(seed) = args.get("seed") {
                // Replay mode: re-run exactly one recorded interleaving.
                println!("racecheck: replaying {seed}");
                let (report, taken) = racecheck::replay(seed, budget.step_cap)
                    .map_err(|e| anyhow::anyhow!("racecheck --seed: {e}"))?;
                for d in &report.diagnostics {
                    println!("  FINDING {d}");
                }
                if report.ok() {
                    println!("racecheck: replay ran clean (schedule {taken:?})");
                    return Ok(());
                }
                bail!("racecheck replay reproduced {} finding(s)", report.diagnostics.len());
            }
            let filter = args.get("scenario");
            match filter {
                Some(s) => println!("racecheck: model-checking scenario {s}..."),
                None => println!(
                    "racecheck: model-checking {} concurrency scenarios...",
                    racecheck::scenario_names().len()
                ),
            }
            let report = racecheck::run_racecheck(&budget, filter);
            anyhow::ensure!(
                report.scenarios > 0,
                "racecheck: no scenario matches filter {:?} (known: {})",
                filter.unwrap_or(""),
                racecheck::scenario_names().join(", ")
            );
            println!(
                "racecheck: {} scenario(s), {} world size(s), {} interleavings explored",
                report.scenarios, report.worlds, report.executions
            );
            for d in &report.diagnostics {
                println!("  FINDING {d}");
            }
            if filter.is_some() {
                // Scoped run: report just the filtered sweep, skip mutants.
                if !report.ok() {
                    bail!("racecheck failed: {} finding(s)", report.diagnostics.len());
                }
                println!("racecheck: OK ({} interleavings clean)", report.executions);
                return Ok(());
            }
            let outcomes = racecheck::run_mutant_suite(&budget);
            let mut escaped = 0usize;
            for o in &outcomes {
                let found: Vec<&str> = o.found.iter().map(|k| k.name()).collect();
                if o.caught {
                    println!("  mutant {:<24} caught ({})", o.label, found.join(", "));
                    if let Some(d) = &o.diag {
                        println!("    {d}");
                    }
                } else {
                    escaped += 1;
                    let expected: Vec<&str> = o.expected.iter().map(|k| k.name()).collect();
                    println!(
                        "  mutant {:<24} ESCAPED: expected one of [{}], found [{}]",
                        o.label,
                        expected.join(", "),
                        found.join(", ")
                    );
                }
            }
            if !report.ok() || escaped > 0 {
                bail!(
                    "racecheck failed: {} finding(s), {} escaped mutant(s)",
                    report.diagnostics.len(),
                    escaped
                );
            }
            println!(
                "racecheck: OK ({} interleavings clean, {}/{} seeded mutants caught)",
                report.executions,
                outcomes.len(),
                outcomes.len()
            );
        }
        "cluster" => {
            let spec = build_cluster_spec(&args)?;
            println!(
                "cluster: {} nodes, {} policy, {} job(s)",
                spec.nodes,
                spec.policy.name(),
                spec.plan.jobs.len()
            );
            let run = simulate(&spec)?;
            let mut t = Table::new(&[
                "job", "algo", "codec", "dev", "gang", "arrive_s", "admit_s", "finish_s",
                "widths", "samples",
            ]);
            for j in &run.jobs {
                let widths: Vec<String> = j.widths.iter().map(|w| w.to_string()).collect();
                t.row(vec![
                    j.name.clone(),
                    j.algo.name().to_string(),
                    j.codec.name().to_string(),
                    j.devices.to_string(),
                    j.base_workers.to_string(),
                    format!("{:.0}", j.arrival_s),
                    format!("{:.0}", j.admitted_s),
                    format!("{:.0}", j.finished_s),
                    widths.join(">"),
                    j.samples.to_string(),
                ]);
            }
            println!("{}", t.render());
            println!(
                "pool audit: {} snapshots, conservation [{}, {}] of {} nodes, {} double-bookings",
                run.audit.snapshots,
                run.audit.alloc_free_min,
                run.audit.alloc_free_max,
                run.nodes,
                run.audit.double_booked
            );
            // Both policies on the same plan: the elasticity headline.
            let mut other = spec.clone();
            other.policy = match spec.policy {
                AllocPolicy::Static => AllocPolicy::Elastic,
                AllocPolicy::Elastic => AllocPolicy::Static,
            };
            let alt = simulate(&other)?;
            println!(
                "{}: makespan {:.0}s, goodput {:.1} samples/s | {}: makespan {:.0}s, goodput {:.1} samples/s",
                spec.policy.name(),
                run.makespan_s,
                run.goodput(),
                other.policy.name(),
                alt.makespan_s,
                alt.goodput()
            );
        }
        "info" => {
            let meta = mxnet_mpi::jsonlite::parse_file(&artifacts.join("meta.json"))?;
            let mut t = Table::new(&["variant", "params", "batch", "keys"]);
            if let Some(vs) = meta.req("variants")?.as_obj() {
                for (name, v) in vs {
                    t.row(vec![
                        name.clone(),
                        v.req("params")?.as_usize().unwrap_or(0).to_string(),
                        v.req("x")?
                            .req("shape")?
                            .idx(0)
                            .and_then(|x| x.as_usize())
                            .unwrap_or(0)
                            .to_string(),
                        v.req("segments")?.as_arr().map(|a| a.len()).unwrap_or(0).to_string(),
                    ]);
                }
            }
            println!("artifacts: {}\n{}", artifacts.display(), t.render());
        }
        other => {
            eprintln!("unknown command {other:?}");
            bail!("unknown command");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn negative_numeric_values_parse_as_flag_values() {
        // `-0.5` is a value, not a flag: the parser must hand it to the
        // flag before it, and build_config must land it in the field.
        let args = Args::parse(&argv(&["--block-momentum", "-0.5", "--algo", "bmuf"]));
        assert_eq!(args.get("block-momentum"), Some("-0.5"));
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.block_momentum, -0.5);
    }

    #[test]
    fn unparseable_flag_value_is_a_named_error_not_a_silent_default() {
        // `--workers -3` used to parse-fail silently and run on the
        // default (reading like a missing value); now the flag is named.
        let args = Args::parse(&argv(&["--workers", "-3"]));
        let err = build_config(&args).unwrap_err();
        assert!(format!("{err:#}").contains("--workers"), "{err:#}");
        // A flag left without a value errors the same way.
        let args = Args::parse(&argv(&["--epochs", "--algo", "mpi-SGD"]));
        let err = build_config(&args).unwrap_err();
        assert!(format!("{err:#}").contains("--epochs"), "{err:#}");
    }

    #[test]
    fn compression_flags_validate_against_the_registry() {
        let args = Args::parse(&argv(&["--compression", "topk", "--topk-ratio", "0.25"]));
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.compression, "topk");
        assert_eq!(cfg.topk_ratio, 0.25);
        let err = build_config(&Args::parse(&argv(&["--compression", "zip9"]))).unwrap_err();
        let msg = format!("{err:#}");
        for name in mxnet_mpi::compress::Codec::names() {
            assert!(msg.contains(name), "{msg}");
        }
        let err =
            build_config(&Args::parse(&argv(&["--topk-ratio", "0"]))).unwrap_err();
        assert!(format!("{err:#}").contains("topk-ratio"), "{err:#}");
    }

    #[test]
    fn devices_flag_overrides_and_rejects_zero() {
        let args = Args::parse(&argv(&["--devices", "4", "--collective", "two_tier"]));
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.devices, 4);
        assert_eq!(cfg.collective, "two_tier");
        let err = build_config(&Args::parse(&argv(&["--devices", "0"]))).unwrap_err();
        assert!(format!("{err:#}").contains("devices"), "{err:#}");
        // A negative count fails in num() with the flag named, like --workers -3.
        let err = build_config(&Args::parse(&argv(&["--devices", "-2"]))).unwrap_err();
        assert!(format!("{err:#}").contains("devices"), "{err:#}");
    }

    #[test]
    fn cluster_flags_build_a_spec_and_reject_garbage() {
        let args = Args::parse(&argv(&[
            "--nodes", "6", "--policy", "static",
            "--arrivals", "mpi-SGD:2x4@0,mpi-ESGD.int8:2x4@30",
            "--epoch-iters", "4",
        ]));
        let spec = build_cluster_spec(&args).unwrap();
        assert_eq!(spec.nodes, 6);
        assert_eq!(spec.policy, AllocPolicy::Static);
        assert_eq!(spec.plan.jobs.len(), 2);
        assert_eq!(spec.iters_per_epoch, 4);
        // Unknown policy, malformed plan and an empty plan all die loudly.
        let err = build_cluster_spec(&Args::parse(&argv(&[
            "--policy", "greedy", "--arrivals", "mpi-SGD:2x4@0",
        ])))
        .unwrap_err();
        assert!(format!("{err:#}").contains("--policy"), "{err:#}");
        let err = build_cluster_spec(&Args::parse(&argv(&["--arrivals", "mpi-SGD:2x4"])))
            .unwrap_err();
        assert!(format!("{err:#}").contains("--arrivals"), "{err:#}");
        let err = build_cluster_spec(&Args::parse(&argv(&[]))).unwrap_err();
        assert!(format!("{err:#}").contains("no jobs"), "{err:#}");
    }

    #[test]
    fn fault_and_collective_flags_still_build() {
        let args = Args::parse(&argv(&[
            "--algo", "mpi-ESGD", "--collective", "ring", "--fault", "kill:3@200",
        ]));
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.collective, "ring");
        assert_eq!(cfg.fault, "kill:3@200");
    }
}
