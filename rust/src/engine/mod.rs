//! MXNET-style dataflow dependency engine (paper §3.1).
//!
//! The paper's central implementation trick is that *communication is data*:
//! KVStore push/pull enqueue C++11 lambdas into MXNET's dependency engine
//! with explicit read/mutate tags (Figs 4–5), so MPI collectives interleave
//! with compute exactly as the data-flow graph allows. This module is that
//! engine: operations are closures tagged with the [`Var`]s they read and
//! mutate; the scheduler grants **concurrent readers / exclusive writers per
//! var, in push (program) order** — MXNET's exact rule — and runs ready
//! operations on a small thread pool.

use crate::util::sync::{Builder, Condvar, JoinHandle, Mutex};
use std::collections::VecDeque;
use std::sync::Arc;

/// A dependency tag ("variable") — identifies a piece of state, e.g. one
/// KVStore key's gradient buffer. Cheap to copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Var(usize);

type OpFn = Box<dyn FnOnce() + Send + 'static>;

struct OpState {
    func: Option<OpFn>,
    /// Dependency grants still outstanding.
    pending: usize,
    read: Vec<Var>,
    mutate: Vec<Var>,
}

#[derive(Default)]
struct VarState {
    /// FIFO of (op id, is_write) requests — program order per var.
    queue: VecDeque<(usize, bool)>,
    running_reads: usize,
    running_write: bool,
}

#[derive(Default)]
struct Shared {
    ops: Vec<Option<OpState>>,
    /// Recycled op slots (long trainings push millions of ops).
    free_slots: Vec<usize>,
    vars: Vec<VarState>,
    ready: VecDeque<usize>,
    outstanding: usize,
    shutdown: bool,
}

/// The threaded dependency engine.
pub struct Engine {
    shared: Arc<(Mutex<Shared>, Condvar, Condvar)>, // (state, worker_cv, idle_cv)
    workers: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Create an engine with `threads` worker threads (>= 1).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new((
            Mutex::named(Shared::default(), "engine.state"),
            Condvar::named("engine.worker_cv"),
            Condvar::named("engine.idle_cv"),
        ));
        let workers = (0..threads.max(1))
            .map(|i| {
                let sh = shared.clone();
                Builder::new()
                    .name(format!("engine-worker-{i}"))
                    .spawn(move || Self::worker_loop(&sh))
                    .expect("spawn engine worker thread")
            })
            .collect();
        Self { shared, workers }
    }

    fn worker_loop(sh: &Arc<(Mutex<Shared>, Condvar, Condvar)>) {
        let (lock, worker_cv, idle_cv) = &**sh;
        loop {
            let (op_id, func) = {
                let mut st = lock.lock().expect("engine state lock poisoned in worker");
                loop {
                    if let Some(id) = st.ready.pop_front() {
                        let op = st.ops[id]
                            .as_mut()
                            .unwrap_or_else(|| panic!("engine op {id} vanished from the slot table"));
                        let f = op
                            .func
                            .take()
                            .unwrap_or_else(|| panic!("engine op {id} ready without a function (double grant?)"));
                        break (id, f);
                    }
                    if st.shutdown {
                        return;
                    }
                    st = worker_cv.wait(st).expect("engine state lock poisoned at worker_cv");
                }
            };
            // A panicking op must not wedge the engine: dependencies are
            // released either way, so waiters (wait_all / wait_var /
            // Pending) wake up and see the op produced nothing — the old
            // reply-channel behavior — instead of parking forever on a
            // var that can never quiesce.
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(func)) {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic".into());
                eprintln!("engine op panicked: {msg}");
            }
            // Release dependencies and grant successors.
            let mut st = lock.lock().expect("engine state lock poisoned at op completion");
            let op = st.ops[op_id]
                .take()
                .unwrap_or_else(|| panic!("engine op {op_id} completed twice"));
            st.free_slots.push(op_id);
            let mut to_grant: Vec<Var> = Vec::new();
            for v in &op.read {
                st.vars[v.0].running_reads -= 1;
                to_grant.push(*v);
            }
            for v in &op.mutate {
                st.vars[v.0].running_write = false;
                to_grant.push(*v);
            }
            for v in to_grant {
                Self::try_grant(&mut st, v);
            }
            st.outstanding -= 1;
            if !st.ready.is_empty() {
                worker_cv.notify_all();
            }
            // Wake wait_all *and* wait_var sleepers: the latter care about
            // individual var quiescence, not global idleness.
            idle_cv.notify_all();
        }
    }

    /// Grant queued requests at the head of `v`'s FIFO while legal:
    /// consecutive reads share; a write requires exclusivity.
    fn try_grant(st: &mut Shared, v: Var) {
        loop {
            let vs = &mut st.vars[v.0];
            let Some(&(op_id, is_write)) = vs.queue.front() else { break };
            let can = if is_write {
                !vs.running_write && vs.running_reads == 0
            } else {
                !vs.running_write
            };
            if !can {
                break;
            }
            vs.queue.pop_front();
            if is_write {
                vs.running_write = true;
            } else {
                vs.running_reads += 1;
            }
            let op = st.ops[op_id]
                .as_mut()
                .unwrap_or_else(|| panic!("engine op {op_id} granted a dependency after completion"));
            op.pending -= 1;
            if op.pending == 0 {
                st.ready.push_back(op_id);
            }
        }
    }

    /// Allocate a new dependency variable.
    pub fn new_var(&self) -> Var {
        let (lock, ..) = &*self.shared;
        let mut st = lock.lock().expect("engine state lock poisoned in new_var");
        st.vars.push(VarState::default());
        Var(st.vars.len() - 1)
    }

    /// Enqueue `func` with the given read/mutate dependencies.
    ///
    /// Mirrors `Engine.Push(lambda, read_deps, mutate_deps)` from §3.1. A
    /// var listed in both sets is treated as mutate (MXNET dedups the same
    /// way); duplicates within a set are collapsed.
    pub fn push<F: FnOnce() + Send + 'static>(&self, func: F, read: &[Var], mutate: &[Var]) {
        let mut mut_v: Vec<Var> = mutate.to_vec();
        mut_v.sort();
        mut_v.dedup();
        let mut read_v: Vec<Var> = read
            .iter()
            .copied()
            .filter(|v| !mut_v.contains(v))
            .collect();
        read_v.sort();
        read_v.dedup();

        let (lock, worker_cv, _) = &*self.shared;
        let mut st = lock.lock().expect("engine state lock poisoned in push");
        let pending = read_v.len() + mut_v.len();
        let op = OpState {
            func: Some(Box::new(func)),
            pending,
            read: read_v.clone(),
            mutate: mut_v.clone(),
        };
        let op_id = match st.free_slots.pop() {
            Some(slot) => {
                st.ops[slot] = Some(op);
                slot
            }
            None => {
                st.ops.push(Some(op));
                st.ops.len() - 1
            }
        };
        st.outstanding += 1;
        if pending == 0 {
            st.ready.push_back(op_id);
        } else {
            for v in &read_v {
                st.vars[v.0].queue.push_back((op_id, false));
            }
            for v in &mut_v {
                st.vars[v.0].queue.push_back((op_id, true));
            }
            // Grant in var order; each var's FIFO preserves program order
            // because pushes hold the same lock.
            for v in read_v.iter().chain(mut_v.iter()) {
                Self::try_grant(&mut st, *v);
            }
        }
        worker_cv.notify_all();
    }

    /// Block until every pushed operation has completed (MXNET's
    /// `WaitForAll`).
    pub fn wait_all(&self) {
        let (lock, _, idle_cv) = &*self.shared;
        let mut st = lock.lock().expect("engine state lock poisoned in wait_all");
        while st.outstanding > 0 {
            st = idle_cv.wait(st).expect("engine state lock poisoned at idle_cv");
        }
    }

    /// Block until every operation *already pushed* that reads or mutates
    /// `v` has completed (MXNET's `WaitForVar`). Operations pushed after
    /// this call returns are not waited on. This is what backs
    /// [`crate::kvstore::Pending`]: a result is ready exactly when its
    /// dependency var quiesces, so waiting is a dependency-engine
    /// operation rather than a parked reply channel.
    pub fn wait_var(&self, v: Var) {
        let (lock, _, idle_cv) = &*self.shared;
        let mut st = lock.lock().expect("engine state lock poisoned in wait_var");
        loop {
            let vs = &st.vars[v.0];
            if vs.queue.is_empty() && !vs.running_write && vs.running_reads == 0 {
                return;
            }
            st = idle_cv.wait(st).expect("engine state lock poisoned at idle_cv");
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.wait_all();
        {
            let (lock, worker_cv, _) = &*self.shared;
            let mut st = lock.lock().expect("engine state lock poisoned at shutdown");
            st.shutdown = true;
            worker_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;

    #[test]
    fn runs_zero_dep_op() {
        let e = Engine::new(2);
        let hit = Arc::new(AtomicUsize::new(0));
        let h = hit.clone();
        e.push(move || { h.fetch_add(1, Ordering::SeqCst); }, &[], &[]);
        e.wait_all();
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn writes_to_same_var_serialize_in_push_order() {
        let e = Engine::new(4);
        let v = e.new_var();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..50 {
            let log = log.clone();
            e.push(move || log.lock().unwrap().push(i), &[], &[v]);
        }
        e.wait_all();
        assert_eq!(*log.lock().unwrap(), (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_readers_overlap() {
        // Two readers of the same var hand a token to each other; if the
        // engine serialized reads this would deadlock.
        let e = Engine::new(2);
        let v = e.new_var();
        let (tx1, rx1) = mpsc::channel::<()>();
        let (tx2, rx2) = mpsc::channel::<()>();
        e.push(
            move || {
                tx1.send(()).unwrap();
                rx2.recv().unwrap();
            },
            &[v],
            &[],
        );
        e.push(
            move || {
                rx1.recv().unwrap();
                tx2.send(()).unwrap();
            },
            &[v],
            &[],
        );
        e.wait_all();
    }

    #[test]
    fn writer_waits_for_readers_and_blocks_later_readers() {
        let e = Engine::new(4);
        let v = e.new_var();
        let log = Arc::new(Mutex::new(Vec::new()));
        for (i, kind) in [(0, "r"), (1, "r"), (2, "w"), (3, "r")] {
            let log = log.clone();
            let f = move || log.lock().unwrap().push((i, kind));
            match kind {
                "r" => e.push(f, &[v], &[]),
                _ => e.push(f, &[], &[v]),
            }
        }
        e.wait_all();
        let got = log.lock().unwrap().clone();
        let pos = |i| got.iter().position(|&(j, _)| j == i).unwrap();
        // Write (2) after both leading reads, read (3) after the write.
        assert!(pos(2) > pos(0) && pos(2) > pos(1));
        assert!(pos(3) > pos(2));
    }

    #[test]
    fn read_and_mutate_same_var_treated_as_mutate() {
        let e = Engine::new(2);
        let v = e.new_var();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..10 {
            let log = log.clone();
            // read + mutate the same var; must still serialize in order.
            e.push(move || log.lock().unwrap().push(i), &[v], &[v]);
        }
        e.wait_all();
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn independent_vars_do_not_interfere() {
        let e = Engine::new(4);
        let a = e.new_var();
        let b = e.new_var();
        let count = Arc::new(AtomicUsize::new(0));
        for i in 0..40 {
            let c = count.clone();
            let var = if i % 2 == 0 { a } else { b };
            e.push(move || { c.fetch_add(1, Ordering::SeqCst); }, &[], &[var]);
        }
        e.wait_all();
        assert_eq!(count.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn chain_read_after_write_sees_value() {
        let e = Engine::new(2);
        let v = e.new_var();
        let cell = Arc::new(Mutex::new(0u64));
        let out = Arc::new(Mutex::new(0u64));
        {
            let cell = cell.clone();
            e.push(move || *cell.lock().unwrap() = 42, &[], &[v]);
        }
        {
            let cell = cell.clone();
            let out = out.clone();
            e.push(move || *out.lock().unwrap() = *cell.lock().unwrap(), &[v], &[]);
        }
        e.wait_all();
        assert_eq!(*out.lock().unwrap(), 42);
    }

    #[test]
    fn wait_var_waits_for_its_ops_only() {
        // A slow op on `a` must be waited; an unrelated slow op on `b`
        // must not block wait_var(a).
        let e = Engine::new(2);
        let a = e.new_var();
        let b = e.new_var();
        let hit = Arc::new(AtomicUsize::new(0));
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        {
            let h = hit.clone();
            e.push(
                move || {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    h.fetch_add(1, Ordering::SeqCst);
                },
                &[],
                &[a],
            );
        }
        e.push(
            move || {
                gate_rx.recv().unwrap(); // blocks until after wait_var(a)
            },
            &[],
            &[b],
        );
        e.wait_var(a);
        assert_eq!(hit.load(Ordering::SeqCst), 1, "wait_var returned early");
        gate_tx.send(()).unwrap();
        e.wait_all();
    }

    #[test]
    fn wait_var_sees_queued_chain() {
        // Many queued writes to one var: wait_var returns only after the
        // whole chain drains.
        let e = Engine::new(3);
        let v = e.new_var();
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = count.clone();
            e.push(move || { c.fetch_add(1, Ordering::SeqCst); }, &[], &[v]);
        }
        e.wait_var(v);
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn wait_all_with_many_ops_and_vars() {
        let e = Engine::new(3);
        let vars: Vec<Var> = (0..8).map(|_| e.new_var()).collect();
        let count = Arc::new(AtomicUsize::new(0));
        for i in 0..500 {
            let c = count.clone();
            let r = vars[i % 8];
            let m = vars[(i * 3 + 1) % 8];
            e.push(move || { c.fetch_add(1, Ordering::SeqCst); }, &[r], &[m]);
        }
        e.wait_all();
        assert_eq!(count.load(Ordering::SeqCst), 500);
    }
}
