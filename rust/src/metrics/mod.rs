//! Metrics: per-epoch training records, CSV emission and the small table
//! formatter used by the figure benches and EXPERIMENTS.md.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// One validation point (paper metrics §7: epoch time + validation acc).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Virtual seconds since training start (netsim clock) — the x-axis of
    /// Figs 11/13/14.
    pub vtime: f64,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_acc: f64,
}

/// A full run: config label + per-epoch records.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    pub label: String,
    pub records: Vec<EpochRecord>,
    /// Mean virtual seconds per epoch (Fig. 12 bar).
    pub avg_epoch_time: f64,
}

impl RunResult {
    /// `avg_epoch_time` is the true mean epoch duration. A cold start
    /// (first record is epoch 0) began at vtime 0, so `last.vtime / len`
    /// is exact — including epoch 0's own duration. A warm-started /
    /// churn-restored run (first record deep into both the epoch count
    /// and the clock) has no epoch-0 anchor; the old unconditional
    /// `last.vtime / len` inflated its mean by the whole warm-up offset,
    /// so it averages the successive end-of-epoch deltas instead (a
    /// single warm record has no delta and falls back to its vtime).
    pub fn finish(label: &str, records: Vec<EpochRecord>) -> Self {
        let avg = match records.len() {
            0 => 0.0,
            n if records[0].epoch == 0 => records[n - 1].vtime / n as f64,
            1 => records[0].vtime,
            n => (records[n - 1].vtime - records[0].vtime) / (n - 1) as f64,
        };
        Self { label: label.to_string(), records, avg_epoch_time: avg }
    }

    pub fn final_acc(&self) -> f64 {
        self.records.last().map(|r| r.val_acc).unwrap_or(0.0)
    }

    /// Virtual time to first reach accuracy `target` (Figs 11/13 compare
    /// "rate of convergence" = acc-vs-time).
    pub fn time_to_acc(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.val_acc >= target)
            .map(|r| r.vtime)
    }
}

/// Write one or more runs as a tidy CSV: label,epoch,vtime,...
pub fn write_runs_csv(path: &Path, runs: &[RunResult]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "label,epoch,vtime_s,train_loss,val_loss,val_acc")?;
    for run in runs {
        for r in &run.records {
            writeln!(
                f,
                "{},{},{:.4},{:.5},{:.5},{:.4}",
                run.label, r.epoch, r.vtime, r.train_loss, r.val_loss, r.val_acc
            )?;
        }
    }
    Ok(())
}

/// Generic CSV writer for sweep-style results.
pub struct Csv {
    out: std::fs::File,
}

impl Csv {
    pub fn create(path: &Path, header: &str) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = std::fs::File::create(path)?;
        writeln!(out, "{header}")?;
        Ok(Self { out })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }
}

/// Fixed-width console table (the benches print paper-style rows).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.header.len());
        self.rows.push(fields);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, vtime: f64, acc: f64) -> EpochRecord {
        EpochRecord { epoch, vtime, train_loss: 1.0, val_loss: 1.0, val_acc: acc }
    }

    #[test]
    fn run_result_summary() {
        let r = RunResult::finish("x", vec![rec(0, 10.0, 0.3), rec(1, 20.0, 0.6)]);
        assert_eq!(r.avg_epoch_time, 10.0);
        assert_eq!(r.final_acc(), 0.6);
        assert_eq!(r.time_to_acc(0.5), Some(20.0));
        assert_eq!(r.time_to_acc(0.9), None);
    }

    #[test]
    fn avg_epoch_time_ignores_warm_start_offset() {
        // A restored run whose first record lands at vtime 110 must report
        // the per-epoch cadence (10 s), not (130 / 3) ≈ 43 s.
        let r = RunResult::finish(
            "warm",
            vec![rec(11, 110.0, 0.5), rec(12, 120.0, 0.6), rec(13, 130.0, 0.7)],
        );
        assert_eq!(r.avg_epoch_time, 10.0);
        // A cold start keeps the exact last/len mean — epoch 0's own
        // duration counts even when epochs are non-uniform.
        let c = RunResult::finish(
            "cold",
            vec![rec(0, 15.0, 0.4), rec(1, 20.0, 0.5), rec(2, 30.0, 0.6)],
        );
        assert_eq!(c.avg_epoch_time, 10.0);
        // Degenerate cases stay sane.
        assert_eq!(RunResult::finish("none", vec![]).avg_epoch_time, 0.0);
        assert_eq!(RunResult::finish("one", vec![rec(0, 7.0, 0.1)]).avg_epoch_time, 7.0);
        assert_eq!(RunResult::finish("warm1", vec![rec(9, 7.0, 0.1)]).avg_epoch_time, 7.0);
    }

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("mxnetmpi_test_csv");
        let path = dir.join("runs.csv");
        let runs = vec![RunResult::finish("a", vec![rec(0, 1.0, 0.5)])];
        write_runs_csv(&path, &runs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("label,epoch"));
        assert!(text.contains("a,0,1.0000"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["mode", "time"]);
        t.row(vec!["mpi-SGD".into(), "1.5".into()]);
        t.row(vec!["dist-SGD".into(), "9.0".into()]);
        let s = t.render();
        assert!(s.contains("mpi-SGD"));
        assert!(s.lines().count() == 4);
    }
}
