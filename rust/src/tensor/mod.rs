//! Flat f32 buffers, KVStore segments and the paper's *node tensor*.
//!
//! MXNET expresses parameters/gradients as per-layer `ndarray`s keyed in the
//! KVStore (§3.2). We keep the model's parameters as one flat `f32` vector
//! (the AOT artifacts' calling convention) plus a [`SegmentTable`] mapping
//! each KVStore key to its slice — so the Rust side sees per-layer keys
//! exactly like MXNET while the compiled HLO sees one vector.
//!
//! [`NodeTensor`] is the paper's §6.1 "tensor": the *group of per-GPU
//! vectors on one node*, treated as a single object by the tensor
//! collectives.



/// A named slice of the flat parameter vector — one KVStore key.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

/// Per-layer key -> slice mapping, loaded from `artifacts/meta.json`.
#[derive(Debug, Clone, Default)]
pub struct SegmentTable {
    pub segments: Vec<Segment>,
}

impl SegmentTable {
    pub fn new(segments: Vec<Segment>) -> Self {
        Self { segments }
    }

    /// Total flat length covered by the table.
    pub fn total_size(&self) -> usize {
        self.segments.last().map(|s| s.offset + s.size).unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        self.segments.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    pub fn by_name(&self, name: &str) -> Option<&Segment> {
        self.segments.iter().find(|s| s.name == name)
    }

    /// Slice a flat vector by key index.
    pub fn slice<'a>(&self, flat: &'a [f32], key: usize) -> &'a [f32] {
        let s = &self.segments[key];
        &flat[s.offset..s.offset + s.size]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], key: usize) -> &'a mut [f32] {
        let s = &self.segments[key];
        &mut flat[s.offset..s.offset + s.size]
    }

    /// Validate invariants: contiguous, non-overlapping, sizes match shapes.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut off = 0;
        for s in &self.segments {
            anyhow::ensure!(s.offset == off, "segment {} not contiguous", s.name);
            let prod: usize = s.shape.iter().product();
            anyhow::ensure!(prod == s.size, "segment {} size/shape mismatch", s.name);
            off += s.size;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Elementwise f32 math on flat buffers (the host-memory reduction path).
// ---------------------------------------------------------------------------

/// dst += src (the ring-step reduction on host memory).
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// dst = a * x + dst.
pub fn axpy(dst: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(dst.len(), x.len());
    for (d, s) in dst.iter_mut().zip(x) {
        *d += a * s;
    }
}

/// dst *= a.
pub fn scale(dst: &mut [f32], a: f32) {
    for d in dst.iter_mut() {
        *d *= a;
    }
}

/// Euclidean norm.
pub fn l2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Max absolute difference between two buffers.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

// ---------------------------------------------------------------------------
// NodeTensor — the paper's §6.1 group-of-vectors object.
// ---------------------------------------------------------------------------

/// The group of per-device vectors on one node, treated as a single object.
///
/// In the paper each Minsky socket contributes 2 GPUs; the tensor collective
/// reduces/broadcasts *all* vectors of a node as one unit, using the
/// intra-node links (NVLink there, the AOT `tensor_reduce` kernel here).
#[derive(Debug, Clone)]
pub struct NodeTensor {
    pub vecs: Vec<Vec<f32>>,
}

impl NodeTensor {
    pub fn new(devices: usize, len: usize) -> Self {
        Self {
            vecs: vec![vec![0.0; len]; devices],
        }
    }

    pub fn from_vecs(vecs: Vec<Vec<f32>>) -> Self {
        assert!(!vecs.is_empty());
        let len = vecs[0].len();
        assert!(vecs.iter().all(|v| v.len() == len), "ragged node tensor");
        Self { vecs }
    }

    pub fn devices(&self) -> usize {
        self.vecs.len()
    }

    pub fn len(&self) -> usize {
        self.vecs.first().map(|v| v.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Intra-node reduction: sum all device vectors into a host buffer.
    /// (The IBMGpu/NCCL kernel of §7.3; here plain f32 math — the compiled
    /// `tensor_reduce` HLO kernel is used on the training path instead.)
    pub fn reduce_to_host(&self) -> Vec<f32> {
        let mut out = self.vecs[0].clone();
        for v in &self.vecs[1..] {
            add_assign(&mut out, v);
        }
        out
    }

    /// Intra-node broadcast: copy a host buffer to every device vector.
    pub fn broadcast_from_host(&mut self, host: &[f32]) {
        for v in self.vecs.iter_mut() {
            v.copy_from_slice(host);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SegmentTable {
        SegmentTable::new(vec![
            Segment { name: "a".into(), offset: 0, size: 6, shape: vec![2, 3] },
            Segment { name: "b".into(), offset: 6, size: 4, shape: vec![4] },
        ])
    }

    #[test]
    fn segment_table_total_and_lookup() {
        let t = table();
        assert_eq!(t.total_size(), 10);
        assert_eq!(t.by_name("b").unwrap().offset, 6);
        assert!(t.by_name("zz").is_none());
        t.validate().unwrap();
    }

    #[test]
    fn segment_slicing() {
        let t = table();
        let mut flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(t.slice(&flat, 1), &[6.0, 7.0, 8.0, 9.0]);
        t.slice_mut(&mut flat, 0)[0] = 99.0;
        assert_eq!(flat[0], 99.0);
    }

    #[test]
    fn validate_rejects_gap() {
        let t = SegmentTable::new(vec![Segment {
            name: "a".into(),
            offset: 4,
            size: 2,
            shape: vec![2],
        }]);
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_shape_mismatch() {
        let t = SegmentTable::new(vec![Segment {
            name: "a".into(),
            offset: 0,
            size: 5,
            shape: vec![2, 3],
        }]);
        assert!(t.validate().is_err());
    }

    #[test]
    fn elementwise_math() {
        let mut d = vec![1.0, 2.0];
        add_assign(&mut d, &[3.0, 4.0]);
        assert_eq!(d, vec![4.0, 6.0]);
        axpy(&mut d, 0.5, &[2.0, 2.0]);
        assert_eq!(d, vec![5.0, 7.0]);
        scale(&mut d, 2.0);
        assert_eq!(d, vec![10.0, 14.0]);
        assert!((l2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[2.0, 3.0]), 2.0);
    }

    #[test]
    fn node_tensor_reduce_and_broadcast() {
        let mut t = NodeTensor::from_vecs(vec![vec![1.0, 2.0], vec![10.0, 20.0]]);
        assert_eq!(t.reduce_to_host(), vec![11.0, 22.0]);
        t.broadcast_from_host(&[7.0, 8.0]);
        assert_eq!(t.vecs[0], vec![7.0, 8.0]);
        assert_eq!(t.vecs[1], vec![7.0, 8.0]);
        assert_eq!(t.devices(), 2);
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn node_tensor_rejects_ragged() {
        NodeTensor::from_vecs(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
