//! # mxnet-mpi-rs
//!
//! Reproduction of *MXNET-MPI: Embedding MPI parallelism in Parameter
//! Server Task Model for scaling Deep Learning* (Mamidala et al., 2018).
//!
//! The crate implements the paper's hybrid **Parameter Server + MPI**
//! training framework as a three-layer stack:
//!
//! * **L3 (this crate)** — PS tasks (scheduler / servers / workers), a
//!   simulated MPI library ([`mpisim`]), the hybrid [`kvstore`] API with
//!   communication embedded in a dataflow [`engine`], the paper's
//!   pluggable tensor [`collectives`] (ring / halving-doubling /
//!   hierarchical + α-β-γ autotuner and gradient fusion), a pluggable
//!   gradient-compression plane ([`compress`]: identity / int8 / top-k
//!   with error feedback, priced end to end), a network
//!   simulator ([`netsim`]) and the distributed SGD [`trainer`]s, whose
//!   algorithms are pluggable [`trainer::strategies`] objects behind a
//!   string-keyed registry (the paper's dist/mpi × SGD/ASGD/ESGD modes
//!   plus the communication-avoiding `bmuf` and `local-sgd`).
//! * **L2/L1 (python, build-time only)** — JAX model fwd/bwd + Pallas
//!   kernels. The AOT artifacts (`meta.json`, `init.bin`) feed
//!   [`runtime`], whose native CPU kernels mirror the JAX models exactly
//!   (the offline image has no PJRT; see `runtime/native.rs`).
//!
//! See `DESIGN.md` for the system inventory and experiment index.

// Curated allow-list for `cargo clippy --all-targets -- -D warnings` (CI
// lint gate). The collective/compression entry points deliberately thread
// (comm, data, codec, ef-state, rings, group, cost) through one call —
// the paper's API shape — so the arity lint is waived crate-wide rather
// than per-site.
#![allow(clippy::too_many_arguments)]

pub mod analysis;
pub mod cluster;
pub mod collectives;
pub mod compress;
pub mod config;
pub mod jsonlite;
pub mod data;
pub mod engine;
pub mod figures;
pub mod kvstore;
pub mod launcher;
pub mod metrics;
pub mod mpisim;
pub mod netsim;
pub mod optimizer;
pub mod ps;
pub mod runtime;
pub mod tensor;
pub mod trainer;
pub mod util;
