//! Experiment configuration: registry-backed algorithm selection, testbed
//! presets and JSON round-trip (hand-rolled: no serde offline).
//!
//! The old closed `Algo` enum is gone: [`Algo`] is now a handle into the
//! string-keyed algorithm registry
//! ([`trainer::strategies`](crate::trainer::strategies)), so the config
//! layer — like the CLI, figures and bench — can never know a different
//! set of algorithms than the trainers run.

use crate::collectives::AlgoKind;
use crate::compress::{Codec, Compressor};
use crate::jsonlite::Value;
use crate::netsim::CostParams;
use crate::ps::FaultPlan;
use anyhow::{Context, Result};
use std::path::Path;

pub use crate::trainer::strategies::{Algo, Grouping};

/// Everything one experiment run needs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model variant in `artifacts/meta.json`.
    pub variant: String,
    pub algo: Algo,
    /// Total DL workers (12 on testbed1).
    pub workers: usize,
    /// PS servers (2 on testbed1; 0 = pure MPI).
    pub servers: usize,
    /// MPI clients; workers are split evenly across them. `clients ==
    /// workers` degrades MPI modes to dist modes — the paper's knob.
    pub clients: usize,
    pub epochs: usize,
    /// Samples per epoch (the synthetic "ImageNet" scale).
    pub samples_per_epoch: u64,
    /// Per-worker scheduling batch (128 in the paper; here the model's
    /// compiled batch).
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Elastic averaging coefficient.
    pub alpha: f32,
    /// Lazy-sync interval (64 in §5): ESGD's elastic sync cadence, and the
    /// model-averaging block length for `local-sgd` / `bmuf`.
    pub interval: usize,
    /// BMUF block momentum η: the filter coefficient on the block-averaged
    /// model delta (`Δ = η Δ + (w̄ - G)`; Chen & Huo, ICASSP 2016).
    pub block_momentum: f32,
    /// Post-local warmup for `local-sgd` (arXiv:1808.07217): the first
    /// `warmup_iters` iterations average the model *every* iteration
    /// before the lazy `interval` schedule takes over. 0 disables.
    pub warmup_iters: usize,
    /// Multi-ring count for tensor collectives.
    pub rings: usize,
    /// Devices per worker node (MXNet `local` kvstore tier, ≥ 1): each
    /// worker splits its batch into `devices` shards of `batch/devices`
    /// rows, computes one gradient per device, and merges them locally
    /// before any inter-node traffic. With the `two_tier` collective the
    /// device tier reduces on the fast intra-node fabric and only node
    /// leaders touch the NIC (inter-node wire bytes ÷ `devices`); flat
    /// schedules instead pay `devices`-way NIC contention. 1 = the
    /// pre-device-tier flat world, bitwise unchanged.
    pub devices: usize,
    /// Allreduce schedule: "ring", "halving_doubling", "hierarchical",
    /// "two_tier" (intra-node device reduce before the inter-node hop) or
    /// "auto" (α-β-γ autotuner, the default — §6 collective layer).
    pub collective: String,
    /// Gradient-fusion bucket cap in bytes (0 disables): consecutive
    /// small keys coalesce into one allreduce message up to this size.
    pub fusion_bytes: usize,
    /// Compute/communication overlap (the DAG-embedded collective path,
    /// arXiv:1802.06949): per-bucket collectives issue as gradients become
    /// ready, so backward compute hides communication. Affects the virtual
    /// time axis of the sim plane; the threaded plane always issues
    /// nonblocking per-bucket ops (results are identical either way).
    pub overlap: bool,
    /// Sub-chunks per pipelined collective step; 0 = the testbed preset's
    /// value ([`CostParams::pipeline_chunks`]), 1 = blocking schedules.
    pub pipeline_chunks: usize,
    /// Compute-plane threads for the native kernels: 0 = auto (all
    /// available parallelism), 1 = the scalar path. Kernel reduction
    /// orders are fixed per problem size, so results are bitwise
    /// identical at any setting — a pure performance knob.
    pub threads: usize,
    /// Gradient codec (the compression plane): "identity" (default, the
    /// bitwise pre-compression paths), "int8" (per-bucket linear
    /// quantization + error feedback) or "topk" (top-k sparsification +
    /// error feedback). Registry-validated like `algo`.
    pub compression: String,
    /// Fraction of elements the `topk` codec keeps per buffer, in (0, 1].
    pub topk_ratio: f64,
    pub seed: u64,
    /// Cost-model preset: "testbed1" or "minsky".
    pub testbed: String,
    /// Virtual compute seconds per batch (the modeled GPU fwd+bwd; the
    /// *numerics* run for real, this sets the virtual time axis).
    pub compute_s_per_batch: f64,
    /// Relative per-worker compute jitter (stragglers; drives staleness).
    pub jitter: f64,
    /// Gaussian-mixture noise level and class count.
    pub noise: f32,
    pub classes: usize,
    /// Held-out samples for validation accuracy.
    pub eval_samples: u64,
    /// Bytes of the *virtual* model moved per push/pull/allreduce on the
    /// netsim clock. The convergence numerics use the compiled small
    /// model; the time axis uses paper-scale traffic (ResNet-50 ≈ 102 MB
    /// of f32 parameters) so the compute:communication ratio matches §7.
    pub virtual_model_bytes: usize,
    /// Scripted churn (the `--fault` grammar: `kill:R@N`,
    /// `straggle:R@NxF`, `join@N`, `join:C@N`, comma-separated; empty =
    /// static job). MPI modes only — elasticity is the hybrid's story.
    pub fault: String,
    /// Shared node-pool size for the cluster authority (one worker rank
    /// per node).
    pub cluster_nodes: usize,
    /// Cluster allocation policy: "static" (jobs hold exactly their gang)
    /// or "elastic" (grow into idle nodes, shrink under contention).
    pub cluster_policy: String,
    /// Scripted job arrivals (the `--arrivals` grammar:
    /// `ALGO[.CODEC[.DEVICES]]:WxE@T`, comma-separated; empty = no
    /// cluster workload). The cluster-level analogue of `fault`.
    pub arrivals: String,
}

impl ExperimentConfig {
    /// testbed1 defaults (§7.1): 12 workers, 2 servers, 2 MPI clients,
    /// batch 128-analog, ResNet-analog "mlp" variant.
    pub fn testbed1(algo: Algo) -> Self {
        let clients = if algo.is_mpi() { 2 } else { 12 };
        Self {
            variant: "mlp".into(),
            algo,
            workers: 12,
            servers: 2,
            clients,
            epochs: 10,
            samples_per_epoch: 12 * 16 * 64, // 16 batches/worker/epoch
            batch: 64,
            lr: 0.1,
            // §5's pseudo-code ships *plain* SGD everywhere; momentum stays
            // available as a knob but defaults off so the modes differ
            // only in their distribution strategy.
            momentum: 0.0,
            weight_decay: 1e-4,
            alpha: 0.2,
            interval: 8,
            block_momentum: 0.5,
            warmup_iters: 0,
            rings: 2,
            devices: 1,
            collective: "auto".into(),
            fusion_bytes: 4 << 20,
            overlap: true,
            pipeline_chunks: 0,
            threads: 0,
            compression: "identity".into(),
            topk_ratio: 0.01,
            seed: 42,
            testbed: "testbed1".into(),
            // ResNet-50 on K80-class GPUs: ~0.35 s per 128-batch; we keep
            // the same compute:comm ratio for the 460k-param analog.
            compute_s_per_batch: 0.35,
            jitter: 0.15,
            noise: 8.0,
            classes: 16,
            eval_samples: 512,
            virtual_model_bytes: 102 << 20, // ResNet-50 f32 params
            fault: String::new(),
            cluster_nodes: 8,
            cluster_policy: "elastic".into(),
            arrivals: String::new(),
        }
    }

    /// Parsed churn schedule (`Ok(FaultPlan::none())` when `fault` is
    /// empty).
    pub fn fault_plan(&self) -> Result<FaultPlan> {
        FaultPlan::parse(&self.fault)
    }

    /// Parsed cluster allocation policy; unknown strings fall back to
    /// elastic (the JSON/CLI boundaries reject unknown names outright).
    pub fn alloc_policy(&self) -> crate::cluster::AllocPolicy {
        crate::cluster::AllocPolicy::parse(&self.cluster_policy)
            .unwrap_or(crate::cluster::AllocPolicy::Elastic)
    }

    /// Parsed job-arrival schedule (`Ok` of an empty plan when `arrivals`
    /// is empty).
    pub fn arrival_plan(&self) -> Result<crate::cluster::ArrivalPlan> {
        crate::cluster::ArrivalPlan::parse(&self.arrivals)
    }

    pub fn workers_per_client(&self) -> usize {
        (self.workers / self.clients.max(1)).max(1)
    }

    /// The algorithm mini-batch (§5): declared by the strategy.
    pub fn mini_batch(&self) -> usize {
        self.algo.strategy().mini_batch(self)
    }

    pub fn cost_params(&self) -> CostParams {
        let mut p = match self.testbed.as_str() {
            "minsky" | "testbed2" => CostParams::minsky(),
            _ => CostParams::testbed1(),
        };
        if self.pipeline_chunks > 0 {
            p.pipeline_chunks = self.pipeline_chunks;
        }
        p.devices = self.devices.max(1);
        p
    }

    /// Parsed `collective` knob; unknown strings fall back to the
    /// autotuner (every schedule is sum-equivalent, so this is safe).
    pub fn collective_kind(&self) -> AlgoKind {
        AlgoKind::parse(&self.collective).unwrap_or(AlgoKind::Auto)
    }

    /// Parsed `compression` knob; unknown strings fall back to identity
    /// (lossless, so this is safe — the JSON/CLI boundaries reject unknown
    /// names outright with the registry listed).
    pub fn codec(&self) -> Codec {
        Codec::parse(&self.compression).unwrap_or_else(Codec::identity)
    }

    /// Instantiate the configured codec (`topk_ratio` applied).
    pub fn build_compressor(&self) -> Box<dyn Compressor> {
        self.codec().build(self.topk_ratio)
    }

    /// Serialize to JSON (results provenance).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("variant", Value::str(&self.variant)),
            ("algo", Value::str(self.algo.name())),
            ("workers", Value::num(self.workers as f64)),
            ("servers", Value::num(self.servers as f64)),
            ("clients", Value::num(self.clients as f64)),
            ("epochs", Value::num(self.epochs as f64)),
            ("samples_per_epoch", Value::num(self.samples_per_epoch as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("lr", Value::num(self.lr as f64)),
            ("momentum", Value::num(self.momentum as f64)),
            ("weight_decay", Value::num(self.weight_decay as f64)),
            ("alpha", Value::num(self.alpha as f64)),
            ("interval", Value::num(self.interval as f64)),
            ("block_momentum", Value::num(self.block_momentum as f64)),
            ("warmup_iters", Value::num(self.warmup_iters as f64)),
            ("rings", Value::num(self.rings as f64)),
            ("devices", Value::num(self.devices as f64)),
            ("collective", Value::str(&self.collective)),
            ("fusion_bytes", Value::num(self.fusion_bytes as f64)),
            ("overlap", Value::Bool(self.overlap)),
            ("pipeline_chunks", Value::num(self.pipeline_chunks as f64)),
            ("threads", Value::num(self.threads as f64)),
            ("compression", Value::str(&self.compression)),
            ("topk_ratio", Value::num(self.topk_ratio)),
            ("seed", Value::num(self.seed as f64)),
            ("testbed", Value::str(&self.testbed)),
            ("compute_s_per_batch", Value::num(self.compute_s_per_batch)),
            ("jitter", Value::num(self.jitter)),
            ("noise", Value::num(self.noise as f64)),
            ("classes", Value::num(self.classes as f64)),
            ("eval_samples", Value::num(self.eval_samples as f64)),
            ("virtual_model_bytes", Value::num(self.virtual_model_bytes as f64)),
            ("fault", Value::str(&self.fault)),
            ("cluster_nodes", Value::num(self.cluster_nodes as f64)),
            ("cluster_policy", Value::str(&self.cluster_policy)),
            ("arrivals", Value::str(&self.arrivals)),
        ])
    }

    /// Load from a JSON file; missing fields fall back to testbed1
    /// defaults for the given algo.
    ///
    /// Count-like fields (`workers`, `servers`, iteration counts, byte
    /// caps, …) must be non-negative finite numbers: a negative value
    /// would otherwise truncate silently through the `usize` cast (e.g.
    /// `servers=-1` reading as a "valid" count), so it errors with the
    /// offending field named instead.
    pub fn from_json(v: &Value) -> Result<Self> {
        let algo_name = v.req("algo")?.as_str().context("algo")?;
        let algo = Algo::parse(algo_name).with_context(|| {
            format!(
                "unknown algo {algo_name:?} (registered: {})",
                Algo::names().join(", ")
            )
        })?;
        let mut c = Self::testbed1(algo);
        // Free-form numerics (may legitimately be any float).
        let getn = |k: &str, d: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(d);
        // Counts and sizes: reject negatives/NaN before the lossy cast.
        let getu = |k: &str, d: f64| -> Result<f64> {
            match v.get(k).and_then(|x| x.as_f64()) {
                None => Ok(d),
                Some(x) if x.is_finite() && x >= 0.0 => Ok(x),
                Some(x) => anyhow::bail!(
                    "config field {k:?} must be a non-negative number, got {x}"
                ),
            }
        };
        let gets = |k: &str, d: &str| {
            v.get(k)
                .and_then(|x| x.as_str())
                .unwrap_or(d)
                .to_string()
        };
        c.variant = gets("variant", &c.variant);
        c.workers = getu("workers", c.workers as f64)? as usize;
        c.servers = getu("servers", c.servers as f64)? as usize;
        c.clients = getu("clients", c.clients as f64)? as usize;
        c.epochs = getu("epochs", c.epochs as f64)? as usize;
        c.samples_per_epoch = getu("samples_per_epoch", c.samples_per_epoch as f64)? as u64;
        c.batch = getu("batch", c.batch as f64)? as usize;
        c.lr = getn("lr", c.lr as f64) as f32;
        c.momentum = getn("momentum", c.momentum as f64) as f32;
        c.weight_decay = getn("weight_decay", c.weight_decay as f64) as f32;
        c.alpha = getn("alpha", c.alpha as f64) as f32;
        c.interval = getu("interval", c.interval as f64)? as usize;
        c.block_momentum = getn("block_momentum", c.block_momentum as f64) as f32;
        c.warmup_iters = getu("warmup_iters", c.warmup_iters as f64)? as usize;
        c.rings = getu("rings", c.rings as f64)? as usize;
        // `devices` is a divisor of the per-worker batch and a tier
        // width: zero is as silently catastrophic as the `servers=-1`
        // truncation was, so both non-positive cases fail loudly with
        // the field named (negatives already die inside `getu`).
        c.devices = getu("devices", c.devices as f64)? as usize;
        anyhow::ensure!(
            c.devices >= 1,
            "config field \"devices\" must be >= 1 (a worker has at least \
             one device), got {}",
            c.devices
        );
        c.collective = gets("collective", &c.collective);
        anyhow::ensure!(
            AlgoKind::parse(&c.collective).is_some(),
            "unknown collective {:?} (valid: ring, halving_doubling, hierarchical, two_tier, auto)",
            c.collective
        );
        c.fusion_bytes = getu("fusion_bytes", c.fusion_bytes as f64)? as usize;
        c.overlap = v.get("overlap").and_then(|x| x.as_bool()).unwrap_or(c.overlap);
        c.pipeline_chunks = getu("pipeline_chunks", c.pipeline_chunks as f64)? as usize;
        c.threads = getu("threads", c.threads as f64)? as usize;
        c.compression = gets("compression", &c.compression);
        anyhow::ensure!(
            Codec::parse(&c.compression).is_some(),
            "unknown compression {:?} (registered: {})",
            c.compression,
            Codec::names().join(", ")
        );
        c.topk_ratio = getn("topk_ratio", c.topk_ratio);
        anyhow::ensure!(
            c.topk_ratio.is_finite() && c.topk_ratio > 0.0 && c.topk_ratio <= 1.0,
            "config field \"topk_ratio\" must be in (0, 1], got {}",
            c.topk_ratio
        );
        c.seed = getu("seed", c.seed as f64)? as u64;
        c.testbed = gets("testbed", &c.testbed);
        c.compute_s_per_batch = getu("compute_s_per_batch", c.compute_s_per_batch)?;
        c.jitter = getu("jitter", c.jitter)?;
        c.noise = getn("noise", c.noise as f64) as f32;
        c.classes = getu("classes", c.classes as f64)? as usize;
        c.eval_samples = getu("eval_samples", c.eval_samples as f64)? as u64;
        c.virtual_model_bytes =
            getu("virtual_model_bytes", c.virtual_model_bytes as f64)? as usize;
        c.fault = gets("fault", &c.fault);
        // Surface a malformed churn grammar at the config boundary, not
        // mid-launch.
        c.fault_plan()
            .with_context(|| format!("config field \"fault\" = {:?}", c.fault))?;
        c.cluster_nodes = getu("cluster_nodes", c.cluster_nodes as f64)? as usize;
        anyhow::ensure!(
            c.cluster_nodes >= 1,
            "config field \"cluster_nodes\" must be >= 1 (the pool needs a node), got {}",
            c.cluster_nodes
        );
        c.cluster_policy = gets("cluster_policy", &c.cluster_policy);
        anyhow::ensure!(
            crate::cluster::AllocPolicy::parse(&c.cluster_policy).is_some(),
            "unknown cluster_policy {:?} (valid: static, elastic)",
            c.cluster_policy
        );
        c.arrivals = gets("arrivals", &c.arrivals);
        // Same boundary discipline as `fault`: a malformed arrival grammar
        // dies here with the field named, not mid-schedule.
        c.arrival_plan()
            .with_context(|| format!("config field \"arrivals\" = {:?}", c.arrivals))?;
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&crate::jsonlite::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_round_trip() {
        for a in Algo::all() {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("nope"), None);
    }

    #[test]
    fn dist_modes_are_one_worker_clients() {
        let c = ExperimentConfig::testbed1(Algo::named("dist-SGD"));
        assert_eq!(c.clients, 12);
        assert_eq!(c.workers_per_client(), 1);
        let c = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        assert_eq!(c.clients, 2);
        assert_eq!(c.workers_per_client(), 6);
    }

    #[test]
    fn mini_batch_follows_section5() {
        // sync SGD: num_workers * batch; async/elastic: per-client workers.
        let sync = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        assert_eq!(sync.mini_batch(), 12 * 64);
        let esgd = ExperimentConfig::testbed1(Algo::named("mpi-ESGD"));
        assert_eq!(esgd.mini_batch(), 6 * 64);
        let bmuf = ExperimentConfig::testbed1(Algo::named("bmuf"));
        assert_eq!(bmuf.mini_batch(), 6 * 64);
    }

    #[test]
    fn json_round_trip() {
        let c = ExperimentConfig::testbed1(Algo::named("mpi-ESGD"));
        let v = c.to_json();
        let c2 = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c2.algo, c.algo);
        assert_eq!(c2.workers, c.workers);
        assert_eq!(c2.interval, c.interval);
        assert!((c2.alpha - c.alpha).abs() < 1e-9);
    }

    #[test]
    fn new_strategy_knobs_round_trip() {
        let mut c = ExperimentConfig::testbed1(Algo::named("bmuf"));
        c.block_momentum = 0.875;
        c.warmup_iters = 24;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert!((c2.block_momentum - 0.875).abs() < 1e-9);
        assert_eq!(c2.warmup_iters, 24);
        // Negative warmup is a count: rejected with the field named.
        let v = crate::jsonlite::parse(r#"{"algo": "local-sgd", "warmup_iters": -4}"#).unwrap();
        let err = ExperimentConfig::from_json(&v).unwrap_err();
        assert!(format!("{err:#}").contains("warmup_iters"));
    }

    #[test]
    fn unknown_algo_error_lists_registered_names() {
        let v = crate::jsonlite::parse(r#"{"algo": "turbo-SGD"}"#).unwrap();
        let err = ExperimentConfig::from_json(&v).unwrap_err();
        let msg = format!("{err:#}");
        for name in Algo::names() {
            assert!(msg.contains(name), "error does not list {name}: {msg}");
        }
    }

    #[test]
    fn partial_json_falls_back_to_defaults() {
        let v = crate::jsonlite::parse(r#"{"algo": "mpi-SGD", "workers": 4}"#).unwrap();
        let c = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.servers, 2);
        assert_eq!(c.collective, "auto");
        assert_eq!(c.fusion_bytes, 4 << 20);
    }

    #[test]
    fn negative_counts_rejected_with_field_name() {
        for (field, json) in [
            ("servers", r#"{"algo": "mpi-SGD", "servers": -1}"#),
            ("workers", r#"{"algo": "mpi-SGD", "workers": -3}"#),
            ("fusion_bytes", r#"{"algo": "mpi-SGD", "fusion_bytes": -4096}"#),
            ("epochs", r#"{"algo": "mpi-SGD", "epochs": -2}"#),
            ("threads", r#"{"algo": "mpi-SGD", "threads": -2}"#),
        ] {
            let v = crate::jsonlite::parse(json).unwrap();
            let err = ExperimentConfig::from_json(&v).unwrap_err();
            assert!(
                format!("{err:#}").contains(field),
                "error for {field} does not name it: {err:#}"
            );
        }
        // Zero stays legal (servers=0 is the pure-MPI mode).
        let v = crate::jsonlite::parse(r#"{"algo": "mpi-SGD", "servers": 0}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&v).unwrap().servers, 0);
    }

    #[test]
    fn devices_knob_round_trips_and_rejects_non_positive() {
        let mut c = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        assert_eq!(c.devices, 1); // flat default
        c.devices = 4;
        c.collective = "two_tier".into();
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.devices, 4);
        assert_eq!(c2.collective_kind(), AlgoKind::TwoTier);
        assert_eq!(c2.cost_params().devices, 4);
        // devices=0 would divide the batch by zero and truncate the tier
        // away; devices=-2 would wrap through the usize cast (the PR 3
        // servers=-1 class). Both must fail with the field named.
        for json in [
            r#"{"algo": "mpi-SGD", "devices": 0}"#,
            r#"{"algo": "mpi-SGD", "devices": -2}"#,
        ] {
            let v = crate::jsonlite::parse(json).unwrap();
            let err = ExperimentConfig::from_json(&v).unwrap_err();
            assert!(
                format!("{err:#}").contains("devices"),
                "error does not name \"devices\": {err:#}"
            );
        }
    }

    #[test]
    fn compression_knobs_round_trip_and_validate() {
        let mut c = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        assert_eq!(c.compression, "identity");
        assert!(c.codec().is_identity());
        c.compression = "topk".into();
        c.topk_ratio = 0.05;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.compression, "topk");
        assert!((c2.topk_ratio - 0.05).abs() < 1e-12);
        assert_eq!(c2.build_compressor().name(), "topk");
        // Unknown codec names are rejected at the JSON boundary with the
        // registry listed; direct field mutation degrades to identity.
        c.compression = "zip9".into();
        assert!(c.codec().is_identity());
        let err = ExperimentConfig::from_json(&c.to_json()).unwrap_err();
        let msg = format!("{err:#}");
        for name in crate::compress::Codec::names() {
            assert!(msg.contains(name), "error does not list {name}: {msg}");
        }
        // topk_ratio outside (0, 1] is rejected with the field named.
        c.compression = "topk".into();
        c.topk_ratio = 0.0;
        let err = ExperimentConfig::from_json(&c.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("topk_ratio"));
        c.topk_ratio = 1.5;
        assert!(ExperimentConfig::from_json(&c.to_json()).is_err());
    }

    #[test]
    fn fault_plan_round_trips_and_validates() {
        let mut c = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        c.fault = "kill:3@200,join@300".into();
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.fault, c.fault);
        assert_eq!(c2.fault_plan().unwrap().events.len(), 2);
        assert!(ExperimentConfig::testbed1(Algo::named("mpi-SGD"))
            .fault_plan()
            .unwrap()
            .is_empty());
        // Malformed grammar rejected at the JSON boundary.
        c.fault = "explode:1@5".into();
        assert!(ExperimentConfig::from_json(&c.to_json()).is_err());
    }

    #[test]
    fn cluster_knobs_round_trip_and_validate() {
        let mut c = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        assert_eq!(c.cluster_nodes, 8);
        assert_eq!(c.alloc_policy(), crate::cluster::AllocPolicy::Elastic);
        assert!(c.arrival_plan().unwrap().is_empty());
        c.cluster_nodes = 16;
        c.cluster_policy = "static".into();
        c.arrivals = "mpi-SGD:4x6@0,mpi-ESGD.int8:2x6@120".into();
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster_nodes, 16);
        assert_eq!(c2.alloc_policy(), crate::cluster::AllocPolicy::Static);
        assert_eq!(c2.arrival_plan().unwrap().jobs.len(), 2);
        // Unknown policy and malformed arrival grammar die at the JSON
        // boundary with the field named.
        c.cluster_policy = "greedy".into();
        let err = ExperimentConfig::from_json(&c.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("cluster_policy"));
        c.cluster_policy = "elastic".into();
        c.arrivals = "mpi-SGD:4x6".into();
        let err = ExperimentConfig::from_json(&c.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("arrivals"));
        // A zero-node pool could never place a gang.
        c.arrivals = String::new();
        c.cluster_nodes = 0;
        let err = ExperimentConfig::from_json(&c.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("cluster_nodes"));
    }

    #[test]
    fn collective_knob_round_trips_and_parses() {
        let mut c = ExperimentConfig::testbed1(Algo::named("mpi-SGD"));
        c.collective = "halving_doubling".into();
        c.fusion_bytes = 123456;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.collective, "halving_doubling");
        assert_eq!(c2.fusion_bytes, 123456);
        assert_eq!(c2.collective_kind(), AlgoKind::HalvingDoubling);
        // Direct field mutation degrades gracefully to the autotuner...
        c.collective = "not-a-schedule".into();
        assert_eq!(c.collective_kind(), AlgoKind::Auto);
        // ...but the JSON boundary rejects unknown schedules outright.
        assert!(ExperimentConfig::from_json(&c.to_json()).is_err());
    }
}
